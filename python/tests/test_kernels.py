"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the f32/bf16 dtypes the stack supports);
every property is a straight assert_allclose against ref.py. These tests
are the build-time gate: `make artifacts` refuses to ship HLO whose
kernels disagree with the oracles (see Makefile).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lora_linear, rmsnorm, wanda_apply
from compile.kernels.lora_linear import _block
from compile.kernels.ref import (
    lora_linear_bwd_ref,
    lora_linear_ref,
    magnitude_prune_ref,
    rmsnorm_ref,
    wanda_apply_ref,
    wanda_score_ref,
    wanda_threshold_ref,
)
from compile.kernels.wanda import wanda_prune

jax.config.update("jax_platform_name", "cpu")

dims = st.sampled_from([8, 16, 24, 48, 64, 96, 128])
ranks = st.sampled_from([2, 4, 6, 8, 16])


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


def _rank_mask(r_max, r_active):
    return (jnp.arange(r_max) < r_active).astype(jnp.float32)


# ------------------------------------------------------------- lora_linear


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, r=ranks, r_active=st.integers(0, 16))
def test_lora_linear_fwd_matches_ref(m, k, n, r, r_active):
    x, w = _rand(0, (m, k)), _rand(1, (n, k))
    a, b = _rand(2, (r, k), 0.05), _rand(3, (n, r), 0.05)
    mask = _rank_mask(r, min(r_active, r))
    got = lora_linear(x, w, a, b, mask, 2.0)
    want = lora_linear_ref(x, w, a, b, mask, 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(m=dims, k=dims, n=dims, r=ranks)
def test_lora_linear_grads_match_ref(m, k, n, r):
    x, w = _rand(0, (m, k)), _rand(1, (n, k))
    a, b = _rand(2, (r, k), 0.05), _rand(3, (n, r), 0.05)
    mask = _rank_mask(r, max(1, r // 2))
    dy = _rand(4, (m, n))

    def loss(x, a, b):
        return jnp.sum(lora_linear(x, w, a, b, mask, 2.0) * dy)

    dx, da, db = jax.grad(loss, (0, 1, 2))(x, a, b)
    dxr, dar, dbr = lora_linear_bwd_ref(x, w, a, b, mask, 2.0, dy)
    # f32 matmul accumulation order differs between the tiled kernel and the
    # single jnp dot; tolerance scales with the reduction length.
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(da, dar, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(db, dbr, rtol=1e-4, atol=1e-3)


def test_lora_linear_zero_mask_is_base_matmul():
    """rank mask all-zero => adapter contributes nothing (NLS lower bound)."""
    x, w = _rand(0, (32, 48)), _rand(1, (64, 48))
    a, b = _rand(2, (8, 48)), _rand(3, (64, 8))
    y = lora_linear(x, w, a, b, jnp.zeros(8), 4.0)
    np.testing.assert_allclose(y, x @ w.T, rtol=1e-5, atol=1e-4)


def test_lora_linear_full_mask_is_vanilla_lora():
    """all-ones mask == merged-LoRA forward (paper: maximal sub-adapter)."""
    x, w = _rand(0, (32, 48)), _rand(1, (64, 48))
    a, b = _rand(2, (8, 48), 0.1), _rand(3, (64, 8), 0.1)
    y = lora_linear(x, w, a, b, jnp.ones(8), 4.0)
    merged = w + 4.0 * (b @ a)
    np.testing.assert_allclose(y, x @ merged.T, rtol=1e-4, atol=1e-3)


def test_lora_linear_mask_prefix_equals_sliced_adapter():
    """Weight sharing: masking to rank r == using A[:r], B[:, :r] (paper §3.2)."""
    x, w = _rand(0, (32, 48)), _rand(1, (64, 48))
    a, b = _rand(2, (8, 48), 0.1), _rand(3, (64, 8), 0.1)
    for r in (2, 4, 6):
        y_masked = lora_linear(x, w, a, b, _rank_mask(8, r), 2.0)
        y_sliced = x @ w.T + (x @ a[:r].T) @ b[:, :r].T * 2.0
        np.testing.assert_allclose(y_masked, y_sliced, rtol=1e-5, atol=1e-4)


def test_lora_linear_frozen_w_gets_zero_grad():
    x, w = _rand(0, (16, 24)), _rand(1, (32, 24))
    a, b = _rand(2, (4, 24)), _rand(3, (32, 4))
    dw = jax.grad(lambda w: jnp.sum(lora_linear(x, w, a, b, jnp.ones(4), 1.0)))(w)
    np.testing.assert_array_equal(dw, jnp.zeros_like(w))


def test_block_helper_divides():
    for dim in (1, 7, 48, 128, 344, 512, 1000):
        for cap in (1, 16, 128, 4096):
            b = _block(dim, cap)
            assert b >= 1 and b <= cap or b == dim
            assert dim % b == 0


# ----------------------------------------------------------------- rmsnorm


@settings(max_examples=10, deadline=None)
@given(m=dims, d=dims)
def test_rmsnorm_matches_ref(m, d):
    x, g = _rand(0, (m, d)), _rand(1, (d,))
    np.testing.assert_allclose(rmsnorm(x, g), rmsnorm_ref(x, g), rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(m=dims, d=dims)
def test_rmsnorm_grads_match_autodiff_of_ref(m, d):
    x, g = _rand(0, (m, d)), _rand(1, (d,))
    dx, dg = jax.grad(lambda x, g: jnp.sum(jnp.sin(rmsnorm(x, g))), (0, 1))(x, g)
    dxr, dgr = jax.grad(lambda x, g: jnp.sum(jnp.sin(rmsnorm_ref(x, g))), (0, 1))(x, g)
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dg, dgr, rtol=1e-4, atol=1e-4)


def test_rmsnorm_row_scale_invariant_direction():
    """RMSNorm output is invariant to positive row scaling of the input."""
    x, g = _rand(0, (8, 32)), _rand(1, (32,))
    y1, y2 = rmsnorm(x, g), rmsnorm(x * 7.5, g)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------------- wanda


@settings(max_examples=10, deadline=None)
@given(n=dims, k=dims, keep=st.sampled_from([0.3, 0.5, 0.6, 0.75, 1.0]))
def test_wanda_kernel_matches_ref(n, k, keep):
    w = _rand(0, (n, k))
    xnorm = jnp.abs(_rand(1, (k,))) + 0.01
    thresh = wanda_threshold_ref(w, xnorm, keep)
    wp, mask = wanda_apply(w, xnorm, thresh)
    wpr, maskr = wanda_apply_ref(w, xnorm, thresh)
    np.testing.assert_allclose(wp, wpr, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(mask, maskr)


@settings(max_examples=8, deadline=None)
@given(n=dims, k=dims, sparsity=st.sampled_from([0.0, 0.4, 0.5, 0.7]))
def test_wanda_prune_hits_target_sparsity_per_row(n, k, sparsity):
    """Wanda compares within rows (paper §2.1): every row hits the target."""
    w = _rand(0, (n, k)) + 0.01  # avoid ties at 0
    xnorm = jnp.abs(_rand(1, (k,))) + 0.01
    _, mask = wanda_prune(w, xnorm, 1.0 - sparsity)
    keep_per_row = np.asarray(mask.sum(axis=1))
    expect = max(1, round(k * (1.0 - sparsity)))
    assert (keep_per_row == expect).all(), (keep_per_row[:4], expect)


def test_wanda_prefers_high_activation_columns():
    """With equal |W|, columns with larger ||X||_2 must survive (Eq. 1)."""
    n, k = 16, 32
    w = jnp.ones((n, k))
    xnorm = jnp.arange(1, k + 1, dtype=jnp.float32)
    _, mask = wanda_prune(w, xnorm, 0.5)
    assert mask[:, k // 2:].all() and not mask[:, : k // 2].any()


def test_wanda_score_is_abs_w_times_xnorm():
    w = _rand(0, (8, 16))
    xnorm = jnp.abs(_rand(1, (16,)))
    np.testing.assert_allclose(
        wanda_score_ref(w, xnorm), jnp.abs(w) * xnorm[None, :], rtol=1e-6
    )


def test_magnitude_prune_ignores_activations():
    """Magnitude baseline == Wanda with unit activations."""
    w = _rand(0, (16, 32))
    wp_mag, m_mag = magnitude_prune_ref(w, 0.5)
    wp_w, m_w = wanda_prune(w, jnp.ones(32), 0.5)
    np.testing.assert_allclose(wp_mag, wp_w, rtol=1e-6)
    np.testing.assert_array_equal(m_mag, m_w)


def test_wanda_keep_all_is_identity():
    w = _rand(0, (16, 24))
    xnorm = jnp.abs(_rand(1, (24,))) + 0.1
    wp, mask = wanda_prune(w, xnorm, 1.0)
    np.testing.assert_array_equal(wp, w)
    assert mask.all()
