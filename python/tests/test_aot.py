"""AOT/manifest integrity: the L2↔L3 ABI invariants.

These tests validate the *builders* (fast — no lowering) and, when
`artifacts/manifest.json` exists, cross-check it against the current
builder signatures so a stale `make artifacts` is caught in CI.
"""

import json
import os

import pytest

from compile import model as M
from compile import train as T
from compile.aot import ENTRY_SETS, PRUNE_KINDS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_sets_cover_all_builders():
    for cname, entries in ENTRY_SETS.items():
        assert cname in M.CONFIGS
        for e in entries:
            assert e in T.BUILDERS, e
    # every config ships the pipeline-critical entries
    for entries in ENTRY_SETS.values():
        for required in ["train_step_nls", "train_step_full", "forward_eval",
                         "forward_eval_base", "calib_stats"]:
            assert required in entries


@pytest.mark.parametrize("cname", list(M.CONFIGS.keys()))
def test_builder_signatures_consistent(cname):
    cfg = M.CONFIGS[cname]
    for entry in ENTRY_SETS[cname]:
        built = T.BUILDERS[entry](cfg)
        assert len(built["specs"]) == len(built["input_names"]), entry
        assert len(set(built["input_names"])) == len(built["input_names"]), entry
        assert len(set(built["output_names"])) == len(built["output_names"]), entry
        # train steps: outputs are trainables + opt state + loss
        if entry.startswith("train_step"):
            assert built["output_names"][-1] == "loss", entry
            n_out = len(built["output_names"]) - 1
            assert n_out % 3 == 0, entry  # params, m, v aligned


def test_train_nls_input_order_matches_convention():
    cfg = M.CONFIGS["tiny-llama"]
    built = T.build_train_step_nls(cfg)
    names = built["input_names"]
    nb = len(M.base_param_specs(cfg))
    na = len(M.adapter_param_specs(cfg))
    assert names[:nb] == [n for n, _ in M.base_param_specs(cfg)]
    assert names[nb:nb + na] == [n for n, _ in M.adapter_param_specs(cfg)]
    assert names[-6:] == ["step", "lr", "x", "y", "loss_mask", "rank_mask"]


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_matches_current_builders():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for cname, cj in man["configs"].items():
        cfg = M.CONFIGS[cname]
        assert [p["name"] for p in cj["base_params"]] == [
            n for n, _ in M.base_param_specs(cfg)
        ]
        assert [p["name"] for p in cj["adapter_params"]] == [
            n for n, _ in M.adapter_param_specs(cfg)
        ]
        assert cj["adapter_modules"] == M.adapter_modules(cfg)
        for entry, ej in cj["entrypoints"].items():
            built = T.BUILDERS[entry](cfg)
            assert [i["name"] for i in ej["inputs"]] == built["input_names"], (
                cname, entry)
            assert [o["name"] for o in ej["outputs"]] == built["output_names"], (
                cname, entry)
            # the artifact file exists
            assert os.path.exists(os.path.join(ART, ej["file"]))


@needs_artifacts
def test_prune_ops_cover_every_prunable_shape():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    shapes = set()
    for cj in man["configs"].values():
        for p in cj["prunable"]:
            shapes.add(tuple(p["shape"]))
    for (n, k) in shapes:
        for kind in PRUNE_KINDS:
            key = f"{kind}_{n}x{k}"
            assert key in man["prune_ops"], key
            assert os.path.exists(os.path.join(ART, man["prune_ops"][key]["file"]))
