"""L2 model correctness: forward semantics, adapter variants, train steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["tiny-llama"]


def _init_base(cfg, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    base = {}
    for n, s in M.base_param_specs(cfg):
        if n.endswith(".g"):
            base[n] = jnp.ones(s)
        elif n.endswith(".b"):
            base[n] = jnp.zeros(s)
        else:
            base[n] = jnp.asarray(rng.normal(0, scale, s).astype("float32"))
    return base


def _init_adapters(cfg, seed=1, scale=0.02):
    rng = np.random.default_rng(seed)
    adpt = {}
    for n, s in M.adapter_param_specs(cfg):
        # LoRA init (paper §2.2): A gaussian, B zeros
        adpt[n] = (
            jnp.asarray(rng.normal(0, scale, s).astype("float32"))
            if n.startswith("lora_a")
            else jnp.zeros(s)
        )
    return adpt


def _batch(cfg, seed=2, train=True):
    rng = np.random.default_rng(seed)
    b = cfg["batch_train"] if train else cfg["batch_eval"]
    x = jnp.asarray(rng.integers(0, 32, (b, cfg["seq_len"])), jnp.int32)
    return x, jnp.roll(x, -1, axis=1), jnp.ones((b, cfg["seq_len"]))


# ------------------------------------------------------------------ specs


def test_base_param_specs_cover_all_archs():
    for name, cfg in M.CONFIGS.items():
        specs = M.base_param_specs(cfg)
        names = [n for n, _ in specs]
        assert len(names) == len(set(names)), name
        assert "embed" in names and "lm_head" in names
        if cfg["arch"] == "mpt":
            assert "layers.0.attn_norm.b" in names  # LayerNorm has bias
            assert "layers.0.mlp.gate" not in names  # GELU MLP, no gate


def test_adapter_specs_match_modules_and_targets():
    for cfg in M.CONFIGS.values():
        mods = M.adapter_modules(cfg)
        assert len(mods) == cfg["n_layers"] * len(cfg["targets"])
        specs = M.adapter_param_specs(cfg)
        assert len(specs) == 2 * len(mods)
        r = cfg["max_rank"]
        for (an, ash), (bn, bsh) in zip(specs[::2], specs[1::2]):
            assert an.startswith("lora_a.") and bn.startswith("lora_b.")
            assert an[7:] == bn[7:]  # same module
            assert ash[0] == r and bsh[1] == r
            out, inp = bsh[0], ash[1]
            assert (out, inp) in [
                M._target_shape(cfg, t) for t in cfg["targets"]
            ]


def test_prunable_sites_exist_in_calib_sites():
    for cfg in M.CONFIGS.values():
        site_names = {s for s, _ in M.calib_sites(cfg)}
        for name, (n, k), site in M.prunable_specs(cfg):
            assert site in site_names, (name, site)
        # site dim must match the weight's input dim
        dims = dict(M.calib_sites(cfg))
        for name, (n, k), site in M.prunable_specs(cfg):
            assert dims[site] == k, (name, site)


# ---------------------------------------------------------------- forward


def test_forward_shapes():
    base = _init_base(CFG)
    x, _, _ = _batch(CFG)
    logits = M.forward(CFG, base, x)
    assert logits.shape == (x.shape[0], x.shape[1], CFG["vocab"])
    assert bool(jnp.isfinite(logits).all())


def test_forward_mpt_shapes():
    cfg = dict(M.CONFIGS["mpt-sim"])
    cfg.update(n_layers=1, seq_len=16, batch_train=2)  # keep the test fast
    base = _init_base(cfg)
    x, _, _ = _batch(cfg)
    logits = M.forward(cfg, base, x)
    assert logits.shape == (2, 16, cfg["vocab"])
    assert bool(jnp.isfinite(logits).all())


def test_forward_is_causal():
    """Changing a future token must not change past logits."""
    base = _init_base(CFG)
    x, _, _ = _batch(CFG)
    l1 = M.forward(CFG, base, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % CFG["vocab"])
    l2 = M.forward(CFG, base, x2)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)


def test_zero_rank_mask_equals_base_forward():
    """NLS minimal-below-minimum: all-zero mask deactivates every adapter."""
    base, adpt = _init_base(CFG), _init_adapters(CFG)
    # give B nonzero values so the mask actually has something to suppress
    adpt = {k: (v if k.startswith("lora_a") else jnp.ones_like(v) * 0.1) for k, v in adpt.items()}
    x, _, _ = _batch(CFG)
    n_mods, r = len(M.adapter_modules(CFG)), CFG["max_rank"]
    la = M.forward(CFG, base, x, adapters=adpt, rank_mask=jnp.zeros((n_mods, r)))
    lb = M.forward(CFG, base, x)
    np.testing.assert_allclose(la, lb, atol=1e-5)


def test_zero_init_b_makes_adapters_transparent():
    """LoRA init invariant (paper §2.2): B=0 => adapted forward == base."""
    base, adpt = _init_base(CFG), _init_adapters(CFG)
    x, _, _ = _batch(CFG)
    n_mods, r = len(M.adapter_modules(CFG)), CFG["max_rank"]
    la = M.forward(CFG, base, x, adapters=adpt, rank_mask=jnp.ones((n_mods, r)))
    lb = M.forward(CFG, base, x)
    np.testing.assert_allclose(la, lb, atol=1e-5)


def test_rank_mask_prefix_slices_superadapter():
    """Sub-adapter of rank r == slicing A/B to rank r (weight sharing)."""
    base, adpt = _init_base(CFG), _init_adapters(CFG)
    rng = np.random.default_rng(3)
    adpt = {
        k: jnp.asarray(rng.normal(0, 0.05, v.shape).astype("float32"))
        for k, v in adpt.items()
    }
    x, _, _ = _batch(CFG)
    mods, r = M.adapter_modules(CFG), CFG["max_rank"]
    sub_r = 4
    mask = jnp.broadcast_to(
        (jnp.arange(r) < sub_r).astype(jnp.float32), (len(mods), r)
    )
    l_masked = M.forward(CFG, base, x, adapters=adpt, rank_mask=mask)

    sliced = {}
    for k, v in adpt.items():
        if k.startswith("lora_a"):
            sliced[k] = v.at[sub_r:].set(0.0)
        else:
            sliced[k] = v.at[:, sub_r:].set(0.0)
    l_sliced = M.forward(
        CFG, base, x, adapters=sliced, rank_mask=jnp.ones((len(mods), r))
    )
    np.testing.assert_allclose(l_masked, l_sliced, atol=1e-5)


def test_prefix_series_parallel_change_logits():
    base = _init_base(CFG)
    x, _, _ = _batch(CFG)
    l0 = M.forward(CFG, base, x)
    rng = np.random.default_rng(4)

    pre = {n: jnp.asarray(rng.normal(0, 0.1, s).astype("float32"))
           for n, s in M.prefix_param_specs(CFG)}
    assert float(jnp.abs(M.forward(CFG, base, x, prefix=pre) - l0).max()) > 1e-4

    ser = {n: jnp.asarray(rng.normal(0, 0.1, s).astype("float32"))
           for n, s in M.series_param_specs(CFG)}
    assert float(jnp.abs(M.forward(CFG, base, x, series=ser) - l0).max()) > 1e-4

    par = {n: jnp.asarray(rng.normal(0, 0.1, s).astype("float32"))
           for n, s in M.parallel_param_specs(CFG)}
    assert float(jnp.abs(M.forward(CFG, base, x, parallel=par) - l0).max()) > 1e-4


def test_calib_stats_shapes_and_psd():
    base = _init_base(CFG)
    x, _, _ = _batch(CFG)
    fw = M.Forward(CFG, base, collect=True)
    fw(x)
    dims = dict(M.calib_sites(CFG))
    for site, dim in M.calib_sites(CFG):
        sumsq, h = fw.stats[site]
        assert sumsq.shape == (dim,) and h.shape == (dim, dim)
        assert bool((sumsq >= 0).all())
        # Gram matrices are PSD: x'Hx >= 0
        z = jnp.ones((dim,))
        assert float(z @ h @ z) >= -1e-3
        # diag(H) == sumsq by construction
        np.testing.assert_allclose(jnp.diag(h), sumsq, rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------- train steps


def _run_steps(built, args_init, n_steps, extract, lr=3e-3):
    fn = jax.jit(built["fn"])
    args = list(args_init)
    losses = []
    for step in range(n_steps):
        out = fn(*args, jnp.float32(step + 1), jnp.float32(lr), *extract)
        n_new = len(out) - 1
        args = args[: len(args) - n_new] + list(out[:-1]) if False else args
        losses.append(float(out[-1]))
        # re-thread updated params (they lead the arg list after base)
        args = args[: len(args) - n_new] + list(out[:n_new])
    return losses


def test_train_step_nls_reduces_loss():
    cfg = CFG
    base, adpt = _init_base(cfg), _init_adapters(cfg)
    x, y, lmask = _batch(cfg)
    built = T.build_train_step_nls(cfg)
    aspecs = M.adapter_param_specs(cfg)
    zeros = [jnp.zeros(s) for _, s in aspecs]
    n_mods, r = len(M.adapter_modules(cfg)), cfg["max_rank"]
    rmask = jnp.ones((n_mods, r))
    fn = jax.jit(built["fn"])
    args = [base[n] for n, _ in M.base_param_specs(cfg)] \
        + [adpt[n] for n, _ in aspecs] + zeros + zeros
    losses = []
    for step in range(25):
        out = fn(*args, jnp.float32(step + 1), jnp.float32(5e-3),
                 x, y, lmask, rmask)
        na = len(aspecs)
        args = args[: len(M.base_param_specs(cfg))] + list(out[: 3 * na])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.98, losses[::6]


def test_train_step_full_keeps_sparsity():
    """SparseFT protocol: pruned weights stay exactly zero through training."""
    cfg = CFG
    base = _init_base(cfg)
    prun = M.prunable_specs(cfg)
    rng = np.random.default_rng(7)
    masks = [
        jnp.asarray((rng.random(s) > 0.5).astype("float32")) for _, s, _ in prun
    ]
    for (n, _, _), mk in zip(prun, masks):
        base[n] = base[n] * mk
    x, y, lmask = _batch(cfg)
    built = T.build_train_step_full(cfg)
    bspecs = M.base_param_specs(cfg)
    zeros = [jnp.zeros(s) for _, s in bspecs]
    fn = jax.jit(built["fn"])
    args = [base[n] for n, _ in bspecs] + zeros + zeros + masks
    for step in range(3):
        out = fn(*args, jnp.float32(step + 1), jnp.float32(1e-3), x, y, lmask)
        nb = len(bspecs)
        args = list(out[: 3 * nb]) + masks
    new_base = dict(zip([n for n, _ in bspecs], out[: len(bspecs)]))
    for (n, _, _), mk in zip(prun, masks):
        zeroed = np.asarray(new_base[n])[np.asarray(mk) == 0]
        assert (zeroed == 0).all(), n


@pytest.mark.parametrize("entry", ["train_step_prefix", "train_step_series",
                                   "train_step_parallel"])
def test_baseline_train_steps_reduce_loss(entry):
    cfg = CFG
    base = _init_base(cfg)
    x, y, lmask = _batch(cfg)
    built = T.BUILDERS[entry](cfg)
    especs = {
        "train_step_prefix": M.prefix_param_specs,
        "train_step_series": M.series_param_specs,
        "train_step_parallel": M.parallel_param_specs,
    }[entry](cfg)
    rng = np.random.default_rng(8)
    ext = [jnp.asarray(rng.normal(0, 0.02, s).astype("float32")) for _, s in especs]
    zeros = [jnp.zeros(s) for _, s in especs]
    fn = jax.jit(built["fn"])
    args = [base[n] for n, _ in M.base_param_specs(cfg)] + ext + zeros + zeros
    losses = []
    for step in range(15):
        out = fn(*args, jnp.float32(step + 1), jnp.float32(5e-3), x, y, lmask)
        ne = len(especs)
        args = args[: len(M.base_param_specs(cfg))] + list(out[: 3 * ne])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], (entry, losses[::5])


def test_lm_loss_mask_restricts_positions():
    logits = jnp.zeros((2, 4, 8))
    y = jnp.zeros((2, 4), jnp.int32)
    full = M.lm_loss(logits, y, jnp.ones((2, 4)))
    half = M.lm_loss(logits, y, jnp.concatenate(
        [jnp.ones((2, 2)), jnp.zeros((2, 2))], axis=1))
    np.testing.assert_allclose(full, half, rtol=1e-6)  # uniform logits
    np.testing.assert_allclose(full, np.log(8.0), rtol=1e-5)


def test_adamw_moves_toward_gradient():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.ones((4,))}
    m = {"w": jnp.zeros((4,))}
    v = {"w": jnp.zeros((4,))}
    newp, _, _ = M.adamw_update(p, g, m, v, 1.0, 0.1)
    assert bool((newp["w"] < p["w"]).all())
