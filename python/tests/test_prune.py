"""Prune-op correctness: Wanda / magnitude / SparseGPT-lite semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import prune as P

jax.config.update("jax_platform_name", "cpu")

dims = st.sampled_from([8, 16, 32, 48, 64])


def _w(seed, n, k):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, (n, k)).astype("float32"))


def _gram(seed, k, m=256):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (m, k)).astype("float32")
    return jnp.asarray(x.T @ x)


@settings(max_examples=10, deadline=None)
@given(n=dims, k=dims, sparsity=st.sampled_from([0.4, 0.5, 0.7]))
def test_wanda_op_row_sparsity(n, k, sparsity):
    w = _w(0, n, k)
    sumsq = jnp.abs(_w(1, 1, k)[0]) + 0.01
    wp, mask = P.wanda_op(w, sumsq, 1.0 - sparsity)
    expect = max(1, round(k * (1.0 - sparsity)))
    assert (np.asarray(mask.sum(axis=1)) == expect).all()
    np.testing.assert_array_equal(np.asarray(wp)[np.asarray(mask) == 0], 0.0)


@settings(max_examples=10, deadline=None)
@given(n=dims, k=dims)
def test_magnitude_op_keeps_largest(n, k):
    w = _w(0, n, k)
    wp, mask = P.magnitude_op(w, 0.5)
    aw = np.abs(np.asarray(w))
    for r in range(min(n, 4)):
        kept = aw[r][np.asarray(mask[r]) == 1]
        dropped = aw[r][np.asarray(mask[r]) == 0]
        if len(dropped):
            assert kept.min() >= dropped.max() - 1e-6


def test_sparsegpt_hits_sparsity_and_compensates():
    n, k = 32, 48
    w = _w(0, n, k)
    gram = _gram(1, k)
    wp, mask = P.sparsegpt_op(w, gram, 0.5)
    assert abs(float(mask.mean()) - 0.5) < 0.05
    np.testing.assert_array_equal(np.asarray(wp)[np.asarray(mask) == 0], 0.0)
    # surviving weights must have moved (OBS compensation), unlike Wanda
    moved = np.abs(np.asarray(wp) - np.asarray(w))[np.asarray(mask) == 1]
    assert moved.max() > 1e-4


def test_sparsegpt_compensation_beats_naive_masking():
    """The point of OBS: compensating survivors shrinks ||XW' - XW||
    versus zeroing the same weights without compensation."""
    n, k, m = 32, 48, 512
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (m, k)).astype("float32")
    x[:, 1] = 0.9 * x[:, 0] + 0.1 * x[:, 1]  # correlation to exploit
    w = jnp.asarray(rng.normal(0, 1, (n, k)).astype("float32"))
    gram = jnp.asarray(x.T @ x)
    wp_s, mask_s = P.sparsegpt_op(w, gram, 0.5)
    y = x @ np.asarray(w).T
    err_comp = np.linalg.norm(x @ np.asarray(wp_s).T - y)
    err_naive = np.linalg.norm(x @ (np.asarray(w) * np.asarray(mask_s)).T - y)
    assert err_comp < err_naive, (err_comp, err_naive)


def test_sparsegpt_beats_magnitude_under_anisotropic_activations():
    """Activation-aware pruning wins when input scales are skewed —
    the regime Figure 2 / the Wanda paper motivate."""
    n, k, m = 32, 48, 512
    rng = np.random.default_rng(6)
    scales = np.logspace(-2, 1, k).astype("float32")
    x = (rng.normal(0, 1, (m, k)) * scales[None, :]).astype("float32")
    w = jnp.asarray(rng.normal(0, 1, (n, k)).astype("float32"))
    gram = jnp.asarray(x.T @ x)
    wp_s, _ = P.sparsegpt_op(w, gram, 0.5)
    wp_m, _ = P.magnitude_op(w, 0.5)
    y = x @ np.asarray(w).T
    err_s = np.linalg.norm(x @ np.asarray(wp_s).T - y)
    err_m = np.linalg.norm(x @ np.asarray(wp_m).T - y)
    assert err_s < err_m, (err_s, err_m)


def test_wanda_op_uses_activation_scale():
    """Wanda ≠ magnitude when activations are skewed (paper's core claim)."""
    n, k = 16, 32
    w = jnp.ones((n, k))
    sumsq = jnp.asarray(np.linspace(0.01, 10.0, k).astype("float32")) ** 2
    _, mask_w = P.wanda_op(w, sumsq, 0.5)
    _, mask_m = P.magnitude_op(w + jnp.asarray(
        np.random.default_rng(0).normal(0, 1e-4, (n, k)).astype("float32")), 0.5)
    # wanda keeps the high-activation half
    assert bool(mask_w[:, k // 2:].all())
    assert not bool((mask_w == mask_m).all())


def test_keep_frac_one_is_identity_everywhere():
    w = _w(3, 16, 24)
    for kind, args in [
        ("wanda", (w, jnp.ones(24), 1.0)),
        ("magnitude", (w, 1.0)),
        ("sparsegpt", (w, _gram(4, 24), 1.0)),
    ]:
        wp, mask = getattr(P, f"{kind}_op")(*args)
        assert bool(mask.all()), kind
        np.testing.assert_allclose(wp, w, atol=1e-5)
