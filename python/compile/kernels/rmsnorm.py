"""RMSNorm as a Pallas kernel (forward) with a jnp backward.

llama-sim normalizes with RMSNorm (Touvron et al., 2023); the kernel fuses
the mean-square reduction, rsqrt, and gain multiply in one VMEM-resident
pass over a [bm, D] row tile. Backward is closed-form jnp (cheap relative
to the matmuls and keeps the HLO small).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
_BM = 128


def _block(dim: int, cap: int) -> int:
    b = min(dim, cap)
    while dim % b:
        b -= 1
    return b


def _kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...][None, :]


def _fwd(x, g, eps):
    m, d = x.shape
    bm = _block(m, _BM)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=INTERPRET,
    )(x, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, g, eps=1e-6):
    """x * rsqrt(mean(x^2) + eps) * g over the last axis of a 2-D input."""
    return _fwd(x, g, eps)


def _vjp_fwd(x, g, eps):
    return _fwd(x, g, eps), (x, g)


def _vjp_bwd(eps, res, dy):
    x, g = res
    d = x.shape[-1]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    xhat = x * inv
    dg = jnp.sum(dy * xhat, axis=0)
    dxhat = dy * g[None, :]
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True) * d / d)
    # d/dx of x*inv: inv*dxhat - x * inv^3/d * sum(dxhat*x)
    dx = inv * dxhat - x * (inv ** 3) * jnp.mean(dxhat * x, axis=-1, keepdims=True)
    return dx, dg


rmsnorm.defvjp(_vjp_fwd, _vjp_bwd)
