"""Layer-1 Pallas kernels for Shears.

Every kernel here is authored with `pl.pallas_call(..., interpret=True)`:
the CPU PJRT plugin cannot execute Mosaic custom-calls, so interpret mode
is the correctness path, while the BlockSpec structure documents the
intended TPU HBM<->VMEM schedule (see DESIGN.md §4 / §9).

Public surface:
  lora_linear   — fused sparse-base + elastic-LoRA linear (custom_vjp)
  rmsnorm       — fused RMSNorm (custom_vjp, jnp backward)
  wanda_apply   — Wanda score + per-row threshold masking
"""

from .lora_linear import lora_linear
from .rmsnorm import rmsnorm
from .wanda import wanda_apply

__all__ = ["lora_linear", "rmsnorm", "wanda_apply"]
