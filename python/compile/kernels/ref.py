"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: pytest (python/tests/) asserts the
Pallas kernels match these to tight tolerances across hypothesis-driven
shape sweeps, and the Rust integration tests execute HLO lowered from
graphs that call the kernels and compare against values computed from the
same math.

Conventions (shared with lora_linear.py and model.py):
  X     [M, K]   activations, M = batch*seq flattened
  W     [N, K]   frozen (possibly sparsified) base weight, row = out feature
  A     [R, K]   LoRA down-projection (trainable)
  B     [N, R]   LoRA up-projection (trainable, zero-init)
  mask  [R]      elastic rank mask: first r entries 1.0, rest 0.0
  scale scalar   lora_alpha / R  (static)

  Y = X @ W.T + ((X @ A.T) * mask) @ B.T * scale
"""

import jax.numpy as jnp


def lora_linear_ref(x, w, a, b, mask, scale):
    """Fused base + elastic-LoRA linear. Y[M,N]."""
    p = (x @ a.T) * mask[None, :]
    return x @ w.T + (p @ b.T) * scale


def lora_linear_bwd_ref(x, w, a, b, mask, scale, dy):
    """Reference gradients (dx, da, db). W is frozen -> no dw."""
    p = (x @ a.T) * mask[None, :]          # [M, R]
    dp = (dy @ b) * mask[None, :] * scale  # [M, R]
    dx = dy @ w + dp @ a                   # [M, K]
    da = dp.T @ x                          # [R, K]
    db = (dy.T @ p) * scale                # [N, R]
    return dx, da, db


def rmsnorm_ref(x, g, eps=1e-6):
    """RMSNorm: x / rms(x) * g, row-wise over the last axis."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * g


def wanda_score_ref(w, xnorm):
    """Wanda importance (paper Eq. 1): S = |W| * ||X||_2 (broadcast)."""
    return jnp.abs(w) * xnorm[None, :]


def wanda_threshold_ref(w, xnorm, keep_frac):
    """Per-row score threshold keeping the top round(K*keep_frac) weights.

    Wanda compares importance *within each row* of W; the threshold is the
    score of the last kept element.
    """
    k = w.shape[1]
    n_keep = jnp.clip(jnp.round(k * keep_frac).astype(jnp.int32), 1, k)
    scores = wanda_score_ref(w, xnorm)
    sorted_desc = -jnp.sort(-scores, axis=1)
    # threshold = score of the n_keep-th largest element (1-indexed)
    idx = jnp.broadcast_to(n_keep - 1, (w.shape[0],))[:, None]
    return jnp.take_along_axis(sorted_desc, idx, axis=1)[:, 0]


def wanda_apply_ref(w, xnorm, thresh):
    """Zero out weights whose score falls strictly below the row threshold."""
    scores = wanda_score_ref(w, xnorm)
    mask = (scores >= thresh[:, None]).astype(w.dtype)
    return w * mask, mask


def magnitude_prune_ref(w, keep_frac):
    """Per-row magnitude pruning baseline (same protocol as Wanda, S=|W|)."""
    ones = jnp.ones((w.shape[1],), w.dtype)
    return wanda_apply_ref(w, ones, wanda_threshold_ref(w, ones, keep_frac))
