"""Wanda pruning as a Pallas kernel (paper §2.1 / §3.1, Eq. 1).

Importance S = |W| * ||X||_2 with per-row comparison: each output row of W
keeps its top round(K*keep_frac) weights. The row thresholds require a
sort, which stays in jnp (`wanda_threshold_ref`); the O(N*K) score +
compare + mask application — the part that touches every weight — is the
Pallas kernel, tiled [bn, bk] over W.

Outputs both the pruned weights and the {0,1} mask; the mask is what
`train_step_full` (the SparseFT baseline) re-applies after every optimizer
step so sparsity survives full fine-tuning.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import wanda_threshold_ref

INTERPRET = True
_BN, _BK = 128, 256


def _block(dim: int, cap: int) -> int:
    b = min(dim, cap)
    while dim % b:
        b -= 1
    return b


def _kernel(w_ref, xnorm_ref, thresh_ref, wp_ref, mask_ref):
    w = w_ref[...]                                  # [bn, bk]
    s = jnp.abs(w) * xnorm_ref[...][None, :]        # Wanda Eq. 1
    keep = (s >= thresh_ref[...][:, None]).astype(w.dtype)
    wp_ref[...] = w * keep
    mask_ref[...] = keep


def wanda_apply(w, xnorm, thresh):
    """Apply per-row thresholds: returns (W_pruned, mask)."""
    n, k = w.shape
    bn, bk = _block(n, _BN), _block(k, _BK)
    return pl.pallas_call(
        _kernel,
        grid=(n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), w.dtype),
            jax.ShapeDtypeStruct((n, k), w.dtype),
        ],
        interpret=INTERPRET,
    )(w, xnorm, thresh)


def wanda_prune(w, xnorm, keep_frac):
    """Full Wanda: thresholds (jnp sort) + kernel application."""
    thresh = wanda_threshold_ref(w, xnorm, keep_frac)
    return wanda_apply(w, xnorm, thresh)
