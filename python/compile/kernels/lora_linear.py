"""Fused sparse-base + elastic-LoRA linear as a Pallas kernel.

This is the Shears hot path: every adapter-target projection in the model
computes

    Y = X @ W_p.T + ((X @ A.T) * rank_mask) @ B.T * scale

where W_p is the frozen, Wanda-sparsified base weight and (A, B) is the
super-adapter. The rank mask implements NLS weight sharing: activating a
sub-adapter of rank r is masking columns r..R of the LoRA intermediate,
so one compiled executable serves every sub-adapter configuration
(paper §3.2; DESIGN.md "rank masks").

TPU mapping (DESIGN.md §4): the grid tiles (M, N); each program holds an
X tile [bm, K], a W tile [bn, K], and the *entire* adapter (A [R, K],
B tile [bn, R], R <= 8 here / 32 in the paper) in VMEM, so the LoRA path
reuses the X tile already resident for the base matmul — the fusion is
exactly why Shears can leave adapters unmerged (paper §4.4) without an
extra pass over HBM.

`pallas_call` has no automatic reverse-mode rule, so the public
`lora_linear` is a `jax.custom_vjp` whose backward pass is three more
Pallas kernels (dX, dA, dB). W is frozen in Shears; its cotangent is a
symbolic zero that XLA dead-code-eliminates.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# VMEM-driven tile caps (f32 words). With bm=bn=128 and K<=512:
#   X tile 128*512*4 = 256 KiB, W tile 256 KiB, out 64 KiB, A+B < 20 KiB
# -> < 0.6 MiB/program, ample double-buffering headroom in 16 MiB VMEM.
_BM, _BN, _BK = 128, 128, 128


def _block(dim: int, cap: int) -> int:
    """Largest divisor of `dim` not exceeding `cap` (grids must tile exactly)."""
    b = min(dim, cap)
    while dim % b:
        b -= 1
    return b


# ---------------------------------------------------------------- forward


def _fwd_kernel(x_ref, w_ref, a_ref, b_ref, mask_ref, o_ref, *, scale):
    x = x_ref[...]                                       # [bm, K]
    p = jnp.dot(x, a_ref[...].T) * mask_ref[...][None, :]  # [bm, R]
    o_ref[...] = jnp.dot(x, w_ref[...].T) + jnp.dot(p, b_ref[...].T) * scale


def _fwd(x, w, a, b, mask, scale):
    m, k = x.shape
    n, r = b.shape
    bm, bn = _block(m, _BM), _block(n, _BN)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((r, k), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w, a, b, mask)


# ---------------------------------------------------------------- backward


def _dx_kernel(dy_ref, w_ref, a_ref, b_ref, mask_ref, dx_ref, *, scale):
    dy = dy_ref[...]                                        # [bm, N]
    dp = jnp.dot(dy, b_ref[...]) * mask_ref[...][None, :] * scale  # [bm, R]
    dx_ref[...] = jnp.dot(dy, w_ref[...]) + jnp.dot(dp, a_ref[...])


def _dx(dy, w, a, b, mask, scale):
    m, n = dy.shape
    r, k = a.shape
    bm, bk = _block(m, _BM), _block(k, _BK)
    return pl.pallas_call(
        functools.partial(_dx_kernel, scale=scale),
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bk), lambda i, j: (0, j)),
            pl.BlockSpec((r, bk), lambda i, j: (0, j)),
            pl.BlockSpec((n, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), dy.dtype),
        interpret=INTERPRET,
    )(dy, w, a, b, mask)


def _da_kernel(dy_ref, x_ref, b_ref, mask_ref, da_ref, *, scale):
    dp = jnp.dot(dy_ref[...], b_ref[...]) * mask_ref[...][None, :] * scale
    da_ref[...] = jnp.dot(dp.T, x_ref[...])                 # [R, bk]


def _da(dy, x, b, mask, scale):
    m, n = dy.shape
    _, k = x.shape
    r = b.shape[1]
    bk = _block(k, _BK)
    return pl.pallas_call(
        functools.partial(_da_kernel, scale=scale),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((m, n), lambda j: (0, 0)),
            pl.BlockSpec((m, bk), lambda j: (0, j)),
            pl.BlockSpec((n, r), lambda j: (0, 0)),
            pl.BlockSpec((r,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((r, bk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, k), dy.dtype),
        interpret=INTERPRET,
    )(dy, x, b, mask)


def _db_kernel(dy_ref, x_ref, a_ref, mask_ref, db_ref, *, scale):
    p = jnp.dot(x_ref[...], a_ref[...].T) * mask_ref[...][None, :]  # [M, R]
    db_ref[...] = jnp.dot(dy_ref[...].T, p) * scale                 # [bn, R]


def _db(dy, x, a, mask, scale):
    m, n = dy.shape
    r, k = a.shape
    bn = _block(n, _BN)
    return pl.pallas_call(
        functools.partial(_db_kernel, scale=scale),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((r, k), lambda j: (0, 0)),
            pl.BlockSpec((r,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, r), dy.dtype),
        interpret=INTERPRET,
    )(dy, x, a, mask)


# ---------------------------------------------------------------- public op


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def lora_linear(x, w, a, b, mask, scale):
    """Y = X @ W.T + ((X @ A.T) * mask) @ B.T * scale  (see module docstring)."""
    return _fwd(x, w, a, b, mask, scale)


def _vjp_fwd(x, w, a, b, mask, scale):
    return _fwd(x, w, a, b, mask, scale), (x, w, a, b, mask)


def _vjp_bwd(scale, res, dy):
    x, w, a, b, mask = res
    dx = _dx(dy, w, a, b, mask, scale)
    da = _da(dy, x, b, mask, scale)
    db = _db(dy, x, a, mask, scale)
    # W is frozen in Shears; mask is a configuration input. Symbolic zeros
    # keep the train-step HLO free of dead dense-gradient matmuls.
    return dx, jnp.zeros_like(w), da, db, jnp.zeros_like(mask)


lora_linear.defvjp(_vjp_fwd, _vjp_bwd)
