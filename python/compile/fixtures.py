"""Golden parity fixtures: L1 reference numerics -> JSON for rust/tests/parity.rs.

Usage:  cd python && python -m compile.fixtures --out-dir ../rust/tests/fixtures

Every case records its inputs and the reference outputs computed by the
same code the artifacts are lowered from (`kernels/ref.py`, `prune.py`,
`model.py`), so the native Rust backend can be asserted against the L1
ground truth with no Python at test time. Regenerate only when the
reference math changes; the files are checked in.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import prune as P
from .kernels import ref

SEED = 20240731


def t(arr):
    """Tensor -> JSON {shape, data} (f32 or i32)."""
    a = np.asarray(arr)
    if a.dtype.kind in "iu":
        data = [int(x) for x in a.reshape(-1)]
        dtype = "i32"
    else:
        data = [float(np.float32(x)) for x in a.reshape(-1)]
        dtype = "f32"
    return {"shape": list(a.shape), "dtype": dtype, "data": data}


def kernel_cases(rng):
    cases = {}

    # fused elastic-LoRA linear + its gradients (kernels/ref.py contract)
    x = rng.standard_normal((5, 7)).astype(np.float32)
    w = rng.standard_normal((6, 7)).astype(np.float32)
    a = rng.standard_normal((3, 7)).astype(np.float32)
    b = rng.standard_normal((6, 3)).astype(np.float32)
    mask = np.array([1.0, 1.0, 0.0], np.float32)
    scale = 2.5
    dy = rng.standard_normal((5, 6)).astype(np.float32)
    y = ref.lora_linear_ref(x, w, a, b, mask, scale)
    dx, da, db = ref.lora_linear_bwd_ref(x, w, a, b, mask, scale, dy)
    cases["lora_linear"] = {
        "inputs": {"x": t(x), "w": t(w), "a": t(a), "b": t(b), "mask": t(mask), "dy": t(dy)},
        "scalars": {"scale": scale},
        "outputs": {"y": t(y), "dx": t(dx), "da": t(da), "db": t(db)},
    }

    # rmsnorm forward + vjp
    xn = rng.standard_normal((4, 9)).astype(np.float32)
    g = (1.0 + 0.1 * rng.standard_normal(9)).astype(np.float32)
    dyn = rng.standard_normal((4, 9)).astype(np.float32)
    yn, vjp = jax.vjp(ref.rmsnorm_ref, jnp.array(xn), jnp.array(g))
    dxn, dgn = vjp(jnp.array(dyn))
    cases["rmsnorm"] = {
        "inputs": {"x": t(xn), "g": t(g), "dy": t(dyn)},
        "outputs": {"y": t(yn), "dx": t(dxn), "dg": t(dgn)},
    }

    # masked softmax cross-entropy + dlogits (model.lm_loss contract)
    logits = rng.standard_normal((2, 4, 11)).astype(np.float32)
    y_ids = rng.integers(0, 11, (2, 4)).astype(np.int32)
    lmask = np.array([[1, 0, 1, 1], [0, 1, 1, 0]], np.float32)
    loss, dlogits = jax.value_and_grad(
        lambda lg: M.lm_loss(lg, jnp.array(y_ids), jnp.array(lmask))
    )(jnp.array(logits))
    cases["softmax_xent"] = {
        "inputs": {"logits": t(logits), "y": t(y_ids), "loss_mask": t(lmask)},
        "outputs": {"loss": t(np.array([loss], np.float32)), "dlogits": t(dlogits)},
    }

    # one AdamW step (model.adamw_update contract), with and without decay
    p = rng.standard_normal(10).astype(np.float32)
    gr = rng.standard_normal(10).astype(np.float32)
    m0 = (0.1 * rng.standard_normal(10)).astype(np.float32)
    v0 = np.abs(0.1 * rng.standard_normal(10)).astype(np.float32)
    for name, wd in [("adamw", 0.01), ("adamw_nodecay", 0.0)]:
        np_, nm, nv = M.adamw_update(
            {"p": jnp.array(p)}, {"p": jnp.array(gr)}, {"p": jnp.array(m0)},
            {"p": jnp.array(v0)}, jnp.array(3.0), jnp.array(0.01), weight_decay=wd,
        )
        cases[name] = {
            "inputs": {"p": t(p), "g": t(gr), "m": t(m0), "v": t(v0)},
            "scalars": {"step": 3.0, "lr": 0.01, "weight_decay": wd},
            "outputs": {"p": t(np_["p"]), "m": t(nm["p"]), "v": t(nv["p"])},
        }

    # prune ops (prune.py contract): (w, stats..., keep_frac) -> (w_pruned, mask)
    w = rng.standard_normal((6, 10)).astype(np.float32)
    xsq = np.abs(rng.standard_normal(10)).astype(np.float32) + 0.1
    wp, mask = P.wanda_op(jnp.array(w), jnp.array(xsq), jnp.array(0.4))
    cases["wanda"] = {
        "inputs": {"w": t(w), "xnorm_sq": t(xsq)},
        "scalars": {"keep_frac": 0.4},
        "outputs": {"w_pruned": t(wp), "mask": t(mask)},
    }

    w = rng.standard_normal((5, 8)).astype(np.float32)
    wp, mask = P.magnitude_op(jnp.array(w), jnp.array(0.6))
    cases["magnitude"] = {
        "inputs": {"w": t(w)},
        "scalars": {"keep_frac": 0.6},
        "outputs": {"w_pruned": t(wp), "mask": t(mask)},
    }

    w = rng.standard_normal((6, 8)).astype(np.float32)
    xcal = rng.standard_normal((20, 8)).astype(np.float32)
    gram = xcal.T @ xcal
    wp, mask = P.sparsegpt_op(jnp.array(w), jnp.array(gram), jnp.array(0.5))
    cases["sparsegpt"] = {
        "inputs": {"w": t(w), "gram": t(gram)},
        "scalars": {"keep_frac": 0.5},
        "outputs": {"w_pruned": t(wp), "mask": t(mask)},
    }
    return cases


def tiny_cfg(arch):
    return dict(
        arch=arch, d_model=16, n_layers=2, n_heads=2, d_ff=24,
        vocab=32, seq_len=8, max_rank=4, rank_choices=[4, 3, 2],
        lora_alpha=8.0,
        targets=(["q", "k", "v", "up", "down"] if arch == "llama"
                 else ["q", "v", "o", "up"]),
        batch_train=2, batch_eval=2, prefix_len=3, bottleneck=5,
    )


def model_case(arch, rng):
    cfg = tiny_cfg(arch)
    params = {}
    for n, s in M.base_param_specs(cfg):
        if n.endswith(".g"):
            params[n] = (1.0 + 0.05 * rng.standard_normal(s)).astype(np.float32)
        elif n.endswith(".b"):
            params[n] = (0.02 * rng.standard_normal(s)).astype(np.float32)
        else:
            params[n] = (0.25 * rng.standard_normal(s)).astype(np.float32)
    adapters = {
        n: (0.2 * rng.standard_normal(s)).astype(np.float32)
        for n, s in M.adapter_param_specs(cfg)
    }
    mods = M.adapter_modules(cfg)
    rank_mask = np.zeros((len(mods), cfg["max_rank"]), np.float32)
    for i in range(len(mods)):
        rank_mask[i, : [4, 3, 2][i % 3]] = 1.0
    x = rng.integers(0, cfg["vocab"], (2, cfg["seq_len"])).astype(np.int32)
    y = rng.integers(0, cfg["vocab"], (2, cfg["seq_len"])).astype(np.int32)
    lmask = (rng.random((2, cfg["seq_len"])) > 0.4).astype(np.float32)

    jp = {k: jnp.array(v) for k, v in params.items()}
    jad = {k: jnp.array(v) for k, v in adapters.items()}

    logits_base = M.forward(cfg, jp, jnp.array(x))
    logits_ad = M.forward(cfg, jp, jnp.array(x), adapters=jad,
                          rank_mask=jnp.array(rank_mask))

    fw = M.Forward(cfg, jp, collect=True)
    fw(jnp.array(x))
    calib = {}
    for site, _dim in M.calib_sites(cfg):
        calib[f"sumsq.{site}"] = t(fw.stats[site][0])
        calib[f"gram.{site}"] = t(fw.stats[site][1])

    loss, grads = jax.value_and_grad(
        lambda adp: M.lm_loss(
            M.forward(cfg, jp, jnp.array(x), adapters=adp,
                      rank_mask=jnp.array(rank_mask)),
            jnp.array(y), jnp.array(lmask),
        )
    )(jad)

    # full-FT base gradients (GradMode::Base parity: embed scatter, norm
    # gains/biases, lm_head, every matmul)
    loss_full, grads_full = jax.value_and_grad(
        lambda bp: M.lm_loss(
            M.forward(cfg, bp, jnp.array(x)), jnp.array(y), jnp.array(lmask)
        )
    )(jp)

    case = {
        "config": {k: v for k, v in cfg.items()},
        "inputs": {
            **{n: t(v) for n, v in params.items()},
            **{n: t(v) for n, v in adapters.items()},
            "x": t(x), "y": t(y), "loss_mask": t(lmask),
            "rank_mask": t(rank_mask),
        },
        "outputs": {
            "logits_base": t(logits_base),
            "logits_adapters": t(logits_ad),
            "loss_nls": t(np.array([loss], np.float32)),
            "loss_full": t(np.array([loss_full], np.float32)),
            **calib,
            **{f"grad.{n}": t(g) for n, g in grads.items()},
            **{f"grad_base.{n}": t(g) for n, g in grads_full.items()},
        },
    }

    # PEFT baselines on the same base: forwards + their gradients
    # (llama only, keeps files small)
    if arch == "llama":
        for kind, specs_fn in [("prefix", M.prefix_param_specs),
                               ("series", M.series_param_specs),
                               ("parallel", M.parallel_param_specs)]:
            extra = {n: (0.15 * rng.standard_normal(s)).astype(np.float32)
                     for n, s in specs_fn(cfg)}
            jex = {k: jnp.array(v) for k, v in extra.items()}
            lg = M.forward(cfg, jp, jnp.array(x), **{kind: jex})
            loss_e, grads_e = jax.value_and_grad(
                lambda e, kind=kind: M.lm_loss(
                    M.forward(cfg, jp, jnp.array(x), **{kind: e}),
                    jnp.array(y), jnp.array(lmask),
                )
            )(jex)
            case["inputs"].update({n: t(v) for n, v in extra.items()})
            case["outputs"][f"logits_{kind}"] = t(lg)
            case["outputs"][f"loss_{kind}"] = t(np.array([loss_e], np.float32))
            case["outputs"].update(
                {f"grad_{kind}.{n}": t(g) for n, g in grads_e.items()}
            )
    return case


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../rust/tests/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    rng = np.random.default_rng(SEED)

    with open(os.path.join(args.out_dir, "kernels.json"), "w") as f:
        json.dump(kernel_cases(rng), f, separators=(",", ":"))
    for arch in ["llama", "mpt"]:
        with open(os.path.join(args.out_dir, f"model_{arch}.json"), "w") as f:
            json.dump(model_case(arch, rng), f, separators=(",", ":"))
    print(f"[fixtures] wrote kernels.json, model_llama.json, model_mpt.json -> {args.out_dir}")


if __name__ == "__main__":
    main()
