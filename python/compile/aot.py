"""AOT lowering: every (config × entry point) and prune op -> HLO text.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

HLO *text* (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (what the rust `xla`
crate links) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Also emits `manifest.json` — the single source of truth the rust side
reads for parameter ordering, entry-point signatures, and prune-op shapes.
Python runs exactly once; after this the rust binary is self-contained.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import prune as P
from . import train as T

# which entry points each config ships (all need pretraining = train_step_full)
_ALL = list(T.BUILDERS.keys())
ENTRY_SETS = {
    "tiny-llama": _ALL,
    "llama-sim-s": _ALL,
    "llama-sim-m": [e for e in _ALL if e != "forward_eval_pallas"],
    "mpt-sim": [e for e in _ALL if e != "forward_eval_pallas"],
}

PRUNE_KINDS = ["wanda", "magnitude", "sparsegpt"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    dt = {"float32": "f32", "int32": "i32"}[str(s.dtype)]
    return {"shape": list(s.shape), "dtype": dt}


def _lower(fn, specs):
    # keep_unused=True: the L3 side feeds inputs positionally from the
    # manifest; letting XLA drop e.g. lm_head from calib_stats would break
    # the ABI (and execute_b segfaults rather than erroring on mismatch).
    return jax.jit(fn, keep_unused=True).lower(*specs)


def _io_json(built, lowered):
    out_avals = lowered.out_info
    outs = [_spec_json(o) for o in jax.tree_util.tree_leaves(out_avals)]
    return {
        "inputs": [
            {"name": n, **_spec_json(s)}
            for n, s in zip(built["input_names"], built["specs"])
        ],
        "outputs": [
            {"name": n, **o} for n, o in zip(built["output_names"], outs)
        ],
    }


def _config_json(name, cfg):
    j = {k: v for k, v in cfg.items()}
    j["name"] = name
    j["base_params"] = [
        {"name": n, "shape": list(s)} for n, s in M.base_param_specs(cfg)
    ]
    j["adapter_params"] = [
        {"name": n, "shape": list(s)} for n, s in M.adapter_param_specs(cfg)
    ]
    j["prefix_params"] = [
        {"name": n, "shape": list(s)} for n, s in M.prefix_param_specs(cfg)
    ]
    j["series_params"] = [
        {"name": n, "shape": list(s)} for n, s in M.series_param_specs(cfg)
    ]
    j["parallel_params"] = [
        {"name": n, "shape": list(s)} for n, s in M.parallel_param_specs(cfg)
    ]
    j["adapter_modules"] = M.adapter_modules(cfg)
    j["prunable"] = [
        {"name": n, "shape": list(s), "site": site}
        for n, s, site in M.prunable_specs(cfg)
    ]
    j["sites"] = [{"site": s, "dim": d} for s, d in M.calib_sites(cfg)]
    j["entrypoints"] = {}
    return j


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(M.CONFIGS.keys()))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg_names = [c for c in args.configs.split(",") if c]

    manifest = {"version": 1, "configs": {}, "prune_ops": {}}
    shapes_seen = set()

    for cname in cfg_names:
        cfg = M.CONFIGS[cname]
        cj = _config_json(cname, cfg)
        for entry in ENTRY_SETS[cname]:
            built = T.BUILDERS[entry](cfg)
            lowered = _lower(built["fn"], built["specs"])
            text = to_hlo_text(lowered)
            fname = f"{cname}__{entry}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            cj["entrypoints"][entry] = {"file": fname, **_io_json(built, lowered)}
            print(f"[aot] {fname}  ({len(text) / 1e6:.2f} MB)", file=sys.stderr)
        manifest["configs"][cname] = cj
        for _, (n, k), _site in M.prunable_specs(cfg):
            shapes_seen.add((n, k))

    for (n, k) in sorted(shapes_seen):
        for kind in PRUNE_KINDS:
            built = P.build_prune_op(kind, n, k)
            lowered = _lower(built["fn"], built["specs"])
            text = to_hlo_text(lowered)
            fname = f"prune__{kind}_{n}x{k}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest["prune_ops"][f"{kind}_{n}x{k}"] = {
                "file": fname, "kind": kind, "shape": [n, k],
                **_io_json(built, lowered),
            }
            print(f"[aot] {fname}  ({len(text) / 1e6:.2f} MB)", file=sys.stderr)

    blob = json.dumps(manifest, indent=1, sort_keys=True)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        f.write(blob)
    digest = hashlib.sha256(blob.encode()).hexdigest()[:16]
    print(f"[aot] manifest.json  sha256:{digest}  "
          f"({len(manifest['configs'])} configs, "
          f"{len(manifest['prune_ops'])} prune ops)", file=sys.stderr)


if __name__ == "__main__":
    main()
