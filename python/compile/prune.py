"""Prune ops lowered per weight shape: Wanda, magnitude, SparseGPT-lite.

Each op is a standalone HLO artifact `(W, stats..., keep_frac) -> (W_pruned,
mask)` compiled once per distinct prunable shape — the rust pruning driver
streams every prunable weight of matching shape through it (paper §3.1:
pruning is a one-shot, training-free pass).

Wanda uses the L1 Pallas kernel (`kernels/wanda.py`). SparseGPT here is the
"lite" variant: per-row importance `w² / diag(H⁻¹)` decided up front, then
the OBS column-sequential error compensation sweep — the blockwise
re-scoring of the full SparseGPT is dropped (documented substitution,
DESIGN.md §3); the compensation math (Frantar & Alistarh 2023, Eq. 3/4)
is intact, which is what separates it from Wanda in Figure 2.
"""

import jax
import jax.numpy as jnp

from .kernels.ref import wanda_threshold_ref
from .kernels.wanda import wanda_apply

F32 = jnp.float32


def _sds(shape, dt=F32):
    return jax.ShapeDtypeStruct(shape, dt)


def _row_topk_mask(scores, keep_frac):
    """{0,1} mask keeping the top round(K*keep_frac) scores per row."""
    k = scores.shape[1]
    n_keep = jnp.clip(jnp.round(k * keep_frac).astype(jnp.int32), 1, k)
    sorted_desc = -jnp.sort(-scores, axis=1)
    idx = jnp.broadcast_to(n_keep - 1, (scores.shape[0],))[:, None]
    thresh = jnp.take_along_axis(sorted_desc, idx, axis=1)
    return (scores >= thresh).astype(scores.dtype)


def wanda_op(w, xnorm_sq, keep_frac):
    """Wanda (Eq. 1): S = |W| * ||X||₂ per row. xnorm_sq is the L3-accumulated
    Σx²; the sqrt happens here so accumulation stays a plain sum."""
    xnorm = jnp.sqrt(xnorm_sq)
    thresh = wanda_threshold_ref(w, xnorm, keep_frac)
    wp, mask = wanda_apply(w, xnorm, thresh)
    return wp, mask


def magnitude_op(w, keep_frac):
    """|W| thresholding per row — the classical baseline Wanda improves on."""
    mask = _row_topk_mask(jnp.abs(w), keep_frac)
    return w * mask, mask


def _chol_lower(a):
    """Cholesky factor L (a = L Lᵀ) in pure jnp ops.

    jnp.linalg.cholesky lowers to a LAPACK custom-call with
    API_VERSION_TYPED_FFI, which the xla_extension 0.5.1 runtime rejects
    — so the prune artifacts carry this O(K³) right-looking loop instead
    (K ≤ 512 at repo scale).
    """
    k = a.shape[0]
    idx = jnp.arange(k)

    def body(j, a):
        d = jnp.sqrt(jnp.maximum(a[j, j], 1e-20))
        col = a[:, j] / d
        col = jnp.where(idx > j, col, 0.0).at[j].set(d)
        below = jnp.where(idx > j, 1.0, 0.0)
        a = a - jnp.outer(col * below, col * below)
        return a.at[:, j].set(col)

    a = jax.lax.fori_loop(0, k, body, a)
    return jnp.tril(a)


def _tril_inv(l):
    """Inverse of a lower-triangular matrix by forward substitution."""
    k = l.shape[0]
    idx = jnp.arange(k)
    eye = jnp.eye(k, dtype=l.dtype)

    def body(i, x):
        mask = jnp.where(idx < i, 1.0, 0.0)
        acc = (l[i] * mask) @ x              # combination of earlier rows
        xi = (eye[i] - acc) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(l))


def sparsegpt_op(w, gram, keep_frac, damp=0.01):
    """SparseGPT-lite: OBS error compensation with up-front mask selection.

    Follows the reference implementation's column sweep: with
    U = upper-Cholesky factor of H⁻¹ (H⁻¹ = UᵀU), pruning w[:, j] injects
    err = w[:, j] / U[j, j] and compensates the *later* columns with row
    U[j, j:] (upper-triangularity restricts the update to unprocessed
    columns automatically). Importance is w² / diag(U)².

    Linear algebra is hand-rolled jnp (`_chol_lower`, `_tril_inv`): no
    LAPACK custom-calls survive into the artifact.
    """
    k = w.shape[1]
    h = gram + damp * (jnp.trace(gram) / k + 1e-6) * jnp.eye(k, dtype=w.dtype)
    linv = _tril_inv(_chol_lower(h))         # H⁻¹ = Linvᵀ Linv
    hinv = linv.T @ linv
    u = _chol_lower(hinv).T                  # upper: hinv = uᵀu
    d = jnp.clip(jnp.diag(u), 1e-10, None)
    mask = _row_topk_mask(w * w / (d * d)[None, :], keep_frac)

    def body(j, w):
        e = jnp.where(mask[:, j] > 0, 0.0, w[:, j]) / u[j, j]   # [N]
        return w - e[:, None] * u[j][None, :]  # u[j, :j] == 0 (upper)

    w = jax.lax.fori_loop(0, k, body, w)
    return w * mask, mask


def build_prune_op(kind, n, k):
    """Return dict(fn, specs, input_names, output_names) for shape [n, k]."""
    if kind == "wanda":
        fn = lambda w, s, f: wanda_op(w, s, f)
        specs = [_sds((n, k)), _sds((k,)), _sds(())]
        inputs = ["w", "xnorm_sq", "keep_frac"]
    elif kind == "magnitude":
        fn = lambda w, f: magnitude_op(w, f)
        specs = [_sds((n, k)), _sds(())]
        inputs = ["w", "keep_frac"]
    elif kind == "sparsegpt":
        fn = lambda w, g, f: sparsegpt_op(w, g, f)
        specs = [_sds((n, k)), _sds((k, k)), _sds(())]
        inputs = ["w", "gram", "keep_frac"]
    else:
        raise ValueError(kind)
    return dict(fn=fn, specs=specs, input_names=inputs,
                output_names=["w_pruned", "mask"])
