"""Layer-2 JAX model for Shears: llama-sim / mpt-sim decoder LMs with
elastic LoRA adapters, PEFT baselines, losses and forward variants.

Everything here is *build-time only*: `aot.py` lowers the entry points in
`train.py`/`prune.py` (which call into this module) to HLO text, and the
Rust coordinator executes those artifacts. No Python on the request path.

Model conventions
-----------------
* weights are `[out, in]` so each linear is `y = x @ W.T` — the same
  convention as the L1 kernels (`kernels/ref.py`).
* `params` is a flat `dict[str, Array]`; the canonical *ordering* of every
  parameter group is defined by the `*_param_specs()` functions and exported
  verbatim to `artifacts/manifest.json`. The Rust `ParamStore` mirrors that
  order — it is the ABI between L3 and L2.
* elastic LoRA: each adapter target holds a super-adapter `(A [R, in],
  B [out, R])`; a `rank_mask [n_adapters, R]` input activates a sub-adapter
  (prefix-slice weight sharing, paper §3.2). `scale = lora_alpha / R`.
* `use_pallas=True` routes adapter matmuls/norms through the L1 Pallas
  kernels; `False` uses the element-identical jnp reference math
  (see DESIGN.md §4 for why both are lowered).
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import lora_linear, rmsnorm
from .kernels.ref import lora_linear_ref, rmsnorm_ref

# --------------------------------------------------------------------------
# configurations (mirrors DESIGN.md §8; paper hyperparams Tables 7-9 scaled)
# --------------------------------------------------------------------------

LLAMA_TARGETS = ["q", "k", "v", "up", "gate", "down"]  # Table 7 (40% row)
MPT_TARGETS = ["q", "k", "v", "o", "up", "down"]       # Table 9

CONFIGS = {
    # tests / CI
    "tiny-llama": dict(
        arch="llama", d_model=48, n_layers=2, n_heads=4, d_ff=128,
        vocab=256, seq_len=48, max_rank=8, rank_choices=[8, 6, 4],
        lora_alpha=16.0, targets=["q", "k", "v", "up", "down"],
        batch_train=8, batch_eval=16, prefix_len=4, bottleneck=8,
    ),
    # LLaMA-7B stand-in (paper Table 1 upper block)
    "llama-sim-s": dict(
        arch="llama", d_model=128, n_layers=4, n_heads=8, d_ff=344,
        vocab=512, seq_len=64, max_rank=8, rank_choices=[8, 6, 4],
        lora_alpha=16.0, targets=LLAMA_TARGETS,
        batch_train=16, batch_eval=32, prefix_len=8, bottleneck=16,
    ),
    # LLaMA-13B stand-in (paper Table 1 lower block)
    "llama-sim-m": dict(
        arch="llama", d_model=192, n_layers=6, n_heads=8, d_ff=512,
        vocab=512, seq_len=64, max_rank=8, rank_choices=[8, 6, 4],
        lora_alpha=16.0, targets=["q", "k", "v", "up", "down"],
        batch_train=16, batch_eval=32, prefix_len=8, bottleneck=16,
    ),
    # MPT-7B stand-in (paper §4.3, Tables 5/9, Figure 2)
    "mpt-sim": dict(
        arch="mpt", d_model=128, n_layers=4, n_heads=8, d_ff=512,
        vocab=512, seq_len=64, max_rank=8, rank_choices=[8, 6, 4],
        lora_alpha=16.0, targets=MPT_TARGETS,
        batch_train=16, batch_eval=32, prefix_len=8, bottleneck=16,
    ),
}

# adapter/prunable geometry per target name
def _target_shape(cfg, t):
    d, f = cfg["d_model"], cfg["d_ff"]
    return {
        "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
        "gate": (f, d), "up": (f, d), "down": (d, f),
    }[t]


# --------------------------------------------------------------------------
# parameter specs — the L2<->L3 ABI
# --------------------------------------------------------------------------

def base_param_specs(cfg):
    """Ordered [(name, shape)] for the frozen/pretrained base model."""
    d, f, v = cfg["d_model"], cfg["d_ff"], cfg["vocab"]
    llama = cfg["arch"] == "llama"
    specs = [("embed", (v, d))]
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        specs.append((p + "attn_norm.g", (d,)))
        if not llama:
            specs.append((p + "attn_norm.b", (d,)))
        specs += [(p + "attn.q", (d, d)), (p + "attn.k", (d, d)),
                  (p + "attn.v", (d, d)), (p + "attn.o", (d, d))]
        specs.append((p + "mlp_norm.g", (d,)))
        if not llama:
            specs.append((p + "mlp_norm.b", (d,)))
        if llama:
            specs.append((p + "mlp.gate", (f, d)))
        specs += [(p + "mlp.up", (f, d)), (p + "mlp.down", (d, f))]
    specs.append(("final_norm.g", (d,)))
    if not llama:
        specs.append(("final_norm.b", (d,)))
    specs.append(("lm_head", (v, d)))
    return specs


def adapter_modules(cfg):
    """Ordered adapter module names; row order of the rank_mask input."""
    mods = []
    for i in range(cfg["n_layers"]):
        for t in cfg["targets"]:
            sect = "attn" if t in ("q", "k", "v", "o") else "mlp"
            mods.append(f"layers.{i}.{sect}.{t}")
    return mods


def adapter_param_specs(cfg):
    """Ordered [(name, shape)]: lora_a.<mod> [R, in] then lora_b.<mod> [out, R],
    module-major (both halves of one adapter are adjacent)."""
    r = cfg["max_rank"]
    specs = []
    for i in range(cfg["n_layers"]):
        for t in cfg["targets"]:
            sect = "attn" if t in ("q", "k", "v", "o") else "mlp"
            mod = f"layers.{i}.{sect}.{t}"
            out, inp = _target_shape(cfg, t)
            specs.append((f"lora_a.{mod}", (r, inp)))
            specs.append((f"lora_b.{mod}", (out, r)))
    return specs


def prefix_param_specs(cfg):
    """Prefix-tuning baseline (Li & Liang 2021): learnable per-layer KV."""
    h, p = cfg["n_heads"], cfg["prefix_len"]
    dh = cfg["d_model"] // h
    specs = []
    for i in range(cfg["n_layers"]):
        specs.append((f"prefix_k.{i}", (h, p, dh)))
        specs.append((f"prefix_v.{i}", (h, p, dh)))
    return specs


def series_param_specs(cfg):
    """Series adapter baseline (Houlsby 2019): bottleneck after each MLP."""
    d, bn = cfg["d_model"], cfg["bottleneck"]
    specs = []
    for i in range(cfg["n_layers"]):
        specs.append((f"series_down.{i}", (bn, d)))
        specs.append((f"series_up.{i}", (d, bn)))
    return specs


def parallel_param_specs(cfg):
    """Parallel adapter baseline (Pfeiffer 2020): bottleneck beside each MLP."""
    d, bn = cfg["d_model"], cfg["bottleneck"]
    specs = []
    for i in range(cfg["n_layers"]):
        specs.append((f"parallel_down.{i}", (bn, d)))
        specs.append((f"parallel_up.{i}", (d, bn)))
    return specs


def prunable_specs(cfg):
    """Ordered [(name, shape, site)] of base weights Shears sparsifies.

    `site` identifies the activation-statistics vector the weight's Wanda /
    SparseGPT score needs (weights sharing an input share a site).
    """
    specs = []
    llama = cfg["arch"] == "llama"
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        specs += [
            (p + "attn.q", _target_shape(cfg, "q"), f"{i}.attn_in"),
            (p + "attn.k", _target_shape(cfg, "k"), f"{i}.attn_in"),
            (p + "attn.v", _target_shape(cfg, "v"), f"{i}.attn_in"),
            (p + "attn.o", _target_shape(cfg, "o"), f"{i}.o_in"),
        ]
        if llama:
            specs.append((p + "mlp.gate", _target_shape(cfg, "gate"), f"{i}.mlp_in"))
        specs += [
            (p + "mlp.up", _target_shape(cfg, "up"), f"{i}.mlp_in"),
            (p + "mlp.down", _target_shape(cfg, "down"), f"{i}.down_in"),
        ]
    return specs


def calib_sites(cfg):
    """Ordered unique stats sites with their feature dims."""
    d, f = cfg["d_model"], cfg["d_ff"]
    sites = []
    for i in range(cfg["n_layers"]):
        sites += [(f"{i}.attn_in", d), (f"{i}.o_in", d),
                  (f"{i}.mlp_in", d), (f"{i}.down_in", f)]
    return sites


# --------------------------------------------------------------------------
# forward pass
# --------------------------------------------------------------------------

def _rope(q, k):
    """Rotary position embedding over [B, H, S, dh] (llama-sim)."""
    b, h, s, dh = q.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate(
            [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
        )

    return rot(q), rot(k)


def _alibi_slopes(h):
    """MPT-style ALiBi head slopes: 2^(-8i/h)."""
    return jnp.array([2.0 ** (-8.0 * (i + 1) / h) for i in range(h)], jnp.float32)


def _norm(x2d, params, name, llama, use_pallas):
    if llama:
        fn = rmsnorm if use_pallas else rmsnorm_ref
        return fn(x2d, params[name + ".g"])
    # mpt: LayerNorm
    mu = jnp.mean(x2d, axis=-1, keepdims=True)
    var = jnp.var(x2d, axis=-1, keepdims=True)
    return (x2d - mu) * jax.lax.rsqrt(var + 1e-5) * params[name + ".g"][None, :] + params[name + ".b"][None, :]


class Forward:
    """One forward construction: holds config, params, adapter state.

    Collects Wanda/SparseGPT calibration statistics when `collect=True`
    (Σx² per site and the Gram matrix H = XᵀX, accumulated over tokens).
    """

    def __init__(self, cfg, params, adapters=None, rank_mask=None,
                 prefix=None, series=None, parallel=None,
                 use_pallas=False, collect=False):
        self.cfg = cfg
        self.p = params
        self.adapters = adapters
        self.rank_mask = rank_mask
        self.prefix = prefix
        self.series = series
        self.parallel = parallel
        self.use_pallas = use_pallas
        self.collect = collect
        self.stats = {}
        self.scale = cfg["lora_alpha"] / cfg["max_rank"]
        self.mods = adapter_modules(cfg) if adapters is not None else []

    def _record(self, site, x2d):
        if self.collect:
            self.stats[site] = (
                jnp.sum(x2d * x2d, axis=0),      # Σx² per feature (Wanda)
                x2d.T @ x2d,                      # Gram H (SparseGPT)
            )

    def _lin(self, x2d, wname, mod):
        """Adapter-aware linear: base matmul + elastic LoRA if mod is a target."""
        w = self.p[wname]
        if self.adapters is not None and mod in self.mods:
            idx = self.mods.index(mod)
            a = self.adapters[f"lora_a.{mod}"]
            b = self.adapters[f"lora_b.{mod}"]
            mask = self.rank_mask[idx]
            fn = lora_linear if self.use_pallas else lora_linear_ref
            return fn(x2d, w, a, b, mask, self.scale)
        return x2d @ w.T

    def _attn(self, h, i, bsz, seq):
        cfg, llama = self.cfg, self.cfg["arch"] == "llama"
        d, nh = cfg["d_model"], cfg["n_heads"]
        dh = d // nh
        t = _norm(h, self.p, f"layers.{i}.attn_norm", llama, self.use_pallas)
        self._record(f"{i}.attn_in", t)
        pre = f"layers.{i}.attn."
        q = self._lin(t, pre + "q", pre[:-1] + ".q")
        k = self._lin(t, pre + "k", pre[:-1] + ".k")
        v = self._lin(t, pre + "v", pre[:-1] + ".v")

        def split(x):
            return x.reshape(bsz, seq, nh, dh).transpose(0, 2, 1, 3)

        q, k, v = split(q), split(k), split(v)
        if llama:
            q, k = _rope(q, k)

        if self.prefix is not None:
            pk = jnp.broadcast_to(self.prefix[f"prefix_k.{i}"], (bsz, nh, cfg["prefix_len"], dh))
            pv = jnp.broadcast_to(self.prefix[f"prefix_v.{i}"], (bsz, nh, cfg["prefix_len"], dh))
            k = jnp.concatenate([pk, k], axis=2)
            v = jnp.concatenate([pv, v], axis=2)

        plen = k.shape[2] - seq
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
        if not llama:  # mpt: ALiBi bias
            slopes = _alibi_slopes(nh)
            pos_k = jnp.arange(-plen, seq, dtype=jnp.float32)
            pos_q = jnp.arange(seq, dtype=jnp.float32)
            bias = -jnp.abs(pos_k[None, :] - pos_q[:, None])  # [S, S+P]
            scores = scores + slopes[None, :, None, None] * bias[None, None]
        causal = pos_mask = jnp.tril(jnp.ones((seq, seq), bool))
        if plen:
            pos_mask = jnp.concatenate([jnp.ones((seq, plen), bool), causal], axis=1)
        scores = jnp.where(pos_mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz * seq, d)
        self._record(f"{i}.o_in", ctx)
        return self._lin(ctx, pre + "o", pre[:-1] + ".o")

    def _mlp(self, h, i):
        cfg, llama = self.cfg, self.cfg["arch"] == "llama"
        t = _norm(h, self.p, f"layers.{i}.mlp_norm", llama, self.use_pallas)
        self._record(f"{i}.mlp_in", t)
        pre = f"layers.{i}.mlp."
        if llama:
            g = self._lin(t, pre + "gate", pre[:-1] + ".gate")
            u = self._lin(t, pre + "up", pre[:-1] + ".up")
            act = jax.nn.silu(g) * u
        else:
            act = jax.nn.gelu(self._lin(t, pre + "up", pre[:-1] + ".up"))
        self._record(f"{i}.down_in", act)
        out = self._lin(act, pre + "down", pre[:-1] + ".down")
        if self.series is not None:  # series adapter: after the MLP output
            z = jax.nn.relu(out @ self.series[f"series_down.{i}"].T)
            out = out + z @ self.series[f"series_up.{i}"].T
        if self.parallel is not None:  # parallel adapter: beside the MLP
            z = jax.nn.relu(t @ self.parallel[f"parallel_down.{i}"].T)
            out = out + z @ self.parallel[f"parallel_up.{i}"].T
        return out

    def __call__(self, x_ids):
        cfg = self.cfg
        bsz, seq = x_ids.shape
        h = self.p["embed"][x_ids].reshape(bsz * seq, cfg["d_model"])
        for i in range(cfg["n_layers"]):
            h = h + self._attn(h, i, bsz, seq)
            h = h + self._mlp(h, i)
        h = _norm(h, self.p, "final_norm", cfg["arch"] == "llama", self.use_pallas)
        logits = h @ self.p["lm_head"].T
        return logits.reshape(bsz, seq, cfg["vocab"])


def forward(cfg, params, x_ids, **kw):
    return Forward(cfg, params, **kw)(x_ids)


def lm_loss(logits, y_ids, loss_mask):
    """Masked next-token cross entropy. `y_ids` is already shifted by L3."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y_ids[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)


# --------------------------------------------------------------------------
# AdamW (optimizer state is part of the L2<->L3 ABI)
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adamw_update(params, grads, m, v, step, lr, weight_decay=0.0):
    """One AdamW step over aligned dicts; returns (params, m, v)."""
    b1t = 1.0 - ADAM_B1 ** step
    b2t = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        nm = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
        nv = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
        upd = (nm / b1t) / (jnp.sqrt(nv / b2t) + ADAM_EPS)
        new_p[k] = params[k] - lr * (upd + weight_decay * params[k])
        new_m[k], new_v[k] = nm, nv
    return new_p, new_m, new_v
