"""Entry-point builders: the compute graphs `aot.py` lowers to HLO.

Each builder returns a dict:
    fn            — pure function over flat positional arrays
    specs         — jax.ShapeDtypeStruct example args (lowering shapes)
    input_names   — canonical input order (the L3 ABI, see manifest.json)
    output_names  — canonical output order

Parameter-group orderings come from model.*_param_specs(); scalars are f32
rank-0; token batches are i32 [B, S].

Why whole-step graphs: loss, gradients (adapter-only via stop-slicing the
argument list) and the AdamW update are fused into ONE executable per
method, so the rust hot loop is a single `execute` per training step with
no intermediate host round-trips (DESIGN.md §9 L2 target).
"""

import jax
import jax.numpy as jnp

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dt=F32):
    return jax.ShapeDtypeStruct(shape, dt)


def _batch_specs(cfg, train=True):
    b = cfg["batch_train"] if train else cfg["batch_eval"]
    s = cfg["seq_len"]
    return [_sds((b, s), I32), _sds((b, s), I32), _sds((b, s), F32)]


def _names(specs):
    return [n for n, _ in specs]


def _to_dict(names, vals):
    return dict(zip(names, vals))


# --------------------------------------------------------------------- NLS


def build_train_step_nls(cfg):
    """Shears super-adapter training step (paper §3.2).

    The rank_mask input is the NLS sampler's knob: L3 draws a sub-adapter
    configuration per step and materializes it as a {0,1} mask, giving
    weight-sharing NAS over one compiled executable.
    """
    base = M.base_param_specs(cfg)
    adpt = M.adapter_param_specs(cfg)
    nb, na = len(base), len(adpt)
    n_mods = len(M.adapter_modules(cfg))
    r = cfg["max_rank"]

    def fn(*args):
        i = 0
        basep = _to_dict(_names(base), args[i:i + nb]); i += nb
        adp = _to_dict(_names(adpt), args[i:i + na]); i += na
        m = _to_dict(_names(adpt), args[i:i + na]); i += na
        v = _to_dict(_names(adpt), args[i:i + na]); i += na
        step, lr, x, y, lmask, rmask = args[i:i + 6]

        def loss_fn(adp):
            logits = M.forward(cfg, basep, x, adapters=adp, rank_mask=rmask)
            return M.lm_loss(logits, y, lmask)

        loss, grads = jax.value_and_grad(loss_fn)(adp)
        adp, m, v = M.adamw_update(adp, grads, m, v, step, lr)
        outs = [adp[k] for k, _ in adpt] + [m[k] for k, _ in adpt] \
            + [v[k] for k, _ in adpt] + [loss]
        return tuple(outs)

    specs = [_sds(s) for _, s in base] + [_sds(s) for _, s in adpt] * 3 \
        + [_sds(()), _sds(())] + _batch_specs(cfg) + [_sds((n_mods, r))]
    input_names = _names(base) + _names(adpt) \
        + ["m." + n for n in _names(adpt)] + ["v." + n for n in _names(adpt)] \
        + ["step", "lr", "x", "y", "loss_mask", "rank_mask"]
    output_names = _names(adpt) + ["m." + n for n in _names(adpt)] \
        + ["v." + n for n in _names(adpt)] + ["loss"]
    return dict(fn=fn, specs=specs, input_names=input_names,
                output_names=output_names)


# ---------------------------------------------------------------- full FT


def build_train_step_full(cfg):
    """Full fine-tuning step (SparseFT baseline, paper §4.3; also used for
    in-repo pretraining with all-ones masks).

    Sparsity masks for every prunable weight are re-applied after the AdamW
    update so unstructured sparsity survives full fine-tuning — the same
    protocol Kurtic et al. (2023) keep via sparse optimizers.
    """
    base = M.base_param_specs(cfg)
    prun = M.prunable_specs(cfg)
    nb, np_ = len(base), len(prun)

    def fn(*args):
        i = 0
        basep = _to_dict(_names(base), args[i:i + nb]); i += nb
        m = _to_dict(_names(base), args[i:i + nb]); i += nb
        v = _to_dict(_names(base), args[i:i + nb]); i += nb
        masks = {prun[j][0]: args[i + j] for j in range(np_)}; i += np_
        step, lr, x, y, lmask = args[i:i + 5]

        def loss_fn(p):
            return M.lm_loss(M.forward(cfg, p, x), y, lmask)

        loss, grads = jax.value_and_grad(loss_fn)(basep)
        basep, m, v = M.adamw_update(basep, grads, m, v, step, lr,
                                     weight_decay=0.01)
        for name in masks:  # keep pruned weights at exactly zero
            basep[name] = basep[name] * masks[name]
            m[name] = m[name] * masks[name]
            v[name] = v[name] * masks[name]
        outs = [basep[k] for k, _ in base] + [m[k] for k, _ in base] \
            + [v[k] for k, _ in base] + [loss]
        return tuple(outs)

    specs = [_sds(s) for _, s in base] * 3 \
        + [_sds(s) for _, s, _ in prun] \
        + [_sds(()), _sds(())] + _batch_specs(cfg)
    input_names = _names(base) + ["m." + n for n in _names(base)] \
        + ["v." + n for n in _names(base)] \
        + ["mask." + n for n, _, _ in prun] \
        + ["step", "lr", "x", "y", "loss_mask"]
    output_names = _names(base) + ["m." + n for n in _names(base)] \
        + ["v." + n for n in _names(base)] + ["loss"]
    return dict(fn=fn, specs=specs, input_names=input_names,
                output_names=output_names)


# ------------------------------------------------------- PEFT baselines


def _build_train_step_extra(cfg, extra_specs, fwd_kw):
    """Shared shape for prefix/series/parallel baseline train steps."""
    base = M.base_param_specs(cfg)
    nb, ne = len(base), len(extra_specs)

    def fn(*args):
        i = 0
        basep = _to_dict(_names(base), args[i:i + nb]); i += nb
        ext = _to_dict(_names(extra_specs), args[i:i + ne]); i += ne
        m = _to_dict(_names(extra_specs), args[i:i + ne]); i += ne
        v = _to_dict(_names(extra_specs), args[i:i + ne]); i += ne
        step, lr, x, y, lmask = args[i:i + 5]

        def loss_fn(ext):
            logits = M.forward(cfg, basep, x, **{fwd_kw: ext})
            return M.lm_loss(logits, y, lmask)

        loss, grads = jax.value_and_grad(loss_fn)(ext)
        ext, m, v = M.adamw_update(ext, grads, m, v, step, lr)
        outs = [ext[k] for k, _ in extra_specs] + [m[k] for k, _ in extra_specs] \
            + [v[k] for k, _ in extra_specs] + [loss]
        return tuple(outs)

    specs = [_sds(s) for _, s in base] + [_sds(s) for _, s in extra_specs] * 3 \
        + [_sds(()), _sds(())] + _batch_specs(cfg)
    input_names = _names(base) + _names(extra_specs) \
        + ["m." + n for n in _names(extra_specs)] \
        + ["v." + n for n in _names(extra_specs)] \
        + ["step", "lr", "x", "y", "loss_mask"]
    output_names = _names(extra_specs) + ["m." + n for n in _names(extra_specs)] \
        + ["v." + n for n in _names(extra_specs)] + ["loss"]
    return dict(fn=fn, specs=specs, input_names=input_names,
                output_names=output_names)


def build_train_step_prefix(cfg):
    return _build_train_step_extra(cfg, M.prefix_param_specs(cfg), "prefix")


def build_train_step_series(cfg):
    return _build_train_step_extra(cfg, M.series_param_specs(cfg), "series")


def build_train_step_parallel(cfg):
    return _build_train_step_extra(cfg, M.parallel_param_specs(cfg), "parallel")


# -------------------------------------------------------------- forwards


def build_forward_eval(cfg, use_pallas=False):
    """Adapter-aware eval forward; rank_mask selects the sub-adapter."""
    base = M.base_param_specs(cfg)
    adpt = M.adapter_param_specs(cfg)
    nb, na = len(base), len(adpt)
    n_mods = len(M.adapter_modules(cfg))
    r = cfg["max_rank"]
    b, s = cfg["batch_eval"], cfg["seq_len"]

    def fn(*args):
        basep = _to_dict(_names(base), args[:nb])
        adp = _to_dict(_names(adpt), args[nb:nb + na])
        x, rmask = args[nb + na:]
        logits = M.forward(cfg, basep, x, adapters=adp, rank_mask=rmask,
                           use_pallas=use_pallas)
        return (logits,)

    specs = [_sds(s_) for _, s_ in base] + [_sds(s_) for _, s_ in adpt] \
        + [_sds((b, s), I32), _sds((n_mods, r))]
    input_names = _names(base) + _names(adpt) + ["x", "rank_mask"]
    return dict(fn=fn, specs=specs, input_names=input_names,
                output_names=["logits"])


def build_forward_eval_base(cfg):
    """Base-model eval (w/o-tune ablation rows; also the pruned-w/o-tune rows)."""
    base = M.base_param_specs(cfg)
    b, s = cfg["batch_eval"], cfg["seq_len"]

    def fn(*args):
        basep = _to_dict(_names(base), args[:-1])
        return (M.forward(cfg, basep, args[-1]),)

    specs = [_sds(s_) for _, s_ in base] + [_sds((b, s), I32)]
    return dict(fn=fn, specs=specs,
                input_names=_names(base) + ["x"], output_names=["logits"])


def _build_forward_eval_extra(cfg, extra_specs, fwd_kw):
    base = M.base_param_specs(cfg)
    nb, ne = len(base), len(extra_specs)
    b, s = cfg["batch_eval"], cfg["seq_len"]

    def fn(*args):
        basep = _to_dict(_names(base), args[:nb])
        ext = _to_dict(_names(extra_specs), args[nb:nb + ne])
        return (M.forward(cfg, basep, args[-1], **{fwd_kw: ext}),)

    specs = [_sds(s_) for _, s_ in base] + [_sds(s_) for _, s_ in extra_specs] \
        + [_sds((b, s), I32)]
    return dict(fn=fn, specs=specs,
                input_names=_names(base) + _names(extra_specs) + ["x"],
                output_names=["logits"])


def build_forward_eval_prefix(cfg):
    return _build_forward_eval_extra(cfg, M.prefix_param_specs(cfg), "prefix")


def build_forward_eval_series(cfg):
    return _build_forward_eval_extra(cfg, M.series_param_specs(cfg), "series")


def build_forward_eval_parallel(cfg):
    return _build_forward_eval_extra(cfg, M.parallel_param_specs(cfg), "parallel")


# ------------------------------------------------------------ calibration


def build_calib_stats(cfg):
    """Wanda/SparseGPT calibration forward (paper §3.1).

    One batch in, per-site (Σx², H=XᵀX) out; L3 accumulates over the
    calibration set and feeds the results to the prune ops.
    """
    base = M.base_param_specs(cfg)
    sites = M.calib_sites(cfg)
    b, s = cfg["batch_eval"], cfg["seq_len"]

    def fn(*args):
        basep = _to_dict(_names(base), args[:-1])
        fw = M.Forward(cfg, basep, collect=True)
        fw(args[-1])
        outs = []
        for site, _ in sites:
            sumsq, h = fw.stats[site]
            outs += [sumsq, h]
        return tuple(outs)

    specs = [_sds(s_) for _, s_ in base] + [_sds((b, s), I32)]
    output_names = []
    for site, _ in sites:
        output_names += [f"sumsq.{site}", f"gram.{site}"]
    return dict(fn=fn, specs=specs,
                input_names=_names(base) + ["x"], output_names=output_names)


# ----------------------------------------------------------------- registry

BUILDERS = {
    "train_step_nls": build_train_step_nls,
    "train_step_full": build_train_step_full,
    "train_step_prefix": build_train_step_prefix,
    "train_step_series": build_train_step_series,
    "train_step_parallel": build_train_step_parallel,
    "forward_eval": build_forward_eval,
    "forward_eval_pallas": lambda cfg: build_forward_eval(cfg, use_pallas=True),
    "forward_eval_base": build_forward_eval_base,
    "forward_eval_prefix": build_forward_eval_prefix,
    "forward_eval_series": build_forward_eval_series,
    "forward_eval_parallel": build_forward_eval_parallel,
    "calib_stats": build_calib_stats,
}
