//! Training and evaluation drivers over runtime entry points
//! (backend-agnostic: PJRT artifacts or the native CPU executor).
//!
//! Everything is *manifest-driven*: inputs are assembled by name from the
//! entry point's recorded signature, so one driver serves all five train
//! steps (NLS, full-FT, prefix, series, parallel) and every forward
//! variant. The hot loop is one `Runtime::run_args` per step — loss,
//! gradients and AdamW are fused inside the entry point on both backends
//! (DESIGN.md §6).
//!
//! [`TrainSession`] implements the §Perf buffer-residency lever: inputs
//! that never change across steps (the frozen, sparsified base weights —
//! the bulk of the model) ride a [`ResidentParams`] store synced by
//! `ParamStore` generation, so the backend keeps their prepared
//! CSR/CSC structure across steps and [`TrainSession::sync`] refreshes
//! exactly the weights a prune/edit touched; only the small trainable
//! tensors round-trip per step.

use crate::data::batch::{Batch, Batcher, MaskMode};
use crate::data::{Example, Vocab};
use crate::fault::FaultPlan;
use crate::model::{EntryPoint, ModelConfig, ParamStore};
use crate::nls::SearchSpace;
use crate::ops::model::{AdapterBinding, NamedTensors};
use crate::runtime::{Arg, DecodeSession, DecodeState, Exe, ResidentParams, Runtime};
use crate::tensor::HostTensor;
use crate::util::durable;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::io::Read;
use std::path::PathBuf;

/// Cosine learning-rate schedule with linear warmup.
pub fn lr_at(step: usize, total: usize, peak: f64, warmup: usize) -> f64 {
    if step < warmup {
        return peak * (step + 1) as f64 / warmup.max(1) as f64;
    }
    let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
    peak * 0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos())
}

/// Training options (defaults mirror paper Tables 7–9 at repo scale).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
    /// sample a random sub-adapter per step (NLS); if false and the entry
    /// takes a rank mask, the full mask is used (== vanilla LoRA)
    pub sample_nls: bool,
    pub log_every: usize,
    /// take a last-good checkpoint every N steps (0 = guards off: a
    /// non-finite loss aborts immediately, exactly the legacy behavior)
    pub checkpoint_every: usize,
    /// when set, periodic checkpoints are also persisted here (atomic,
    /// checksummed) so an interrupted run can `resume`
    pub checkpoint_path: Option<PathBuf>,
    /// restore step / weights / RNG / dataset cursor from
    /// `checkpoint_path` if it exists, then continue to `steps`
    pub resume: bool,
    /// how many divergence rollbacks to tolerate before aborting
    pub rollback_budget: usize,
    /// treat `loss > spike_factor × mean(last 8 losses)` as divergence
    /// (0.0 = only non-finite losses count)
    pub spike_factor: f64,
    /// deterministic fault injections scoped to training (`nanloss`);
    /// when empty, `SHEARS_FAULT` is consulted
    pub fault: FaultPlan,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 300,
            lr: 3e-3,
            warmup: 20,
            seed: 42,
            sample_nls: true,
            log_every: 50,
            checkpoint_every: 0,
            checkpoint_path: None,
            resume: false,
            rollback_budget: 3,
            spike_factor: 0.0,
            fault: FaultPlan::none(),
        }
    }
}

/// Loss trace returned by the trainers.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    /// learning rate applied at each recorded step (resume pins: a
    /// resumed run's sequence must equal the uninterrupted run's)
    pub lrs: Vec<f32>,
    pub steps: usize,
    pub wall_secs: f64,
    /// divergence rollbacks taken (0 when guards never fired)
    pub rollbacks: usize,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn mean_tail(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        tail.iter().sum::<f32>() / tail.len().max(1) as f32
    }
}

/// A live training session for one entry point: frozen inputs resident on
/// device (kept fresh by `ParamStore` generation via
/// [`TrainSession::sync`]), trainable state round-tripping per step.
///
/// On the native backend the resident frozen weights carry their
/// prepared CSR/CSC structure across steps, so a pruned base weight's
/// forward *and* backward matmuls skip the zeros on every step without
/// re-deriving anything.
pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    exe: Exe,
    entry: EntryPoint,
    /// resident copies of the frozen store, keyed by generation
    frozen: ResidentParams,
    /// names (in output order) of the trainable params this entry updates
    trainable_names: Vec<String>,
}

impl<'rt> TrainSession<'rt> {
    /// `frozen` supplies inputs that never change across steps (uploaded
    /// once); everything else resolves from the per-step state.
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        entry_name: &str,
        frozen: &ParamStore,
    ) -> Result<Self> {
        let entry = cfg.entry(entry_name)?.clone();
        let exe = rt.load(&entry.file)?;
        let trainable_names = entry
            .outputs
            .iter()
            .filter(|o| {
                o.name != "loss" && !o.name.starts_with("m.") && !o.name.starts_with("v.")
            })
            .map(|o| o.name.clone())
            .collect();
        let mut session =
            TrainSession { rt, exe, entry, frozen: ResidentParams::new(), trainable_names };
        session.sync(frozen)?;
        Ok(session)
    }

    pub fn trainable_names(&self) -> &[String] {
        &self.trainable_names
    }

    /// Re-upload frozen inputs whose `ParamStore` generation changed
    /// (prune step, external weight edit) — cached prepared sparse /
    /// CSC structure rebuilds from the new values on first use. Cheap
    /// no-op when nothing changed.
    pub fn sync(&mut self, frozen: &ParamStore) -> Result<()> {
        self.frozen.sync(self.rt, frozen)
    }

    /// One fused train step. Updates `trainable`, `m`, `v` in place and
    /// returns the loss.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        trainable: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        masks: Option<&ParamStore>,
        batch: &Batch,
        step_no: usize,
        lr: f64,
        rank_mask: Option<&HostTensor>,
    ) -> Result<f32> {
        let step_t = HostTensor::scalar_f32(step_no as f32);
        let lr_t = HostTensor::scalar_f32(lr as f32);
        let mut args: Vec<Arg> = Vec::with_capacity(self.entry.inputs.len());
        for i in &self.entry.inputs {
            let name = i.name.as_str();
            if let Some(buf) = self.frozen.get(name) {
                args.push(Arg::Buf(buf));
                continue;
            }
            let t: &HostTensor = if let Some(rest) = name.strip_prefix("m.") {
                m.get(rest)?
            } else if let Some(rest) = name.strip_prefix("v.") {
                v.get(rest)?
            } else if let Some(rest) = name.strip_prefix("mask.") {
                masks
                    .context("entry needs prune masks but none supplied")?
                    .get(rest)?
            } else {
                match name {
                    "step" => &step_t,
                    "lr" => &lr_t,
                    "x" => &batch.x,
                    "y" => &batch.y,
                    "loss_mask" => &batch.loss_mask,
                    "rank_mask" => rank_mask.context("entry needs a rank mask")?,
                    _ => trainable.get(name)?,
                }
            };
            args.push(Arg::Host(t));
        }
        let outs = self.rt.run_args(&self.exe, &args)?;
        if outs.len() != self.entry.outputs.len() {
            bail!(
                "{}: {} outputs, manifest says {}",
                self.exe.name,
                outs.len(),
                self.entry.outputs.len()
            );
        }
        let mut loss = f32::NAN;
        for (spec, t) in self.entry.outputs.iter().zip(outs) {
            if spec.name == "loss" {
                loss = t.f32s()[0];
            } else if let Some(rest) = spec.name.strip_prefix("m.") {
                m.insert(rest, t);
            } else if let Some(rest) = spec.name.strip_prefix("v.") {
                v.insert(rest, t);
            } else {
                trainable.insert(&spec.name, t);
            }
        }
        Ok(loss)
    }
}

// --------------------------------------------------- durable train state

const TRAIN_CK_MAGIC: &[u8; 4] = b"SHTC";
const TRAIN_CK_VERSION: u32 = 1;

/// Everything the guarded loop needs to rewind or resume a run
/// bit-identically: global step, optimizer state, the NLS-sampling RNG
/// (full xoshiro + Box–Muller spare), the dataset cursor, and the loss /
/// LR traces recorded so far.
#[derive(Clone)]
struct TrainCheckpoint {
    step: usize,
    batcher_pos: usize,
    rng_s: [u64; 4],
    rng_spare: Option<f64>,
    losses: Vec<f32>,
    lrs: Vec<f32>,
    trainable: ParamStore,
    m: ParamStore,
    v: ParamStore,
}

impl TrainCheckpoint {
    /// Serialize and persist atomically with the crate-wide integrity
    /// footer (same writer as model checkpoints and search snapshots).
    fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(TRAIN_CK_MAGIC);
        buf.extend_from_slice(&TRAIN_CK_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.step as u64).to_le_bytes());
        buf.extend_from_slice(&(self.batcher_pos as u64).to_le_bytes());
        for s in self.rng_s {
            buf.extend_from_slice(&s.to_le_bytes());
        }
        buf.push(self.rng_spare.is_some() as u8);
        buf.extend_from_slice(&self.rng_spare.unwrap_or(0.0).to_le_bytes());
        for trace in [&self.losses, &self.lrs] {
            buf.extend_from_slice(&(trace.len() as u64).to_le_bytes());
            for x in trace {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        for store in [&self.trainable, &self.m, &self.v] {
            let payload = store.to_bytes()?;
            buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            buf.extend_from_slice(&payload);
        }
        durable::write_atomic(path, &buf)
            .with_context(|| format!("save train checkpoint {}", path.display()))
    }

    fn load(path: &std::path::Path) -> Result<Self> {
        let payload = durable::read_verified_strict(path, "train checkpoint")?;
        let mut cur = std::io::Cursor::new(payload.as_slice());
        let mut magic = [0u8; 4];
        cur.read_exact(&mut magic).context("corrupt train checkpoint: truncated header")?;
        if &magic != TRAIN_CK_MAGIC {
            bail!("not a shears train checkpoint: {}", path.display());
        }
        let read_u64 = |cur: &mut std::io::Cursor<&[u8]>| -> Result<u64> {
            let mut b = [0u8; 8];
            cur.read_exact(&mut b).context("corrupt train checkpoint: truncated")?;
            Ok(u64::from_le_bytes(b))
        };
        let mut ver = [0u8; 4];
        cur.read_exact(&mut ver).context("corrupt train checkpoint: truncated header")?;
        let ver = u32::from_le_bytes(ver);
        if ver != TRAIN_CK_VERSION {
            bail!("corrupt train checkpoint: unsupported version {ver}");
        }
        let step = read_u64(&mut cur)? as usize;
        let batcher_pos = read_u64(&mut cur)? as usize;
        let mut rng_s = [0u64; 4];
        for s in &mut rng_s {
            *s = read_u64(&mut cur)?;
        }
        let mut flag = [0u8; 1];
        cur.read_exact(&mut flag).context("corrupt train checkpoint: truncated")?;
        let spare = f64::from_bits(read_u64(&mut cur)?);
        let rng_spare = (flag[0] != 0).then_some(spare);
        let remaining = |cur: &std::io::Cursor<&[u8]>| payload.len() - cur.position() as usize;
        let mut traces: Vec<Vec<f32>> = Vec::with_capacity(2);
        for what in ["loss", "lr"] {
            let n = read_u64(&mut cur)? as usize;
            if n > remaining(&cur) / 4 {
                bail!("corrupt train checkpoint: {what} trace count {n} exceeds payload");
            }
            let mut trace = Vec::with_capacity(n);
            for _ in 0..n {
                let mut b = [0u8; 4];
                cur.read_exact(&mut b).context("corrupt train checkpoint: truncated")?;
                trace.push(f32::from_le_bytes(b));
            }
            traces.push(trace);
        }
        let mut stores: Vec<ParamStore> = Vec::with_capacity(3);
        for what in ["trainable", "m", "v"] {
            let n = read_u64(&mut cur)? as usize;
            if n > remaining(&cur) {
                bail!("corrupt train checkpoint: {what} store claims {n} bytes, payload has less");
            }
            let at = cur.position() as usize;
            stores.push(
                ParamStore::from_bytes(&payload[at..at + n])
                    .with_context(|| format!("corrupt train checkpoint: {what} store"))?,
            );
            cur.set_position((at + n) as u64);
        }
        if remaining(&cur) != 0 {
            bail!("corrupt train checkpoint: {} trailing bytes", remaining(&cur));
        }
        let v = stores.pop().unwrap();
        let m = stores.pop().unwrap();
        let trainable = stores.pop().unwrap();
        let lrs = traces.pop().unwrap();
        let losses = traces.pop().unwrap();
        Ok(TrainCheckpoint { step, batcher_pos, rng_s, rng_spare, losses, lrs, trainable, m, v })
    }
}

/// High-level training loop over a dataset batcher.
///
/// With `checkpoint_every > 0` the loop is *guarded*: it snapshots
/// last-good state (weights, optimizer moments, RNG, dataset cursor) at
/// every boundary, detects divergence (non-finite loss, or a spike past
/// `spike_factor ×` the trailing-8 mean), rolls back and deterministically
/// replays — the replayed steps recompute `lr_at` from the restored global
/// step, so a recovered run is bit-identical to one that never diverged.
/// After `rollback_budget` rollbacks it aborts cleanly. With
/// `checkpoint_path` set, boundaries also persist to disk and
/// `resume` continues an interrupted run from the durable state.
#[allow(clippy::too_many_arguments)]
pub fn train_loop(
    rt: &Runtime,
    cfg: &ModelConfig,
    entry_name: &str,
    frozen: &ParamStore,
    trainable: &mut ParamStore,
    masks: Option<&ParamStore>,
    batcher: &mut Batcher,
    space: Option<&SearchSpace>,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let mut fault = opts.fault.clone();
    if fault.is_empty() {
        if let Some(env) = FaultPlan::from_env()? {
            fault = env;
        }
    }
    let session = TrainSession::new(rt, cfg, entry_name, frozen)?;
    let specs: Vec<crate::model::ParamSpec> = session
        .trainable_names()
        .iter()
        .map(|n| crate::model::ParamSpec {
            name: n.clone(),
            shape: trainable.get(n).map(|t| t.shape.clone()).unwrap_or_default(),
        })
        .collect();
    let mut m = ParamStore::zeros_like(&specs);
    let mut v = ParamStore::zeros_like(&specs);
    let mut rng = Rng::new(opts.seed);
    let needs_mask = cfg
        .entry(entry_name)?
        .inputs
        .iter()
        .any(|i| i.name == "rank_mask");
    let timer = crate::util::log::Timer::new(&format!("train {entry_name}"));
    let mut log = TrainLog::default();
    let mut step = 0usize;
    if opts.resume {
        if let Some(path) = opts.checkpoint_path.as_deref() {
            if path.exists() {
                let ck = TrainCheckpoint::load(path)
                    .with_context(|| format!("resume train from {}", path.display()))?;
                step = ck.step;
                *trainable = ck.trainable;
                m = ck.m;
                v = ck.v;
                rng = Rng::from_state(ck.rng_s, ck.rng_spare);
                batcher.set_pos(ck.batcher_pos);
                log.losses = ck.losses;
                log.lrs = ck.lrs;
                crate::info!("{entry_name} resumed at step {step} of {}", opts.steps);
            }
        }
    }
    let snapshot = |step: usize,
                    trainable: &ParamStore,
                    m: &ParamStore,
                    v: &ParamStore,
                    rng: &Rng,
                    batcher: &Batcher,
                    log: &TrainLog| {
        let (rng_s, rng_spare) = rng.state();
        TrainCheckpoint {
            step,
            batcher_pos: batcher.pos(),
            rng_s,
            rng_spare,
            losses: log.losses.clone(),
            lrs: log.lrs.clone(),
            trainable: trainable.clone(),
            m: m.clone(),
            v: v.clone(),
        }
    };
    let mut last_good: Option<TrainCheckpoint> = None;
    let mut rollbacks = 0usize;
    while step < opts.steps {
        if opts.checkpoint_every > 0 && step % opts.checkpoint_every == 0 {
            let ck = snapshot(step, trainable, &m, &v, &rng, batcher, &log);
            if let Some(path) = opts.checkpoint_path.as_deref() {
                ck.save(path)?;
            }
            last_good = Some(ck);
        }
        let batch = batcher.next_cyclic();
        let rank_mask = if needs_mask {
            Some(match space {
                Some(sp) if opts.sample_nls => sp.rank_mask(&sp.sample(&mut rng)),
                Some(sp) => sp.full_mask(),
                None => bail!("entry {entry_name} needs a search space"),
            })
        } else {
            None
        };
        let lr = lr_at(step, opts.steps, opts.lr, opts.warmup);
        let mut loss = session.step(
            trainable,
            &mut m,
            &mut v,
            masks,
            &batch,
            step + 1,
            lr,
            rank_mask.as_ref(),
        )?;
        if !fault.is_empty() && fault.fire_train().nan_loss {
            loss = f32::NAN;
        }
        let spiking = opts.spike_factor > 0.0 && log.losses.len() >= 8 && {
            let tail = &log.losses[log.losses.len() - 8..];
            let mean = tail.iter().sum::<f32>() / tail.len() as f32;
            mean.is_finite() && mean > 0.0 && loss > opts.spike_factor as f32 * mean
        };
        if !loss.is_finite() || spiking {
            let Some(ck) = last_good.as_ref() else {
                bail!("loss diverged (step {step}): {loss}");
            };
            if rollbacks >= opts.rollback_budget {
                bail!(
                    "loss diverged (step {step}): {loss}; rollback budget {} exhausted",
                    opts.rollback_budget
                );
            }
            rollbacks += 1;
            crate::info!(
                "{entry_name} loss diverged at step {step} ({loss}); \
                 rolling back to step {} ({rollbacks}/{})",
                ck.step,
                opts.rollback_budget
            );
            *trainable = ck.trainable.clone();
            m = ck.m.clone();
            v = ck.v.clone();
            rng = Rng::from_state(ck.rng_s, ck.rng_spare);
            batcher.set_pos(ck.batcher_pos);
            log.losses.truncate(ck.losses.len());
            log.lrs.truncate(ck.lrs.len());
            step = ck.step;
            continue;
        }
        log.losses.push(loss);
        log.lrs.push(lr as f32);
        if opts.log_every > 0 && step % opts.log_every == 0 {
            crate::info!("{entry_name} step {step:>5} loss {loss:.4} lr {lr:.2e}");
        }
        step += 1;
    }
    if opts.checkpoint_every > 0 {
        if let Some(path) = opts.checkpoint_path.as_deref() {
            snapshot(step, trainable, &m, &v, &rng, batcher, &log).save(path)?;
        }
    }
    log.steps = opts.steps;
    log.wall_secs = timer.stop();
    log.rollbacks = rollbacks;
    Ok(log)
}

// ------------------------------------------------------------- evaluation

/// A forward entry point with every parameter store resident: uploads
/// once at construction, then serves batch-after-batch forwards with
/// cached prepared weights — the hot loop of [`evaluate`], the eval
/// router, and the serving decoder. [`ForwardSession::sync`] re-uploads
/// only weights whose store generation changed (prune step, optimizer
/// update), so cached sparse structure is never stale.
pub struct ForwardSession<'rt> {
    rt: &'rt Runtime,
    exe: Exe,
    entry: EntryPoint,
    resident: Vec<ResidentParams>,
    /// configuration snapshot (decode-state construction, shape checks)
    cfg: ModelConfig,
}

impl<'rt> ForwardSession<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        entry_name: &str,
        stores: &[&ParamStore],
    ) -> Result<Self> {
        let entry = cfg.entry(entry_name)?.clone();
        let exe = rt.load(&entry.file)?;
        let mut session = ForwardSession {
            rt,
            exe,
            entry,
            resident: stores.iter().map(|_| ResidentParams::new()).collect(),
            cfg: cfg.clone(),
        };
        session.sync(stores)?;
        Ok(session)
    }

    /// The configuration snapshot this session was built over. Taken at
    /// construction, so consumers that move across threads with the
    /// session's owner (the serving decoder handed to the async server
    /// thread) need no borrow of the manifest that produced it.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Re-upload any weights whose generation changed; cheap no-op
    /// otherwise. `stores` must align with the construction-time order.
    pub fn sync(&mut self, stores: &[&ParamStore]) -> Result<()> {
        ensure!(
            stores.len() == self.resident.len(),
            "ForwardSession::sync: {} stores, session built over {}",
            stores.len(),
            self.resident.len()
        );
        for (res, store) in self.resident.iter_mut().zip(stores) {
            res.sync(self.rt, store)?;
        }
        Ok(())
    }

    /// Resolve this entry's inputs positionally: `x` from the caller
    /// ([`Arg::Absent`] for decode bindings, which supply tokens
    /// directly), the rank mask when the entry declares one, and
    /// everything else from the resident stores. One resolution shared
    /// by [`ForwardSession::logits`] and [`ForwardSession::decoder`] so
    /// the two paths cannot drift.
    fn entry_args<'p>(
        &'p self,
        x: Option<&'p HostTensor>,
        rank_mask: Option<&'p HostTensor>,
    ) -> Result<Vec<Arg<'p>>> {
        let mut args: Vec<Arg<'p>> = Vec::with_capacity(self.entry.inputs.len());
        for i in &self.entry.inputs {
            let name = i.name.as_str();
            args.push(match name {
                "x" => match x {
                    Some(t) => Arg::Host(t),
                    None => Arg::Absent,
                },
                // a full forward needs mask values; a decode binding
                // (x absent) may omit them — the session then serves
                // the bare base by default and per-slot tenant
                // bindings carry their own masks
                "rank_mask" => match (rank_mask, &x) {
                    (Some(t), _) => Arg::Host(t),
                    (None, None) => Arg::Absent,
                    (None, Some(_)) => bail!("forward needs a rank mask"),
                },
                _ => Arg::Buf(
                    self.resident
                        .iter()
                        .find_map(|r| r.get(name))
                        .with_context(|| format!("input '{name}' not resident in any store"))?,
                ),
            });
        }
        Ok(args)
    }

    /// One forward over the `[B, S]` token batch; returns the logits.
    pub fn logits(&self, x: &HostTensor, rank_mask: Option<&HostTensor>) -> Result<HostTensor> {
        let args = self.entry_args(Some(x), rank_mask)?;
        let outs = self.rt.run_args(&self.exe, &args)?;
        outs.into_iter().next().context("forward produced no outputs")
    }

    /// Whether this session can serve the KV-cached incremental decode
    /// path: native backend **and** a plain forward entry (the PEFT
    /// baseline forwards re-forward instead).
    pub fn supports_decode(&self) -> bool {
        self.rt.decodable(&self.exe)
    }

    /// Fresh per-slot K/V caches for `slots` concurrent sequences.
    pub fn decode_state(&self, slots: usize) -> DecodeState {
        DecodeState::new(&self.cfg, slots)
    }

    /// Bind this session's resident weights for incremental decoding.
    /// The binding shares the resident prepared-weight cells, so decode
    /// steps ride the cached CSR/dense structures; rebind after
    /// [`ForwardSession::sync`] re-uploads anything.
    pub fn decoder<'p>(&'p self, rank_mask: Option<&'p HostTensor>) -> Result<DecodeSession<'p>> {
        let args = self.entry_args(None, rank_mask)?;
        self.rt.bind_decode(&self.exe, &args)
    }

    /// Whether the bound entry declares the unmerged-adapter inputs
    /// (a rank mask), i.e. per-tenant bindings can apply to it.
    pub fn supports_adapters(&self) -> bool {
        self.entry.inputs.iter().any(|i| i.name == "rank_mask")
    }

    /// Resolve one tenant's [`AdapterBinding`] from this session's
    /// resident LoRA tensors plus the tenant's rank-mask values. The
    /// binding owns copies of the (KB-scale) adapter weights, so it
    /// survives weight re-uploads and can be shared across slots and
    /// threads.
    pub fn adapter_binding(&self, rank_mask: &HostTensor) -> Result<AdapterBinding> {
        ensure!(
            self.supports_adapters(),
            "entry '{}' runs base-only (no adapter inputs to bind)",
            self.exe.name
        );
        let mut named = NamedTensors::new();
        for i in &self.entry.inputs {
            let name = i.name.as_str();
            if let Some(t) = self.resident.iter().find_map(|r| r.get(name)).and_then(|b| b.host())
            {
                named.insert(name, t);
            }
        }
        AdapterBinding::from_named(&self.cfg, &named, rank_mask.f32s())
    }
}

/// Teacher-forced exact-match accuracy over answer spans (the paper's
/// answer-accuracy protocol; see data/mod.rs). Parameters ride the
/// resident-buffer path: uploaded once, prepared weights cached across
/// all batches.
pub fn evaluate(
    rt: &Runtime,
    cfg: &ModelConfig,
    entry_name: &str,
    stores: &[&ParamStore],
    rank_mask: Option<&HostTensor>,
    examples: &[Example],
    vocab: &Vocab,
) -> Result<f64> {
    let session = ForwardSession::new(rt, cfg, entry_name, stores)?;
    let batcher = Batcher::new(examples, cfg.batch_eval, cfg.seq_len, vocab, MaskMode::AnswerOnly);
    let (mut correct, mut total) = (0usize, 0usize);
    let mut ex_idx = 0usize;
    for batch in batcher.epoch() {
        let logits = session.logits(&batch.x, rank_mask)?;
        let v = cfg.vocab;
        let s = cfg.seq_len;
        for row in 0..batch.real {
            let ex = &examples[ex_idx + row];
            let ok = exact_match(ex, &logits, row, s, v);
            correct += ok as usize;
            total += 1;
        }
        ex_idx += batch.real;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Run a forward entry point and return the flat logits tensor.
pub fn forward_logits(
    rt: &Runtime,
    exe: &Exe,
    entry: &EntryPoint,
    stores: &[&ParamStore],
    rank_mask: Option<&HostTensor>,
    batch: &Batch,
) -> Result<HostTensor> {
    let mut args: Vec<&HostTensor> = Vec::with_capacity(entry.inputs.len());
    for i in &entry.inputs {
        let name = i.name.as_str();
        let t = match name {
            "x" => &batch.x,
            "rank_mask" => rank_mask.context("forward needs a rank mask")?,
            _ => stores
                .iter()
                .find_map(|s| s.get(name).ok())
                .with_context(|| format!("input '{name}' not found in any store"))?,
        };
        args.push(t);
    }
    let outs = rt.run(exe, &args)?;
    outs.into_iter().next().context("forward produced no outputs")
}

/// Teacher-forced exact match for one example row.
pub fn exact_match(
    ex: &Example,
    logits: &HostTensor,
    row: usize,
    seq_len: usize,
    vocab: usize,
) -> bool {
    let data = logits.f32s();
    for k in 0..ex.answer_len {
        let pos = ex.answer_start + k;
        if pos == 0 || pos >= seq_len {
            return false;
        }
        // logits at pos-1 predict token at pos
        let off = (row * seq_len + (pos - 1)) * vocab;
        let slice = &data[off..off + vocab];
        let argmax = slice
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i as i32)
            .unwrap_or(-1);
        if argmax != ex.tokens[pos] {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let peak = 1e-3;
        assert!(lr_at(0, 100, peak, 10) < peak * 0.2);
        assert!((lr_at(10, 100, peak, 10) - peak).abs() < 1e-9);
        assert!(lr_at(99, 100, peak, 10) < peak * 0.01 + 1e-9);
        // monotone decay after warmup
        let mut prev = f64::INFINITY;
        for s in 10..100 {
            let l = lr_at(s, 100, peak, 10);
            assert!(l <= prev + 1e-12);
            prev = l;
        }
    }

    #[test]
    fn exact_match_checks_all_answer_positions() {
        // vocab 4, seq 4, answer at positions 2..4 = tokens [3, 1]
        let ex = Example { tokens: vec![1, 2, 3, 1], answer_start: 2, answer_len: 2 };
        let mut logits = vec![0.0f32; 4 * 4];
        // pos 1 predicts token 3; pos 2 predicts token 1
        logits[1 * 4 + 3] = 5.0;
        logits[2 * 4 + 1] = 5.0;
        let t = HostTensor::from_f32(&[1, 4, 4], logits.clone());
        assert!(exact_match(&ex, &t, 0, 4, 4));
        // break the second position
        logits[2 * 4 + 1] = 0.0;
        logits[2 * 4 + 0] = 5.0;
        let t = HostTensor::from_f32(&[1, 4, 4], logits);
        assert!(!exact_match(&ex, &t, 0, 4, 4));
    }

    #[test]
    fn train_log_tail_mean() {
        let log = TrainLog { losses: vec![5.0, 4.0, 3.0, 2.0], steps: 4, ..TrainLog::default() };
        assert_eq!(log.final_loss(), 2.0);
        assert_eq!(log.mean_tail(2), 2.5);
        assert_eq!(log.mean_tail(100), 3.5);
    }
}
