//! # Shears-RS
//!
//! Reproduction of *"Shears: Unstructured Sparsity with Neural Low-rank
//! Adapter Search"* (Muñoz, Yuan, Jain — NAACL 2024) as a three-layer
//! rust + JAX + Pallas stack. This crate is Layer 3: the coordinator that
//! owns the Shears pipeline — unstructured sparsification, super-adapter
//! training via NLS, and sub-adapter search — plus every substrate it
//! needs (synthetic task generators, search algorithms, a pluggable
//! runtime, an eval router, a serving loop).
//!
//! Execution is backend-pluggable ([`runtime`]):
//!
//! * **native** (default) — a pure-Rust CPU executor ([`ops`]) that
//!   implements every manifest entry point (forwards, fused train steps,
//!   calibration, prune ops) against the built-in manifest
//!   ([`model::builtin`]). Hermetic: no Python, no XLA, no `artifacts/`.
//! * **pjrt** (cargo feature `xla`) — `make artifacts` AOT-lowers the L2
//!   JAX model (which calls the L1 Pallas kernels) to HLO text; this
//!   crate loads and executes those artifacts through the PJRT C API.
//!
//! Either way there is no Python on the request path. Start with
//! [`coordinator::pipeline::ShearsPipeline`] for the paper's §3 workflow,
//! or `examples/quickstart.rs` for the smallest end-to-end program.

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod model;
pub mod nls;
pub mod ops;
pub mod pruning;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Repo-relative default artifacts directory (`make artifacts` output).
pub const ARTIFACTS_DIR: &str = "artifacts";
