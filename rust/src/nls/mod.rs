//! Neural Low-rank adapter Search (NLS) — the paper's §3.2/§3.3 machinery.
//!
//! The search space is the cross product of per-module elastic rank
//! choices (paper: `[32, 24, 16]` per adapter; scaled here per manifest).
//! Weight sharing is implemented with *rank masks*: the super-adapter
//! always holds `max_rank` columns and a `{0,1}` mask input activates a
//! prefix slice, so one AOT-compiled executable serves every sub-adapter
//! (DESIGN.md "rank masks"). During super-adapter training the L3 sampler
//! draws a random configuration per step — the weight-sharing NAS loop.

use crate::model::ModelConfig;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// A sub-adapter configuration: one rank per adapter module.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SubAdapterConfig {
    pub ranks: Vec<usize>,
}

impl SubAdapterConfig {
    /// Total active adapter parameters under this configuration, given the
    /// per-module (in, out) dims. Rank r costs r*(in + out).
    pub fn active_params(&self, dims: &[(usize, usize)]) -> usize {
        self.ranks
            .iter()
            .zip(dims)
            .map(|(r, (i, o))| r * (i + o))
            .sum()
    }
}

/// The elastic search space over adapter ranks.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// rank choices per module, descending (e.g. [8, 6, 4])
    pub choices: Vec<usize>,
    pub n_modules: usize,
    pub max_rank: usize,
    /// per-module (in, out) dims for param accounting
    pub dims: Vec<(usize, usize)>,
}

impl SearchSpace {
    pub fn from_config(cfg: &ModelConfig) -> SearchSpace {
        let mut choices = cfg.rank_choices.clone();
        choices.sort_unstable_by(|a, b| b.cmp(a)); // descending
        let dims = cfg
            .adapter_params
            .chunks(2)
            .map(|ab| {
                // [lora_a [R, in], lora_b [out, R]]
                (ab[0].shape[1], ab[1].shape[0])
            })
            .collect();
        SearchSpace {
            choices,
            n_modules: cfg.adapter_modules.len(),
            max_rank: cfg.max_rank,
            dims,
        }
    }

    /// Number of distinct sub-adapters.
    pub fn config_count(&self) -> f64 {
        (self.choices.len() as f64).powi(self.n_modules as i32)
    }

    /// Maximal sub-adapter == vanilla LoRA of rank `max_rank`.
    pub fn maximal(&self) -> SubAdapterConfig {
        SubAdapterConfig { ranks: vec![self.choices[0]; self.n_modules] }
    }

    pub fn minimal(&self) -> SubAdapterConfig {
        SubAdapterConfig {
            ranks: vec![*self.choices.last().unwrap(); self.n_modules],
        }
    }

    /// Paper Eq. 3: the heuristic sub-adapter takes choice index
    /// `c = floor(n/2)` at every module — the center of the space, found
    /// in O(1).
    pub fn heuristic(&self) -> SubAdapterConfig {
        let c = self.choices.len() / 2;
        SubAdapterConfig { ranks: vec![self.choices[c]; self.n_modules] }
    }

    /// Uniform random sub-adapter (the NLS training sampler).
    pub fn sample(&self, rng: &mut Rng) -> SubAdapterConfig {
        SubAdapterConfig {
            ranks: (0..self.n_modules)
                .map(|_| *rng.choice(&self.choices))
                .collect(),
        }
    }

    /// All single-module one-step moves (hill-climbing neighborhood):
    /// each module's rank moved one choice up or down.
    pub fn neighbors(&self, cfg: &SubAdapterConfig) -> Vec<SubAdapterConfig> {
        let mut out = Vec::new();
        for m in 0..self.n_modules {
            let ci = self
                .choices
                .iter()
                .position(|c| *c == cfg.ranks[m])
                .expect("rank not in choice set");
            for nc in [ci.wrapping_sub(1), ci + 1] {
                if nc < self.choices.len() && nc != ci {
                    let mut ranks = cfg.ranks.clone();
                    ranks[m] = self.choices[nc];
                    out.push(SubAdapterConfig { ranks });
                }
            }
        }
        out
    }

    /// Validate a configuration against the space.
    pub fn contains(&self, cfg: &SubAdapterConfig) -> bool {
        cfg.ranks.len() == self.n_modules
            && cfg.ranks.iter().all(|r| self.choices.contains(r))
    }

    /// Materialize the `[n_modules, max_rank]` rank-mask input for a
    /// configuration (prefix-slice weight sharing).
    pub fn rank_mask(&self, cfg: &SubAdapterConfig) -> HostTensor {
        assert!(self.contains(cfg), "config not in space: {cfg:?}");
        let mut data = vec![0.0f32; self.n_modules * self.max_rank];
        for (m, r) in cfg.ranks.iter().enumerate() {
            for j in 0..*r {
                data[m * self.max_rank + j] = 1.0;
            }
        }
        HostTensor::from_f32(&[self.n_modules, self.max_rank], data)
    }

    /// Mask with every rank fully active (vanilla-LoRA baseline path).
    pub fn full_mask(&self) -> HostTensor {
        HostTensor::ones(&[self.n_modules, self.max_rank])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn space() -> SearchSpace {
        SearchSpace {
            choices: vec![8, 6, 4],
            n_modules: 5,
            max_rank: 8,
            dims: vec![(48, 48); 5],
        }
    }

    #[test]
    fn canonical_configs() {
        let s = space();
        assert_eq!(s.maximal().ranks, vec![8; 5]);
        assert_eq!(s.minimal().ranks, vec![4; 5]);
        // Eq. 3: n=3 choices -> c=1 -> middle rank
        assert_eq!(s.heuristic().ranks, vec![6; 5]);
        assert_eq!(s.config_count(), 243.0);
    }

    #[test]
    fn rank_mask_is_prefix() {
        let s = space();
        let cfg = SubAdapterConfig { ranks: vec![8, 6, 4, 6, 8] };
        let m = s.rank_mask(&cfg);
        assert_eq!(m.shape, vec![5, 8]);
        let d = m.f32s();
        // module 2 has rank 4: first 4 on, rest off
        assert_eq!(&d[16..24], &[1., 1., 1., 1., 0., 0., 0., 0.]);
        // row sums equal ranks
        for (i, r) in cfg.ranks.iter().enumerate() {
            let sum: f32 = d[i * 8..(i + 1) * 8].iter().sum();
            assert_eq!(sum as usize, *r);
        }
    }

    #[test]
    fn sampler_stays_in_space_and_varies() {
        let s = space();
        let mut rng = Rng::new(0);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..100 {
            let c = s.sample(&mut rng);
            assert!(s.contains(&c));
            distinct.insert(c);
        }
        assert!(distinct.len() > 20);
    }

    #[test]
    fn neighbors_differ_in_exactly_one_module() {
        check("neighbors one-step", 50, |g| {
            let s = space();
            let mut rng = Rng::new(g.usize_in(0..10_000) as u64);
            let c = s.sample(&mut rng);
            for n in s.neighbors(&c) {
                assert!(s.contains(&n));
                let diff = c
                    .ranks
                    .iter()
                    .zip(&n.ranks)
                    .filter(|(a, b)| a != b)
                    .count();
                assert_eq!(diff, 1);
            }
        });
    }

    #[test]
    fn active_params_monotone_in_rank() {
        let s = space();
        let dims = &s.dims;
        assert!(s.maximal().active_params(dims) > s.heuristic().active_params(dims));
        assert!(s.heuristic().active_params(dims) > s.minimal().active_params(dims));
        assert_eq!(s.minimal().active_params(dims), 5 * 4 * 96);
    }

    #[test]
    #[should_panic(expected = "config not in space")]
    fn foreign_config_rejected() {
        let s = space();
        s.rank_mask(&SubAdapterConfig { ranks: vec![5; 5] });
    }
}
