//! Sub-adapter search (paper §3.3 and Table 6).
//!
//! Strategies over the NLS space, cheapest first — the exact menu the
//! paper describes:
//! 1. O(1) **heuristic** ([`crate::nls::SearchSpace::heuristic`], Eq. 3),
//! 2. **hill-climbing** from the heuristic ([`hill_climb`]),
//! 3. evolutionary **NSGA-II** ([`nsga2`]) and its reference-point variant
//!    **RNSGA-II** ([`rnsga2`]) as the expensive comparison points.
//!
//! Search cost is dominated by sub-adapter evaluations (each is a full
//! validation pass through the PJRT executable), so every strategy runs
//! through a memoizing [`CachedEvaluator`] and reports how many unique
//! evaluations it spent.

use crate::nls::{SearchSpace, SubAdapterConfig};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Anything that can score a sub-adapter (higher = better accuracy).
pub trait Evaluator {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64;
}

impl<F: FnMut(&SubAdapterConfig) -> f64> Evaluator for F {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64 {
        self(cfg)
    }
}

/// Memoizes evaluations (validation passes are expensive) and counts them.
pub struct CachedEvaluator<E: Evaluator> {
    inner: E,
    cache: HashMap<Vec<usize>, f64>,
    pub evals: usize,
}

impl<E: Evaluator> CachedEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CachedEvaluator { inner, cache: HashMap::new(), evals: 0 }
    }
}

impl<E: Evaluator> Evaluator for CachedEvaluator<E> {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64 {
        if let Some(v) = self.cache.get(&cfg.ranks) {
            return *v;
        }
        self.evals += 1;
        let v = self.inner.eval(cfg);
        self.cache.insert(cfg.ranks.clone(), v);
        v
    }
}

/// Search outcome: best config, its score, and evaluation spend.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub config: SubAdapterConfig,
    pub score: f64,
    pub evals: usize,
}

// ---------------------------------------------------------- hill climbing

/// Greedy first-improvement hill climbing from `start` (paper §3.3: "a
/// well-designed hill-climbing algorithm … initiated from the sub-adapter
/// configuration found with the heuristic"). Stops at a local optimum or
/// after `budget` unique evaluations.
pub fn hill_climb<E: Evaluator>(
    space: &SearchSpace,
    start: SubAdapterConfig,
    ev: &mut CachedEvaluator<E>,
    budget: usize,
) -> SearchResult {
    let mut cur = start;
    let mut cur_score = ev.eval(&cur);
    loop {
        let mut improved = false;
        for n in space.neighbors(&cur) {
            if ev.evals >= budget {
                return SearchResult { config: cur, score: cur_score, evals: ev.evals };
            }
            let s = ev.eval(&n);
            if s > cur_score {
                cur = n;
                cur_score = s;
                improved = true;
                break; // first improvement: cheap restarts of the scan
            }
        }
        if !improved {
            return SearchResult { config: cur, score: cur_score, evals: ev.evals };
        }
    }
}

// ------------------------------------------------------------- NSGA-II

/// One individual: genes are choice indices, objectives are minimized.
#[derive(Clone, Debug)]
struct Ind {
    genes: Vec<usize>,
    obj: Vec<f64>,
}

fn dominates(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

/// Fast non-dominated sort (Deb et al. 2002): returns fronts of indices.
pub fn non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<usize> = vec![0; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&objs[i], &objs[j]) {
                dominates_list[i].push(j);
            } else if i != j && dominates(&objs[j], &objs[i]) {
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|i| dominated_by[*i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// Crowding distance within one front (Deb et al. 2002).
pub fn crowding_distance(objs: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let m = objs.first().map(|o| o.len()).unwrap_or(0);
    let mut dist = vec![0.0f64; front.len()];
    for k in 0..m {
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][k]
                .partial_cmp(&objs[front[b]][k])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = objs[front[order[0]]][k];
        let hi = objs[front[*order.last().unwrap()]][k];
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        if (hi - lo).abs() < 1e-12 {
            continue;
        }
        for w in 1..order.len().saturating_sub(1) {
            dist[order[w]] +=
                (objs[front[order[w + 1]]][k] - objs[front[order[w - 1]]][k]) / (hi - lo);
        }
    }
    dist
}

fn objectives<E: Evaluator>(
    space: &SearchSpace,
    genes: &[usize],
    ev: &mut CachedEvaluator<E>,
) -> (SubAdapterConfig, Vec<f64>) {
    let cfg = SubAdapterConfig {
        ranks: genes.iter().map(|g| space.choices[*g]).collect(),
    };
    let acc = ev.eval(&cfg);
    let params = cfg.active_params(&space.dims) as f64
        / space.maximal().active_params(&space.dims) as f64;
    // minimize (-accuracy, normalized params)
    (cfg, vec![-acc, params])
}

struct Evolution<'a, E: Evaluator> {
    space: &'a SearchSpace,
    ev: &'a mut CachedEvaluator<E>,
    rng: Rng,
    pop_size: usize,
}

impl<'a, E: Evaluator> Evolution<'a, E> {
    fn random_genes(&mut self) -> Vec<usize> {
        (0..self.space.n_modules)
            .map(|_| self.rng.below(self.space.choices.len()))
            .collect()
    }

    fn offspring(&mut self, a: &[usize], b: &[usize]) -> Vec<usize> {
        let mut child: Vec<usize> = a
            .iter()
            .zip(b)
            .map(|(x, y)| if self.rng.bool(0.5) { *x } else { *y })
            .collect();
        for g in child.iter_mut() {
            if self.rng.bool(1.0 / self.space.n_modules.max(1) as f64) {
                *g = self.rng.below(self.space.choices.len());
            }
        }
        child
    }

    /// Run generations with a pluggable survivor-ranking function.
    fn run<R>(&mut self, generations: usize, budget: usize, rank: R) -> Vec<Ind>
    where
        R: Fn(&[Vec<f64>]) -> Vec<usize>, // returns survivor indices, best-first
    {
        let mut pop: Vec<Ind> = (0..self.pop_size)
            .map(|_| {
                let genes = self.random_genes();
                let (_, obj) = objectives(self.space, &genes, self.ev);
                Ind { genes, obj }
            })
            .collect();
        for _ in 0..generations {
            if self.ev.evals >= budget {
                break;
            }
            // variation: binary-tournament parents by rank-0 position
            let mut children = Vec::with_capacity(self.pop_size);
            for _ in 0..self.pop_size {
                let pa = &pop[self.rng.below(pop.len())];
                let pb = &pop[self.rng.below(pop.len())];
                let parent_a =
                    if dominates(&pa.obj, &pb.obj) { pa.genes.clone() } else { pb.genes.clone() };
                let pc = &pop[self.rng.below(pop.len())];
                let child_genes = self.offspring(&parent_a, &pc.genes);
                let (_, obj) = objectives(self.space, &child_genes, self.ev);
                children.push(Ind { genes: child_genes, obj });
                if self.ev.evals >= budget {
                    break;
                }
            }
            pop.extend(children);
            let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.obj.clone()).collect();
            let order = rank(&objs);
            pop = order.into_iter().take(self.pop_size).map(|i| pop[i].clone()).collect();
        }
        pop
    }
}

fn nsga2_rank(objs: &[Vec<f64>]) -> Vec<usize> {
    let fronts = non_dominated_sort(objs);
    let mut order = Vec::with_capacity(objs.len());
    for front in fronts {
        let cd = crowding_distance(objs, &front);
        let mut idx: Vec<usize> = (0..front.len()).collect();
        idx.sort_by(|&a, &b| cd[b].partial_cmp(&cd[a]).unwrap_or(std::cmp::Ordering::Equal));
        order.extend(idx.into_iter().map(|i| front[i]));
    }
    order
}

/// NSGA-II over (accuracy, adapter params). Returns the accuracy-best
/// config on the first front.
pub fn nsga2<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
) -> SearchResult {
    let mut evo = Evolution { space, ev, rng: Rng::new(seed), pop_size };
    let pop = evo.run(generations, budget, nsga2_rank);
    best_by_accuracy(space, pop, ev)
}

/// RNSGA-II (Deb & Sundar 2006): survivor ranking biased toward reference
/// points in objective space — here one aspiration point (best accuracy,
/// mid params), which is how the paper uses it for sub-adapter search.
pub fn rnsga2<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
    reference: Vec<f64>,
) -> SearchResult {
    let rank = move |objs: &[Vec<f64>]| -> Vec<usize> {
        let fronts = non_dominated_sort(objs);
        let mut order = Vec::with_capacity(objs.len());
        for front in fronts {
            // preference distance: closer to the reference point = better
            let mut idx: Vec<usize> = (0..front.len()).collect();
            let d: Vec<f64> = front
                .iter()
                .map(|&i| {
                    objs[i]
                        .iter()
                        .zip(&reference)
                        .map(|(a, r)| (a - r) * (a - r))
                        .sum::<f64>()
                })
                .collect();
            idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap_or(std::cmp::Ordering::Equal));
            order.extend(idx.into_iter().map(|i| front[i]));
        }
        order
    };
    let mut evo = Evolution { space, ev, rng: Rng::new(seed), pop_size };
    let pop = evo.run(generations, budget, rank);
    best_by_accuracy(space, pop, ev)
}

fn best_by_accuracy<E: Evaluator>(
    space: &SearchSpace,
    pop: Vec<Ind>,
    ev: &mut CachedEvaluator<E>,
) -> SearchResult {
    let best = pop
        .into_iter()
        .min_by(|a, b| a.obj[0].partial_cmp(&b.obj[0]).unwrap_or(std::cmp::Ordering::Equal))
        .expect("empty population");
    let config = SubAdapterConfig {
        ranks: best.genes.iter().map(|g| space.choices[*g]).collect(),
    };
    SearchResult { config, score: -best.obj[0], evals: ev.evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn space() -> SearchSpace {
        SearchSpace {
            choices: vec![8, 6, 4],
            n_modules: 6,
            max_rank: 8,
            dims: vec![(32, 32); 6],
        }
    }

    /// Synthetic landscape: accuracy rises with total rank, with a dip at
    /// the maximum (so search must find an interior optimum).
    fn landscape(cfg: &SubAdapterConfig) -> f64 {
        let total: usize = cfg.ranks.iter().sum();
        let t = total as f64;
        -(t - 40.0).abs() / 40.0 + 1.0 // peak at total rank 40
    }

    #[test]
    fn cache_avoids_recomputation() {
        let mut calls = 0usize;
        let mut ev = CachedEvaluator::new(|c: &SubAdapterConfig| {
            calls += 1;
            c.ranks[0] as f64
        });
        let s = space();
        let c = s.maximal();
        let a = ev.eval(&c);
        let b = ev.eval(&c);
        assert_eq!(a, b);
        assert_eq!(ev.evals, 1);
        drop(ev);
        assert_eq!(calls, 1);
    }

    #[test]
    fn hill_climb_improves_over_start() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let start = s.minimal(); // total 24, below the peak
        let start_score = landscape(&start);
        let r = hill_climb(&s, start, &mut ev, 500);
        assert!(r.score >= start_score);
        // peak at total 40 is reachable: e.g. 6*6=36..8*6=48 — 40 = 4×6+2×8
        assert!(r.score > 0.9, "{:?}", r);
    }

    #[test]
    fn hill_climb_respects_budget() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let r = hill_climb(&s, s.minimal(), &mut ev, 3);
        assert!(r.evals <= 3 + 1); // start eval + budgeted neighbors
    }

    #[test]
    fn non_dominated_sort_fronts_are_correct() {
        // objectives (minimize both): a=(0,0) dominates all; b,c incomparable
        let objs = vec![vec![0.0, 0.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 3.0]];
        let fronts = non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0]);
        assert_eq!(fronts[1], vec![1, 2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn sort_invariants_hold_on_random_objectives() {
        check("nds invariants", 60, |g| {
            let n = g.usize_in(1..12);
            let objs: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![g.f32_in(0.0, 1.0) as f64, g.f32_in(0.0, 1.0) as f64])
                .collect();
            let fronts = non_dominated_sort(&objs);
            // partition
            let total: usize = fronts.iter().map(|f| f.len()).sum();
            assert_eq!(total, n);
            // no individual dominates another within a front
            for front in &fronts {
                for &i in front {
                    for &j in front {
                        assert!(i == j || !dominates(&objs[i], &objs[j]));
                    }
                }
            }
            // every front-k+1 member is dominated by someone in front k
            for w in 1..fronts.len() {
                for &j in &fronts[w] {
                    assert!(
                        fronts[w - 1].iter().any(|&i| dominates(&objs[i], &objs[j])),
                        "front {w} member {j} undominated by front {}",
                        w - 1
                    );
                }
            }
        });
    }

    #[test]
    fn crowding_extremes_are_infinite() {
        let objs = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let front: Vec<usize> = (0..4).collect();
        let cd = crowding_distance(&objs, &front);
        assert!(cd[0].is_infinite() && cd[3].is_infinite());
        assert!(cd[1].is_finite() && cd[2].is_finite());
    }

    #[test]
    fn nsga2_finds_good_interior_config() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let r = nsga2(&s, &mut ev, 42, 12, 10, 400);
        assert!(r.score > 0.85, "{r:?}");
        assert!(s.contains(&r.config));
    }

    #[test]
    fn rnsga2_converges_toward_reference() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        // aspire to top accuracy at ~70% params
        let r = rnsga2(&s, &mut ev, 42, 12, 10, 400, vec![-1.0, 0.7]);
        assert!(r.score > 0.8, "{r:?}");
        assert!(s.contains(&r.config));
    }

    #[test]
    fn evolutionary_costs_more_than_hill_climb() {
        // the paper's cost argument (§3.3): hill-climbing is cheaper
        let s = space();
        let mut ev1 = CachedEvaluator::new(landscape);
        let hc = hill_climb(&s, s.heuristic(), &mut ev1, 10_000);
        let mut ev2 = CachedEvaluator::new(landscape);
        let ga = nsga2(&s, &mut ev2, 1, 12, 10, 10_000);
        assert!(hc.evals < ga.evals, "hc={} ga={}", hc.evals, ga.evals);
    }
}
