//! Sub-adapter search (paper §3.3 and Table 6).
//!
//! Strategies over the NLS space, cheapest first — the exact menu the
//! paper describes:
//! 1. O(1) **heuristic** ([`crate::nls::SearchSpace::heuristic`], Eq. 3),
//! 2. **hill-climbing** from the heuristic ([`hill_climb`]),
//! 3. evolutionary **NSGA-II** ([`nsga2`]) and its reference-point variant
//!    **RNSGA-II** ([`rnsga2`]) as the expensive comparison points.
//!
//! Search cost is dominated by sub-adapter evaluations (each is a full
//! validation pass through the PJRT executable), so every strategy runs
//! through a memoizing [`CachedEvaluator`] and reports how many unique
//! evaluations it spent.
//!
//! Long multi-generation runs are **durable**: the `*_durable`
//! variants ([`nsga2_durable`], [`rnsga2_durable`],
//! [`hill_climb_durable`]) periodically snapshot population,
//! objectives, RNG state, and the evaluator cache to an atomic
//! checksummed file ([`crate::util::durable`]), and `--resume` picks a
//! killed run back up at the last generation boundary. Because the
//! xoshiro state and the eval-budget counter round-trip exactly, a
//! resumed run is **bit-identical** to an uninterrupted one
//! (`tests/pipeline_faults.rs`); corrupt snapshots fail with a clean
//! `corrupt snapshot: …` error, never a panic or a partial population.

use crate::nls::{SearchSpace, SubAdapterConfig};
use crate::util::durable;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::path::PathBuf;

/// Anything that can score a sub-adapter (higher = better accuracy).
pub trait Evaluator {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64;
}

impl<F: FnMut(&SubAdapterConfig) -> f64> Evaluator for F {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64 {
        self(cfg)
    }
}

/// Memoizes evaluations (validation passes are expensive) and counts them.
pub struct CachedEvaluator<E: Evaluator> {
    inner: E,
    cache: HashMap<Vec<usize>, f64>,
    pub evals: usize,
}

impl<E: Evaluator> CachedEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CachedEvaluator { inner, cache: HashMap::new(), evals: 0 }
    }

    /// Cache contents in deterministic (sorted-key) order, for durable
    /// snapshots.
    pub fn cache_entries(&self) -> Vec<(Vec<usize>, f64)> {
        let mut v: Vec<(Vec<usize>, f64)> = self.cache.iter().map(|(k, &s)| (k.clone(), s)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Restore cache + spend counter from a snapshot. Restoring *both*
    /// makes a resumed search bit-identical: memo hits replay for free
    /// and the budget check fires at exactly the original point.
    pub fn restore_cache(&mut self, entries: Vec<(Vec<usize>, f64)>, evals: usize) {
        self.cache = entries.into_iter().collect();
        self.evals = evals;
    }
}

impl<E: Evaluator> Evaluator for CachedEvaluator<E> {
    fn eval(&mut self, cfg: &SubAdapterConfig) -> f64 {
        if let Some(v) = self.cache.get(&cfg.ranks) {
            return *v;
        }
        self.evals += 1;
        let v = self.inner.eval(cfg);
        self.cache.insert(cfg.ranks.clone(), v);
        v
    }
}

/// Search outcome: best config, its score, evaluation spend, and the
/// final non-dominated front.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub config: SubAdapterConfig,
    pub score: f64,
    pub evals: usize,
    /// final Pareto front as `(config, objectives)` pairs in the
    /// survivor ranking's deterministic order (minimized objectives:
    /// `[-accuracy, normalized params]`). Hill climbing reports its
    /// single optimum. The resume-determinism pins compare this
    /// bit-for-bit.
    pub front: Vec<(SubAdapterConfig, Vec<f64>)>,
}

/// How a `*_durable` search persists its state.
#[derive(Clone, Debug)]
pub struct DurableOpts {
    /// snapshot file (atomic + checksummed; see [`crate::util::durable`])
    pub path: PathBuf,
    /// snapshot every N generation boundaries (hill climbing: every N
    /// accepted moves); clamped to ≥ 1
    pub every: usize,
    /// pick up from `path` when it exists (missing file = fresh start)
    pub resume: bool,
}

// ---------------------------------------------------------- hill climbing

/// Greedy first-improvement hill climbing from `start` (paper §3.3: "a
/// well-designed hill-climbing algorithm … initiated from the sub-adapter
/// configuration found with the heuristic"). Stops at a local optimum or
/// after `budget` unique evaluations.
pub fn hill_climb<E: Evaluator>(
    space: &SearchSpace,
    start: SubAdapterConfig,
    ev: &mut CachedEvaluator<E>,
    budget: usize,
) -> SearchResult {
    hill_climb_durable(space, start, ev, budget, None)
        .expect("hill climb without durability performs no I/O")
}

/// [`hill_climb`] with durable state: every `every`-th accepted move
/// (and the final optimum) snapshots the current config + evaluator
/// cache, and `resume` continues a killed run bit-identically — the
/// neighbor scan restarts from the restored config exactly as the
/// uninterrupted run's scan restarts after each accepted move.
pub fn hill_climb_durable<E: Evaluator>(
    space: &SearchSpace,
    start: SubAdapterConfig,
    ev: &mut CachedEvaluator<E>,
    budget: usize,
    durable: Option<&DurableOpts>,
) -> Result<SearchResult> {
    let mut cur = start;
    if let Some(d) = durable {
        if d.resume && d.path.exists() {
            let snap = Snapshot::load(&d.path)?;
            snap.check_identity(ALGO_HILL_CLIMB, 0, 1, space)?;
            let ind =
                snap.pop.first().context("corrupt snapshot: empty hill-climb population")?;
            // hill-climb snapshots store concrete ranks, not choice
            // indices (the climb walks rank space directly)
            cur = SubAdapterConfig { ranks: ind.genes.clone() };
            ev.restore_cache(snap.cache, snap.evals);
        }
    }
    let mut cur_score = ev.eval(&cur);
    let mut accepted = 0usize;
    loop {
        let mut improved = false;
        for n in space.neighbors(&cur) {
            if ev.evals >= budget {
                return Ok(hc_result(space, cur, cur_score, ev.evals));
            }
            let s = ev.eval(&n);
            if s > cur_score {
                cur = n;
                cur_score = s;
                improved = true;
                accepted += 1;
                if let Some(d) = durable {
                    if accepted % d.every.max(1) == 0 {
                        Snapshot::for_hill_climb(space, &cur, cur_score, ev).save(&d.path)?;
                    }
                }
                break; // first improvement: cheap restarts of the scan
            }
        }
        if !improved {
            if let Some(d) = durable {
                Snapshot::for_hill_climb(space, &cur, cur_score, ev).save(&d.path)?;
            }
            return Ok(hc_result(space, cur, cur_score, ev.evals));
        }
    }
}

fn hc_result(
    space: &SearchSpace,
    cur: SubAdapterConfig,
    score: f64,
    evals: usize,
) -> SearchResult {
    let params = cur.active_params(&space.dims) as f64
        / space.maximal().active_params(&space.dims) as f64;
    SearchResult { front: vec![(cur.clone(), vec![-score, params])], config: cur, score, evals }
}

// ------------------------------------------------------------- NSGA-II

/// One individual: genes are choice indices, objectives are minimized.
#[derive(Clone, Debug)]
struct Ind {
    genes: Vec<usize>,
    obj: Vec<f64>,
}

fn dominates(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

/// Fast non-dominated sort (Deb et al. 2002): returns fronts of indices.
pub fn non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<usize> = vec![0; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&objs[i], &objs[j]) {
                dominates_list[i].push(j);
            } else if i != j && dominates(&objs[j], &objs[i]) {
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|i| dominated_by[*i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// Crowding distance within one front (Deb et al. 2002).
pub fn crowding_distance(objs: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let m = objs.first().map(|o| o.len()).unwrap_or(0);
    let mut dist = vec![0.0f64; front.len()];
    for k in 0..m {
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            objs[front[a]][k]
                .partial_cmp(&objs[front[b]][k])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = objs[front[order[0]]][k];
        let hi = objs[front[*order.last().unwrap()]][k];
        dist[order[0]] = f64::INFINITY;
        dist[*order.last().unwrap()] = f64::INFINITY;
        if (hi - lo).abs() < 1e-12 {
            continue;
        }
        for w in 1..order.len().saturating_sub(1) {
            dist[order[w]] +=
                (objs[front[order[w + 1]]][k] - objs[front[order[w - 1]]][k]) / (hi - lo);
        }
    }
    dist
}

fn objectives<E: Evaluator>(
    space: &SearchSpace,
    genes: &[usize],
    ev: &mut CachedEvaluator<E>,
) -> (SubAdapterConfig, Vec<f64>) {
    let cfg = SubAdapterConfig {
        ranks: genes.iter().map(|g| space.choices[*g]).collect(),
    };
    let acc = ev.eval(&cfg);
    let params = cfg.active_params(&space.dims) as f64
        / space.maximal().active_params(&space.dims) as f64;
    // minimize (-accuracy, normalized params)
    (cfg, vec![-acc, params])
}

struct Evolution<'a, E: Evaluator> {
    space: &'a SearchSpace,
    ev: &'a mut CachedEvaluator<E>,
    rng: Rng,
    pop_size: usize,
}

impl<'a, E: Evaluator> Evolution<'a, E> {
    fn random_genes(&mut self) -> Vec<usize> {
        (0..self.space.n_modules)
            .map(|_| self.rng.below(self.space.choices.len()))
            .collect()
    }

    fn offspring(&mut self, a: &[usize], b: &[usize]) -> Vec<usize> {
        let mut child: Vec<usize> = a
            .iter()
            .zip(b)
            .map(|(x, y)| if self.rng.bool(0.5) { *x } else { *y })
            .collect();
        for g in child.iter_mut() {
            if self.rng.bool(1.0 / self.space.n_modules.max(1) as f64) {
                *g = self.rng.below(self.space.choices.len());
            }
        }
        child
    }

    /// Run generations with a pluggable survivor-ranking function.
    ///
    /// With `durable` set, the run snapshots at generation boundaries
    /// (population + objectives + RNG state + evaluator cache) and —
    /// when resuming — restores all of them, so the remaining
    /// generations replay bit-identically: selection consumes no RNG
    /// between the end of generation *g* and the start of *g+1*, which
    /// makes the boundary state exactly the next iteration's start
    /// state.
    fn run<R>(
        &mut self,
        generations: usize,
        budget: usize,
        rank: R,
        durable: Option<&DurableOpts>,
        algo: u8,
        seed: u64,
    ) -> Result<Vec<Ind>>
    where
        R: Fn(&[Vec<f64>]) -> Vec<usize>, // returns survivor indices, best-first
    {
        let mut start_gen = 0usize;
        let mut pop: Option<Vec<Ind>> = None;
        if let Some(d) = durable {
            if d.resume && d.path.exists() {
                let snap = Snapshot::load(&d.path)?;
                snap.check_identity(algo, seed, self.pop_size, self.space)?;
                self.rng = Rng::from_state(snap.rng_s, snap.rng_spare);
                self.ev.restore_cache(snap.cache, snap.evals);
                start_gen = snap.gen_done;
                pop = Some(snap.pop);
            }
        }
        let mut pop = match pop {
            Some(p) => p,
            None => {
                let p: Vec<Ind> = (0..self.pop_size)
                    .map(|_| {
                        let genes = self.random_genes();
                        let (_, obj) = objectives(self.space, &genes, self.ev);
                        Ind { genes, obj }
                    })
                    .collect();
                // generation-0 snapshot: a kill inside the very first
                // generation resumes without repaying the initial
                // population's evaluations
                if let Some(d) = durable {
                    Snapshot::for_evolution(algo, seed, self, &p, 0).save(&d.path)?;
                }
                p
            }
        };
        for generation in start_gen..generations {
            if self.ev.evals >= budget {
                break;
            }
            // variation: binary-tournament parents by rank-0 position
            let mut children = Vec::with_capacity(self.pop_size);
            for _ in 0..self.pop_size {
                let pa = &pop[self.rng.below(pop.len())];
                let pb = &pop[self.rng.below(pop.len())];
                let parent_a =
                    if dominates(&pa.obj, &pb.obj) { pa.genes.clone() } else { pb.genes.clone() };
                let pc = &pop[self.rng.below(pop.len())];
                let child_genes = self.offspring(&parent_a, &pc.genes);
                let (_, obj) = objectives(self.space, &child_genes, self.ev);
                children.push(Ind { genes: child_genes, obj });
                if self.ev.evals >= budget {
                    break;
                }
            }
            pop.extend(children);
            let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.obj.clone()).collect();
            let order = rank(&objs);
            pop = order.into_iter().take(self.pop_size).map(|i| pop[i].clone()).collect();
            if let Some(d) = durable {
                let done = generation + 1;
                if done % d.every.max(1) == 0 || done == generations {
                    Snapshot::for_evolution(algo, seed, self, &pop, done).save(&d.path)?;
                }
            }
        }
        Ok(pop)
    }
}

fn nsga2_rank(objs: &[Vec<f64>]) -> Vec<usize> {
    let fronts = non_dominated_sort(objs);
    let mut order = Vec::with_capacity(objs.len());
    for front in fronts {
        let cd = crowding_distance(objs, &front);
        let mut idx: Vec<usize> = (0..front.len()).collect();
        idx.sort_by(|&a, &b| cd[b].partial_cmp(&cd[a]).unwrap_or(std::cmp::Ordering::Equal));
        order.extend(idx.into_iter().map(|i| front[i]));
    }
    order
}

/// NSGA-II over (accuracy, adapter params). Returns the accuracy-best
/// config on the first front.
pub fn nsga2<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
) -> SearchResult {
    nsga2_durable(space, ev, seed, pop_size, generations, budget, None)
        .expect("nsga2 without durability performs no I/O")
}

/// [`nsga2`] with durable generation-boundary snapshots and resume
/// (see [`DurableOpts`]). A run killed mid-generation and resumed
/// produces a bit-identical final Pareto front to an uninterrupted
/// run.
pub fn nsga2_durable<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
    durable: Option<&DurableOpts>,
) -> Result<SearchResult> {
    let mut evo = Evolution { space, ev, rng: Rng::new(seed), pop_size };
    let pop = evo.run(generations, budget, nsga2_rank, durable, ALGO_NSGA2, seed)?;
    Ok(best_by_accuracy(space, pop, ev))
}

/// RNSGA-II (Deb & Sundar 2006): survivor ranking biased toward reference
/// points in objective space — here one aspiration point (best accuracy,
/// mid params), which is how the paper uses it for sub-adapter search.
pub fn rnsga2<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
    reference: Vec<f64>,
) -> SearchResult {
    rnsga2_durable(space, ev, seed, pop_size, generations, budget, reference, None)
        .expect("rnsga2 without durability performs no I/O")
}

/// [`rnsga2`] with durable generation-boundary snapshots and resume
/// (see [`DurableOpts`]).
#[allow(clippy::too_many_arguments)]
pub fn rnsga2_durable<E: Evaluator>(
    space: &SearchSpace,
    ev: &mut CachedEvaluator<E>,
    seed: u64,
    pop_size: usize,
    generations: usize,
    budget: usize,
    reference: Vec<f64>,
    durable: Option<&DurableOpts>,
) -> Result<SearchResult> {
    let rank = move |objs: &[Vec<f64>]| -> Vec<usize> {
        let fronts = non_dominated_sort(objs);
        let mut order = Vec::with_capacity(objs.len());
        for front in fronts {
            // preference distance: closer to the reference point = better
            let mut idx: Vec<usize> = (0..front.len()).collect();
            let d: Vec<f64> = front
                .iter()
                .map(|&i| {
                    objs[i]
                        .iter()
                        .zip(&reference)
                        .map(|(a, r)| (a - r) * (a - r))
                        .sum::<f64>()
                })
                .collect();
            idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap_or(std::cmp::Ordering::Equal));
            order.extend(idx.into_iter().map(|i| front[i]));
        }
        order
    };
    let mut evo = Evolution { space, ev, rng: Rng::new(seed), pop_size };
    let pop = evo.run(generations, budget, rank, durable, ALGO_RNSGA2, seed)?;
    Ok(best_by_accuracy(space, pop, ev))
}

fn best_by_accuracy<E: Evaluator>(
    space: &SearchSpace,
    pop: Vec<Ind>,
    ev: &mut CachedEvaluator<E>,
) -> SearchResult {
    let cfg_of = |genes: &[usize]| SubAdapterConfig {
        ranks: genes.iter().map(|g| space.choices[*g]).collect(),
    };
    let objs: Vec<Vec<f64>> = pop.iter().map(|i| i.obj.clone()).collect();
    let front = non_dominated_sort(&objs)
        .first()
        .map(|f| f.iter().map(|&i| (cfg_of(&pop[i].genes), pop[i].obj.clone())).collect())
        .unwrap_or_default();
    let best = pop
        .into_iter()
        .min_by(|a, b| a.obj[0].partial_cmp(&b.obj[0]).unwrap_or(std::cmp::Ordering::Equal))
        .expect("empty population");
    SearchResult { config: cfg_of(&best.genes), score: -best.obj[0], evals: ev.evals, front }
}

// --------------------------------------------------- durable snapshots

const ALGO_HILL_CLIMB: u8 = 0;
const ALGO_NSGA2: u8 = 1;
const ALGO_RNSGA2: u8 = 2;

/// On-disk search state: `"SHSS"` + version, the run's identity
/// (algorithm, seed, population size, space shape), progress
/// (generations done, evaluations spent), the xoshiro RNG state, the
/// population with objectives, and the evaluator cache — everything a
/// resume needs to replay the remaining generations bit-identically.
/// For hill climbing, `pop` holds one individual whose genes are
/// concrete ranks (the climb walks rank space, not choice indices).
struct Snapshot {
    algo: u8,
    seed: u64,
    pop_size: usize,
    n_modules: usize,
    n_choices: usize,
    gen_done: usize,
    evals: usize,
    rng_s: [u64; 4],
    rng_spare: Option<f64>,
    pop: Vec<Ind>,
    cache: Vec<(Vec<usize>, f64)>,
}

impl Snapshot {
    fn for_evolution<E: Evaluator>(
        algo: u8,
        seed: u64,
        evo: &Evolution<'_, E>,
        pop: &[Ind],
        gen_done: usize,
    ) -> Snapshot {
        let (rng_s, rng_spare) = evo.rng.state();
        Snapshot {
            algo,
            seed,
            pop_size: evo.pop_size,
            n_modules: evo.space.n_modules,
            n_choices: evo.space.choices.len(),
            gen_done,
            evals: evo.ev.evals,
            rng_s,
            rng_spare,
            pop: pop.to_vec(),
            cache: evo.ev.cache_entries(),
        }
    }

    fn for_hill_climb<E: Evaluator>(
        space: &SearchSpace,
        cur: &SubAdapterConfig,
        score: f64,
        ev: &CachedEvaluator<E>,
    ) -> Snapshot {
        Snapshot {
            algo: ALGO_HILL_CLIMB,
            seed: 0,
            pop_size: 1,
            n_modules: space.n_modules,
            n_choices: space.choices.len(),
            gen_done: 0,
            evals: ev.evals,
            rng_s: [0; 4],
            rng_spare: None,
            pop: vec![Ind { genes: cur.ranks.clone(), obj: vec![-score] }],
            cache: ev.cache_entries(),
        }
    }

    fn check_identity(
        &self,
        algo: u8,
        seed: u64,
        pop_size: usize,
        space: &SearchSpace,
    ) -> Result<()> {
        if self.algo != algo
            || self.seed != seed
            || self.pop_size != pop_size
            || self.n_modules != space.n_modules
            || self.n_choices != space.choices.len()
        {
            bail!(
                "snapshot identity mismatch: file is (algo {}, seed {}, pop {}, modules {}, \
                 choices {}) but this run is (algo {algo}, seed {seed}, pop {pop_size}, \
                 modules {}, choices {})",
                self.algo,
                self.seed,
                self.pop_size,
                self.n_modules,
                self.n_choices,
                space.n_modules,
                space.choices.len(),
            );
        }
        Ok(())
    }

    fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut p = Vec::new();
        p.extend_from_slice(b"SHSS");
        p.extend_from_slice(&1u32.to_le_bytes()); // version
        p.push(self.algo);
        p.extend_from_slice(&self.seed.to_le_bytes());
        for v in [self.pop_size, self.n_modules, self.n_choices, self.gen_done, self.evals] {
            p.extend_from_slice(&(v as u64).to_le_bytes());
        }
        for w in self.rng_s {
            p.extend_from_slice(&w.to_le_bytes());
        }
        p.push(self.rng_spare.is_some() as u8);
        p.extend_from_slice(&self.rng_spare.unwrap_or(0.0).to_le_bytes());
        let write_usizes = |p: &mut Vec<u8>, xs: &[usize]| {
            p.extend_from_slice(&(xs.len() as u64).to_le_bytes());
            for &x in xs {
                p.extend_from_slice(&(x as u64).to_le_bytes());
            }
        };
        let write_f64s = |p: &mut Vec<u8>, xs: &[f64]| {
            p.extend_from_slice(&(xs.len() as u64).to_le_bytes());
            for &x in xs {
                p.extend_from_slice(&x.to_le_bytes());
            }
        };
        p.extend_from_slice(&(self.pop.len() as u64).to_le_bytes());
        for ind in &self.pop {
            write_usizes(&mut p, &ind.genes);
            write_f64s(&mut p, &ind.obj);
        }
        p.extend_from_slice(&(self.cache.len() as u64).to_le_bytes());
        for (key, val) in &self.cache {
            write_usizes(&mut p, key);
            p.extend_from_slice(&val.to_le_bytes());
        }
        durable::write_atomic(path, &p)
            .with_context(|| format!("save search snapshot {}", path.display()))
    }

    fn load(path: &std::path::Path) -> Result<Snapshot> {
        let payload = durable::read_verified_strict(path, "snapshot")?;
        let mut r = std::io::Cursor::new(payload.as_slice());
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("corrupt snapshot: truncated header")?;
        if &magic != b"SHSS" {
            bail!("not a shears search snapshot");
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4).context("corrupt snapshot: truncated header")?;
        let version = u32::from_le_bytes(b4);
        if version != 1 {
            bail!("corrupt snapshot: unsupported version {version}");
        }
        let mut b1 = [0u8; 1];
        r.read_exact(&mut b1).context("corrupt snapshot: truncated header")?;
        let algo = b1[0];
        let read_u64 = |r: &mut std::io::Cursor<&[u8]>| -> Result<u64> {
            let mut b8 = [0u8; 8];
            r.read_exact(&mut b8).context("corrupt snapshot: truncated")?;
            Ok(u64::from_le_bytes(b8))
        };
        let seed = read_u64(&mut r)?;
        let pop_size = read_u64(&mut r)? as usize;
        let n_modules = read_u64(&mut r)? as usize;
        let n_choices = read_u64(&mut r)? as usize;
        let gen_done = read_u64(&mut r)? as usize;
        let evals = read_u64(&mut r)? as usize;
        let mut rng_s = [0u64; 4];
        for w in rng_s.iter_mut() {
            *w = read_u64(&mut r)?;
        }
        let mut b1 = [0u8; 1];
        r.read_exact(&mut b1).context("corrupt snapshot: truncated")?;
        let spare_bits = read_u64(&mut r)?;
        let rng_spare = (b1[0] != 0).then(|| f64::from_bits(spare_bits));
        // bound every length claim by the remaining payload so a
        // corrupt count is a clean error, not an OOM attempt
        let remaining =
            |r: &std::io::Cursor<&[u8]>| payload.len().saturating_sub(r.position() as usize);
        let read_len = |r: &mut std::io::Cursor<&[u8]>, what: &str| -> Result<usize> {
            let mut b8 = [0u8; 8];
            r.read_exact(&mut b8).context("corrupt snapshot: truncated")?;
            let n = u64::from_le_bytes(b8) as usize;
            if n > remaining(r) {
                bail!("corrupt snapshot: {what} count {n} exceeds payload");
            }
            Ok(n)
        };
        let read_usizes = |r: &mut std::io::Cursor<&[u8]>, what: &str| -> Result<Vec<usize>> {
            let n = read_len(r, what)?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                let mut b8 = [0u8; 8];
                r.read_exact(&mut b8).context("corrupt snapshot: truncated")?;
                out.push(u64::from_le_bytes(b8) as usize);
            }
            Ok(out)
        };
        let read_f64s = |r: &mut std::io::Cursor<&[u8]>, what: &str| -> Result<Vec<f64>> {
            let n = read_len(r, what)?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                let mut b8 = [0u8; 8];
                r.read_exact(&mut b8).context("corrupt snapshot: truncated")?;
                out.push(f64::from_le_bytes(b8));
            }
            Ok(out)
        };
        let pop_len = read_len(&mut r, "population")?;
        let mut pop = Vec::with_capacity(pop_len);
        for i in 0..pop_len {
            let genes = read_usizes(&mut r, "genes")
                .with_context(|| format!("corrupt snapshot: individual {i} of {pop_len}"))?;
            let obj = read_f64s(&mut r, "objectives")
                .with_context(|| format!("corrupt snapshot: individual {i} of {pop_len}"))?;
            pop.push(Ind { genes, obj });
        }
        let cache_len = read_len(&mut r, "cache")?;
        let mut cache = Vec::with_capacity(cache_len);
        for i in 0..cache_len {
            let key = read_usizes(&mut r, "cache key")
                .with_context(|| format!("corrupt snapshot: cache entry {i} of {cache_len}"))?;
            let mut b8 = [0u8; 8];
            r.read_exact(&mut b8).context("corrupt snapshot: truncated")?;
            cache.push((key, f64::from_le_bytes(b8)));
        }
        let pos = r.position() as usize;
        if pos != payload.len() {
            bail!("corrupt snapshot: {} trailing bytes", payload.len() - pos);
        }
        Ok(Snapshot {
            algo,
            seed,
            pop_size,
            n_modules,
            n_choices,
            gen_done,
            evals,
            rng_s,
            rng_spare,
            pop,
            cache,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn space() -> SearchSpace {
        SearchSpace {
            choices: vec![8, 6, 4],
            n_modules: 6,
            max_rank: 8,
            dims: vec![(32, 32); 6],
        }
    }

    /// Synthetic landscape: accuracy rises with total rank, with a dip at
    /// the maximum (so search must find an interior optimum).
    fn landscape(cfg: &SubAdapterConfig) -> f64 {
        let total: usize = cfg.ranks.iter().sum();
        let t = total as f64;
        -(t - 40.0).abs() / 40.0 + 1.0 // peak at total rank 40
    }

    #[test]
    fn cache_avoids_recomputation() {
        let mut calls = 0usize;
        let mut ev = CachedEvaluator::new(|c: &SubAdapterConfig| {
            calls += 1;
            c.ranks[0] as f64
        });
        let s = space();
        let c = s.maximal();
        let a = ev.eval(&c);
        let b = ev.eval(&c);
        assert_eq!(a, b);
        assert_eq!(ev.evals, 1);
        drop(ev);
        assert_eq!(calls, 1);
    }

    #[test]
    fn hill_climb_improves_over_start() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let start = s.minimal(); // total 24, below the peak
        let start_score = landscape(&start);
        let r = hill_climb(&s, start, &mut ev, 500);
        assert!(r.score >= start_score);
        // peak at total 40 is reachable: e.g. 6*6=36..8*6=48 — 40 = 4×6+2×8
        assert!(r.score > 0.9, "{:?}", r);
    }

    #[test]
    fn hill_climb_respects_budget() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let r = hill_climb(&s, s.minimal(), &mut ev, 3);
        assert!(r.evals <= 3 + 1); // start eval + budgeted neighbors
    }

    #[test]
    fn non_dominated_sort_fronts_are_correct() {
        // objectives (minimize both): a=(0,0) dominates all; b,c incomparable
        let objs = vec![vec![0.0, 0.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 3.0]];
        let fronts = non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0]);
        assert_eq!(fronts[1], vec![1, 2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn sort_invariants_hold_on_random_objectives() {
        check("nds invariants", 60, |g| {
            let n = g.usize_in(1..12);
            let objs: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![g.f32_in(0.0, 1.0) as f64, g.f32_in(0.0, 1.0) as f64])
                .collect();
            let fronts = non_dominated_sort(&objs);
            // partition
            let total: usize = fronts.iter().map(|f| f.len()).sum();
            assert_eq!(total, n);
            // no individual dominates another within a front
            for front in &fronts {
                for &i in front {
                    for &j in front {
                        assert!(i == j || !dominates(&objs[i], &objs[j]));
                    }
                }
            }
            // every front-k+1 member is dominated by someone in front k
            for w in 1..fronts.len() {
                for &j in &fronts[w] {
                    assert!(
                        fronts[w - 1].iter().any(|&i| dominates(&objs[i], &objs[j])),
                        "front {w} member {j} undominated by front {}",
                        w - 1
                    );
                }
            }
        });
    }

    #[test]
    fn crowding_extremes_are_infinite() {
        let objs = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let front: Vec<usize> = (0..4).collect();
        let cd = crowding_distance(&objs, &front);
        assert!(cd[0].is_infinite() && cd[3].is_infinite());
        assert!(cd[1].is_finite() && cd[2].is_finite());
    }

    #[test]
    fn nsga2_finds_good_interior_config() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        let r = nsga2(&s, &mut ev, 42, 12, 10, 400);
        assert!(r.score > 0.85, "{r:?}");
        assert!(s.contains(&r.config));
    }

    #[test]
    fn rnsga2_converges_toward_reference() {
        let s = space();
        let mut ev = CachedEvaluator::new(landscape);
        // aspire to top accuracy at ~70% params
        let r = rnsga2(&s, &mut ev, 42, 12, 10, 400, vec![-1.0, 0.7]);
        assert!(r.score > 0.8, "{r:?}");
        assert!(s.contains(&r.config));
    }

    #[test]
    fn evolutionary_costs_more_than_hill_climb() {
        // the paper's cost argument (§3.3): hill-climbing is cheaper
        let s = space();
        let mut ev1 = CachedEvaluator::new(landscape);
        let hc = hill_climb(&s, s.heuristic(), &mut ev1, 10_000);
        let mut ev2 = CachedEvaluator::new(landscape);
        let ga = nsga2(&s, &mut ev2, 1, 12, 10, 10_000);
        assert!(hc.evals < ga.evals, "hc={} ga={}", hc.evals, ga.evals);
    }
}
