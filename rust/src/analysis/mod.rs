//! Crate-native static analysis: `shears-lint`.
//!
//! A zero-dependency source-level lint pass over this crate's own
//! sources, enforcing the written concurrency/durability policy that
//! the reproduction's correctness arguments rest on:
//!
//! * **safety** — every `unsafe` block / `unsafe impl` carries an
//!   adjacent `// SAFETY:` justification (same line, or a contiguous
//!   comment block directly above).
//! * **ordering** — every `Ordering::`/`AOrd::` argument at an atomic
//!   call site matches the role its receiver declared in a
//!   `// ORDERING(name): role` annotation next to the field/static.
//!   Roles: `counter`/`config` may only use `Relaxed`, `handshake`
//!   only `Acquire`/`Release`, `shutdown` only `SeqCst`, `gauge`
//!   anything except `SeqCst`. Undeclared receivers and unused
//!   declarations are both errors.
//! * **hotpath** — no `unwrap()` / `expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in non-test code
//!   under `serve/`, `runtime/`, `coordinator/`. Justified sites go
//!   in the allowlist file (`rust/shears-lint.allow`), each with a
//!   written justification; stale entries are errors.
//! * **time** — `Instant::now` / `SystemTime::now` / `thread::sleep`
//!   only in the wall-clock-aware modules (fault injection, serving,
//!   the eval router, logging, bench utils). Everything feeding the
//!   bit-identity suites (ops, train, search, pruning, model, tensor)
//!   must stay deterministic.
//! * **durable** — all file persistence goes through
//!   [`crate::util::durable`]: no raw `File::create` /
//!   `OpenOptions::new` / `fs::write` outside it.
//!
//! The pass is line-based on a comment/string-stripped view of each
//! file (so tokens inside string literals or doc comments never
//! trigger rules) and skips everything from a top-level `#[cfg(test)]`
//! marker to end of file — by crate convention the test module is the
//! last item in every source file.
//!
//! Run it with `cargo run --bin shears-lint`, `shears lint`, or as a
//! tier-1 test via `cargo test --test lints`.

use std::fmt;
use std::path::Path;

// ------------------------------------------------------------- rules

/// Lint rule identifiers (stable names used in diagnostics and in the
/// allowlist file).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    Safety,
    Ordering,
    HotPath,
    Time,
    Durable,
    /// Allowlist hygiene: malformed or stale entries.
    Allowlist,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::Safety => "safety",
            Rule::Ordering => "ordering",
            Rule::HotPath => "hotpath",
            Rule::Time => "time",
            Rule::Durable => "durable",
            Rule::Allowlist => "allowlist",
        }
    }

    fn from_name(s: &str) -> Option<Rule> {
        Some(match s {
            "safety" => Rule::Safety,
            "ordering" => Rule::Ordering,
            "hotpath" => Rule::HotPath,
            "time" => Rule::Time,
            "durable" => Rule::Durable,
            _ => return None,
        })
    }
}

/// One finding, anchored to `file:line`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.name(), self.msg)
    }
}

// --------------------------------------------------------- allowlist

/// One suppression: `rule|path-suffix|line-substring|justification`.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub rule: Rule,
    pub path: String,
    pub needle: String,
    pub why: String,
    pub used: bool,
}

/// Parsed allowlist. Entries without a justification are rejected at
/// parse time ("zero allowlist additions beyond documented ones");
/// entries that suppress nothing are reported stale by [`lint_crate`].
#[derive(Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// Parse the `rule|path|substring|justification` format. `#`
    /// lines and blanks are skipped. Malformed lines become
    /// diagnostics rather than being silently dropped.
    pub fn parse(src: &str, origin: &str) -> (Allowlist, Vec<Diagnostic>) {
        let mut entries = Vec::new();
        let mut diags = Vec::new();
        for (i, raw) in src.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(4, '|');
            let bad = |msg: &str| Diagnostic {
                rule: Rule::Allowlist,
                file: origin.to_string(),
                line: i + 1,
                msg: msg.to_string(),
            };
            let rule = parts.next().unwrap_or("").trim();
            let path = parts.next().unwrap_or("").trim();
            let needle = parts.next().unwrap_or("").trim();
            let why = parts.next().unwrap_or("").trim();
            let Some(rule) = Rule::from_name(rule) else {
                diags.push(bad(&format!("unknown rule {rule:?} (want rule|path|substring|why)")));
                continue;
            };
            if path.is_empty() || needle.is_empty() {
                diags.push(bad("entry needs a path suffix and a line substring"));
                continue;
            }
            if why.is_empty() {
                diags.push(bad("entry has no justification (4th |-field is required)"));
                continue;
            }
            entries.push(AllowEntry {
                rule,
                path: path.to_string(),
                needle: needle.to_string(),
                why: why.to_string(),
                used: false,
            });
        }
        (Allowlist { entries }, diags)
    }

    /// True (and marks the entry used) if some entry covers `d` given
    /// the raw source line it fired on.
    fn covers(&mut self, d: &Diagnostic, raw_line: &str) -> bool {
        for e in &mut self.entries {
            if e.rule == d.rule && d.file.ends_with(&e.path) && raw_line.contains(&e.needle) {
                e.used = true;
                return true;
            }
        }
        false
    }
}

// ----------------------------------------------- source preprocessing

/// A comment/string-stripped view of one source file. `code[i]` is
/// line `i` with comment text and literal contents blanked to spaces
/// (structure and byte offsets preserved); `comment[i]` is the text of
/// the `//` comment on line `i` (empty if none); `raw[i]` is the
/// original line. `test_from` is the first line index of a top-level
/// `#[cfg(test)]` marker (lines from there on are skipped by every
/// rule), or `len` if none.
struct SourceView {
    code: Vec<String>,
    comment: Vec<String>,
    raw: Vec<String>,
    test_from: usize,
}

#[derive(PartialEq)]
enum ScanState {
    Code,
    LineComment,
    BlockComment(usize),
    Str,
    RawStr(usize),
}

fn preprocess(src: &str) -> SourceView {
    let mut code_all = String::with_capacity(src.len());
    let mut comment_all = String::with_capacity(64);
    let mut comments: Vec<String> = Vec::new();
    let mut state = ScanState::Code;
    let bytes: Vec<char> = src.chars().collect();
    let n = bytes.len();
    let mut i = 0;
    while i < n {
        let c = bytes[i];
        let next = if i + 1 < n { bytes[i + 1] } else { '\0' };
        match state {
            ScanState::Code => match c {
                '/' if next == '/' => {
                    state = ScanState::LineComment;
                    code_all.push(' ');
                    code_all.push(' ');
                    i += 2;
                    continue;
                }
                '/' if next == '*' => {
                    state = ScanState::BlockComment(1);
                    code_all.push(' ');
                    code_all.push(' ');
                    i += 2;
                    continue;
                }
                '"' => {
                    state = ScanState::Str;
                    code_all.push('"');
                }
                'r' | 'b'
                    if {
                        // raw string start: r"..." / r#"..." / br"..."
                        let prev_ident = i > 0 && (bytes[i - 1].is_alphanumeric() || bytes[i - 1] == '_');
                        let mut j = i + 1;
                        if c == 'b' && j < n && bytes[j] == 'r' {
                            j += 1;
                        } else if c == 'b' {
                            j = usize::MAX; // plain b"..." handled by Str via the '"' arm
                        }
                        !prev_ident
                            && j != usize::MAX
                            && j <= n && {
                                let mut k = j;
                                while k < n && bytes[k] == '#' {
                                    k += 1;
                                }
                                k < n && bytes[k] == '"'
                            }
                    } =>
                {
                    // consume up to and including the opening quote
                    let mut j = i + 1;
                    if c == 'b' {
                        j += 1; // the 'r'
                    }
                    let mut hashes = 0;
                    while j < n && bytes[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    for _ in i..=j {
                        code_all.push(' ');
                    }
                    i = j + 1;
                    state = ScanState::RawStr(hashes);
                    continue;
                }
                '\'' => {
                    // char literal vs lifetime: 'x' / '\n' / '\u{..}' are
                    // literals; anything else ('a in generics) is code
                    if next == '\\' {
                        code_all.push(' ');
                        i += 2;
                        while i < n && bytes[i] != '\'' {
                            code_all.push(' ');
                            i += 1;
                        }
                        code_all.push(' ');
                    } else if i + 2 < n && bytes[i + 2] == '\'' {
                        code_all.push(' ');
                        code_all.push(' ');
                        code_all.push(' ');
                        i += 2;
                    } else {
                        code_all.push('\'');
                    }
                }
                _ => code_all.push(c),
            },
            ScanState::LineComment => {
                if c == '\n' {
                    state = ScanState::Code;
                    code_all.push('\n');
                } else {
                    comment_all.push(c);
                    code_all.push(' ');
                }
            }
            ScanState::BlockComment(d) => {
                if c == '*' && next == '/' {
                    state = if d == 1 { ScanState::Code } else { ScanState::BlockComment(d - 1) };
                    code_all.push(' ');
                    code_all.push(' ');
                    i += 2;
                    continue;
                }
                if c == '/' && next == '*' {
                    state = ScanState::BlockComment(d + 1);
                    code_all.push(' ');
                    code_all.push(' ');
                    i += 2;
                    continue;
                }
                code_all.push(if c == '\n' { '\n' } else { ' ' });
            }
            ScanState::Str => {
                if c == '\\' {
                    code_all.push(' ');
                    code_all.push(if next == '\n' { '\n' } else { ' ' });
                    if next == '\n' {
                        comments.push(std::mem::take(&mut comment_all));
                    }
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = ScanState::Code;
                    code_all.push('"');
                } else {
                    code_all.push(if c == '\n' { '\n' } else { ' ' });
                }
            }
            ScanState::RawStr(hashes) => {
                if c == '"' {
                    let mut k = 0;
                    while k < hashes && i + 1 + k < n && bytes[i + 1 + k] == '#' {
                        k += 1;
                    }
                    if k == hashes {
                        for _ in 0..=hashes {
                            code_all.push(' ');
                        }
                        i += hashes + 1;
                        state = ScanState::Code;
                        continue;
                    }
                }
                code_all.push(if c == '\n' { '\n' } else { ' ' });
            }
        }
        if c == '\n' {
            comments.push(std::mem::take(&mut comment_all));
        }
        i += 1;
    }
    comments.push(std::mem::take(&mut comment_all));

    let code: Vec<String> = code_all.split('\n').map(str::to_string).collect();
    let raw: Vec<String> = src.split('\n').map(str::to_string).collect();
    comments.resize(code.len(), String::new());
    let test_from = code
        .iter()
        .position(|l| l.trim() == "#[cfg(test)]")
        .unwrap_or(code.len());
    SourceView { code, comment: comments, raw, test_from }
}

fn has_word(line: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !line[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        let end = at + word.len();
        let after_ok = end >= line.len()
            || !line[end..].chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

// -------------------------------------------------- the ordering rule

const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

fn role_allows(role: &str, ordering: &str) -> Option<bool> {
    let allowed: &[&str] = match role {
        "counter" | "config" => &["Relaxed"],
        "handshake" => &["Acquire", "Release"],
        "shutdown" => &["SeqCst"],
        "gauge" => &["Relaxed", "Acquire", "Release", "AcqRel"],
        _ => return None,
    };
    Some(allowed.contains(&ordering))
}

/// Orderings named on a code line via `Ordering::X` or `AOrd::X`.
fn orderings_on(line: &str) -> Vec<&'static str> {
    let mut found = Vec::new();
    for prefix in ["Ordering::", "AOrd::"] {
        let mut start = 0;
        while let Some(pos) = line[start..].find(prefix) {
            let at = start + pos + prefix.len();
            for o in ORDERINGS {
                if line[at..].starts_with(o) {
                    found.push(o);
                }
            }
            start = at;
        }
    }
    found
}

fn is_atomic_method(name: &str) -> bool {
    matches!(name, "load" | "store" | "swap" | "compare_exchange" | "compare_exchange_weak")
        || name.starts_with("fetch_")
}

/// Receiver field/static name of the atomic call on `joined` (the
/// current line plus up to two lines of look-back for rustfmt-wrapped
/// calls): the identifier before the last `.method(` whose method is
/// an atomic accessor.
fn atomic_receiver(joined: &str) -> Option<String> {
    let b: Vec<char> = joined.chars().collect();
    let mut best: Option<String> = None;
    let mut i = 0;
    while i < b.len() {
        if b[i] == '.' {
            let mut j = i + 1;
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            if j < b.len() && b[j] == '(' {
                let method: String = b[i + 1..j].iter().collect();
                if is_atomic_method(&method) {
                    // skip whitespace first: `depth\n    .fetch_add(` joins
                    // as `depth     .fetch_add(`
                    let mut e = i;
                    while e > 0 && b[e - 1].is_whitespace() {
                        e -= 1;
                    }
                    let mut k = e;
                    while k > 0 && (b[k - 1].is_alphanumeric() || b[k - 1] == '_') {
                        k -= 1;
                    }
                    if k < e {
                        best = Some(b[k..e].iter().collect());
                    }
                }
            }
        }
        i += 1;
    }
    best
}

// -------------------------------------------------------- the linter

/// Wall-clock-aware modules where `Instant::now` / `thread::sleep`
/// are policy: fault injection, serving (deadlines, brownout, latency
/// metrics), the eval router's supervision timeouts, logging, bench
/// utils. Everything else must stay deterministic.
const TIME_ALLOWED: [&str; 7] = [
    "fault.rs",
    "bench_util.rs",
    "util/log.rs",
    "serve/server.rs",
    "serve/mod.rs",
    "serve/brownout.rs",
    "coordinator/router.rs",
];

const HOTPATH_SCOPES: [&str; 3] = ["serve/", "runtime/", "coordinator/"];

/// Lint one in-memory source. `path` selects the per-path policies
/// (hotpath scope, time/durable exemptions); diagnostics covered by
/// `allow` are suppressed (and mark their entry used).
pub fn lint_source(path: &str, src: &str, allow: &mut Allowlist) -> Vec<Diagnostic> {
    let v = preprocess(src);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut raw_of: Vec<usize> = Vec::new(); // diag index -> line index

    let diag = |diags: &mut Vec<Diagnostic>, raw_of: &mut Vec<usize>, rule, i: usize, msg: String| {
        diags.push(Diagnostic { rule, file: path.to_string(), line: i + 1, msg });
        raw_of.push(i);
    };

    // ORDERING declarations: `// ORDERING(name): role`. Must start the
    // comment, so prose *mentioning* the syntax never parses as one.
    let mut decls: Vec<(String, String, usize, bool)> = Vec::new(); // name, role, line, used
    for (i, c) in v.comment.iter().enumerate().take(v.test_from) {
        let c = c.trim_start_matches(['!', '/', ' ']);
        if !c.starts_with("ORDERING(") {
            continue;
        }
        let rest = &c["ORDERING(".len()..];
        let Some(close) = rest.find(')') else {
            diag(&mut diags, &mut raw_of, Rule::Ordering, i, "malformed ORDERING(...) annotation".into());
            continue;
        };
        let name = rest[..close].trim().to_string();
        let role = rest[close + 1..].trim_start_matches(':').trim();
        let role = role.split_whitespace().next().unwrap_or("").to_string();
        if name.is_empty() || role_allows(&role, "Relaxed").is_none() {
            diag(
                &mut diags,
                &mut raw_of,
                Rule::Ordering,
                i,
                format!("ORDERING({name}): unknown role {role:?} (counter|gauge|handshake|shutdown|config)"),
            );
            continue;
        }
        if let Some((_, prev_role, _, _)) = decls.iter().find(|(n, ..)| *n == name) {
            if *prev_role != role {
                diag(
                    &mut diags,
                    &mut raw_of,
                    Rule::Ordering,
                    i,
                    format!("ORDERING({name}) re-declared as {role:?} (was {prev_role:?})"),
                );
            }
            continue;
        }
        decls.push((name, role, i, false));
    }

    for i in 0..v.test_from.min(v.code.len()) {
        let code = &v.code[i];
        let trimmed = code.trim();

        // ---- safety
        if has_word(code, "unsafe") {
            let mut ok = v.comment[i].contains("SAFETY");
            let mut j = i;
            while !ok && j > 0 {
                j -= 1;
                let c_code = v.code[j].trim();
                let is_comment_only = c_code.is_empty() && !v.comment[j].trim().is_empty();
                let is_attr = c_code.starts_with("#[");
                if !(is_comment_only || is_attr) {
                    break;
                }
                if v.comment[j].contains("SAFETY") {
                    ok = true;
                }
            }
            if !ok {
                diag(
                    &mut diags,
                    &mut raw_of,
                    Rule::Safety,
                    i,
                    "`unsafe` without an adjacent `// SAFETY:` justification".into(),
                );
            }
        }

        // ---- ordering call sites
        let ords = orderings_on(code);
        if !ords.is_empty() {
            let lo = i.saturating_sub(2);
            let joined = v.code[lo..=i].join(" ");
            match atomic_receiver(&joined) {
                None => diag(
                    &mut diags,
                    &mut raw_of,
                    Rule::Ordering,
                    i,
                    "memory ordering outside a recognized atomic call".into(),
                ),
                Some(recv) => match decls.iter_mut().find(|(n, ..)| *n == recv) {
                    None => diag(
                        &mut diags,
                        &mut raw_of,
                        Rule::Ordering,
                        i,
                        format!("atomic `{recv}` has no `// ORDERING({recv}): role` declaration in this file"),
                    ),
                    Some((_, role, _, used)) => {
                        *used = true;
                        let role = role.clone();
                        for o in ords {
                            if !role_allows(&role, o).unwrap_or(false) {
                                diag(
                                    &mut diags,
                                    &mut raw_of,
                                    Rule::Ordering,
                                    i,
                                    format!("`{recv}` is declared {role:?} but uses Ordering::{o}"),
                                );
                            }
                        }
                    }
                },
            }
        }

        // ---- hotpath
        if HOTPATH_SCOPES.iter().any(|s| path.contains(s)) {
            for pat in [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("]
            {
                if code.contains(pat) {
                    diag(
                        &mut diags,
                        &mut raw_of,
                        Rule::HotPath,
                        i,
                        format!("`{pat}` in a serve/runtime hot path (return a typed error, \
                                 use `unwrap_or_else(|e| e.into_inner())` for mutexes, or add \
                                 a justified allowlist entry)"),
                    );
                }
            }
        }

        // ---- time
        if !TIME_ALLOWED.iter().any(|s| path.ends_with(s)) {
            for pat in ["Instant::now", "SystemTime::now", "thread::sleep"] {
                if code.contains(pat) {
                    diag(
                        &mut diags,
                        &mut raw_of,
                        Rule::Time,
                        i,
                        format!("`{pat}` outside the wall-clock-aware modules breaks the \
                                 bit-identity suites' determinism"),
                    );
                }
            }
        }

        // ---- durable
        if !path.ends_with("util/durable.rs") {
            for pat in ["File::create", "OpenOptions::new", "File::options", "fs::write"] {
                if code.contains(pat) {
                    diag(
                        &mut diags,
                        &mut raw_of,
                        Rule::Durable,
                        i,
                        format!("`{pat}` bypasses `util::durable` (atomic rename + checksum \
                                 footer); persist through `durable::write_atomic`"),
                    );
                }
            }
        }
        let _ = trimmed;
    }

    // unused ORDERING declarations are stale policy
    for (name, _, line, used) in &decls {
        if !used {
            diag(
                &mut diags,
                &mut raw_of,
                Rule::Ordering,
                *line,
                format!("ORDERING({name}) declared but `{name}` has no atomic call site in this file"),
            );
        }
    }

    // apply the allowlist against raw source lines
    let mut kept = Vec::new();
    for (d, ri) in diags.into_iter().zip(raw_of) {
        let raw_line = v.raw.get(ri).map(String::as_str).unwrap_or("");
        if !allow.covers(&d, raw_line) {
            kept.push(d);
        }
    }
    kept
}

// --------------------------------------------------- crate-tree walk

/// Outcome of a full-tree pass.
pub struct LintReport {
    pub diags: Vec<Diagnostic>,
    pub files: usize,
    pub allow_total: usize,
    pub allow_used: usize,
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root` (the crate's `src/`
/// directory) against the allowlist at `allow_path` (if it exists).
/// Stale allowlist entries — documented suppressions that no longer
/// fire — are reported as diagnostics so the file cannot rot.
pub fn lint_crate(src_root: &Path, allow_path: Option<&Path>) -> std::io::Result<LintReport> {
    let (mut allow, mut diags) = match allow_path {
        Some(p) if p.exists() => {
            let text = std::fs::read_to_string(p)?;
            Allowlist::parse(&text, &p.display().to_string())
        }
        _ => (Allowlist::default(), Vec::new()),
    };
    let mut files = Vec::new();
    walk(src_root, &mut files)?;
    let n_files = files.len();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        // diagnostics use paths relative to src_root's parent (so
        // `src/serve/server.rs`) — stable across checkouts
        let rel = f
            .strip_prefix(src_root.parent().unwrap_or(src_root))
            .unwrap_or(f)
            .display()
            .to_string();
        diags.extend(lint_source(&rel, &src, &mut allow));
    }
    for e in &allow.entries {
        if !e.used {
            diags.push(Diagnostic {
                rule: Rule::Allowlist,
                file: e.path.clone(),
                line: 0,
                msg: format!(
                    "stale allowlist entry (rule {}, substring {:?}) — the site it \
                     justified is gone; remove it",
                    e.rule.name(),
                    e.needle
                ),
            });
        }
    }
    let allow_total = allow.entries.len();
    let allow_used = allow.entries.iter().filter(|e| e.used).count();
    Ok(LintReport { diags, files: n_files, allow_total, allow_used })
}

/// Locate this crate's `src/` + allowlist from the compile-time
/// manifest dir and run the full pass (shared by the `shears-lint`
/// binary, `shears lint`, and `tests/lints.rs`).
pub fn lint_self() -> std::io::Result<LintReport> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    lint_crate(&manifest.join("src"), Some(&manifest.join("shears-lint.allow")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(path: &str, src: &str) -> Vec<Diagnostic> {
        lint_source(path, src, &mut Allowlist::default())
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let v = preprocess("let a = \"unsafe File::create\"; // unsafe too\nlet b = 'x';\n");
        assert!(!v.code[0].contains("unsafe"));
        assert!(v.comment[0].contains("unsafe too"));
        assert!(!v.code[1].contains('x'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let v = preprocess("let a = r#\"File::create \"quoted\" unsafe\"#; let c = 1;\n");
        assert!(!v.code[0].contains("File::create"));
        assert!(v.code[0].contains("let c = 1;"));
    }

    #[test]
    fn multiline_string_does_not_leak_into_code() {
        let v = preprocess("let h = \"span \\\n  File::create\";\nlet x = 2;\n");
        assert!(!v.code.join("\n").contains("File::create"));
        assert!(v.code[2].contains("let x = 2;"));
    }

    #[test]
    fn safety_comment_forms_accepted() {
        let ok_above = "// SAFETY: fine\nunsafe impl Send for X {}\n";
        let ok_trailing = "unsafe impl Send for X {} // SAFETY: fine\n";
        let ok_block = "// SAFETY: part one\n// and part two\nlet p = unsafe { q };\n";
        for src in [ok_above, ok_trailing, ok_block] {
            assert!(lint("src/x.rs", src).is_empty(), "{src:?}");
        }
        let missing = "unsafe impl Send for X {}\n";
        let d = lint("src/x.rs", missing);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::Safety);
        assert_eq!(d[0].line, 1);
    }

    #[test]
    fn blank_line_breaks_safety_adjacency() {
        let src = "// SAFETY: too far away\n\nlet p = unsafe { q };\n";
        assert_eq!(lint("src/x.rs", src).len(), 1);
    }

    #[test]
    fn ordering_roles_enforced() {
        let ok = "// ORDERING(hits): counter\nhits.fetch_add(1, Ordering::Relaxed);\n";
        assert!(lint("src/x.rs", ok).is_empty());
        let wrong = "// ORDERING(hits): counter\nhits.fetch_add(1, Ordering::SeqCst);\n";
        let d = lint("src/x.rs", wrong);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, Rule::Ordering);
        let undeclared = "flag.store(true, Ordering::Release);\n";
        assert_eq!(lint("src/x.rs", undeclared)[0].rule, Rule::Ordering);
        let unused = "// ORDERING(ghost): counter\nlet x = 1;\n";
        assert!(lint("src/x.rs", unused)[0].msg.contains("no atomic call site"));
    }

    #[test]
    fn ordering_receiver_found_across_wrapped_lines() {
        let src = "// ORDERING(depth): gauge\nlet d = self.shared.depth\n    .load(Ordering::Acquire);\n";
        assert!(lint("src/x.rs", src).is_empty());
    }

    #[test]
    fn hotpath_scoped_and_allowlisted() {
        let src = "fn f() { x.unwrap(); }\n";
        assert!(lint("src/ops/x.rs", src).is_empty(), "out of scope");
        let d = lint("src/serve/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::HotPath);
        let (mut allow, errs) =
            Allowlist::parse("hotpath|serve/x.rs|x.unwrap()|invariant: x set above", "t");
        assert!(errs.is_empty());
        assert!(lint_source("src/serve/x.rs", src, &mut allow).is_empty());
        assert!(allow.entries[0].used);
    }

    #[test]
    fn allowlist_requires_justification() {
        let (_, errs) = Allowlist::parse("hotpath|serve/x.rs|x.unwrap()", "t");
        assert_eq!(errs.len(), 1);
        assert!(errs[0].msg.contains("justification"));
    }

    #[test]
    fn time_and_durable_rules() {
        let t = "let t = Instant::now();\n";
        assert_eq!(lint("src/ops/x.rs", t)[0].rule, Rule::Time);
        assert!(lint("src/fault.rs", t).is_empty());
        let d = "let f = File::create(p)?;\n";
        assert_eq!(lint("src/model/x.rs", d)[0].rule, Rule::Durable);
        assert!(lint("src/util/durable.rs", d).is_empty());
    }

    #[test]
    fn test_region_is_skipped() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g() { x.unwrap(); let p = unsafe { q }; }\n}\n";
        assert!(lint("src/serve/x.rs", src).is_empty());
    }
}
