//! Token-id layout shared by every synthetic task.
//!
//! The vocab is purely positional (no string table): special tokens, then
//! digits, operators, choice letters, yes/no, and a "word" region used as
//! filler nouns/verbs by the generators. Everything fits in the smallest
//! model vocab (256).

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    /// question/answer separator ("The answer is")
    pub sep: i32,
    /// digits 0..=9
    pub digit0: i32,
    /// + - * = ( ) , ? tokens
    pub plus: i32,
    pub minus: i32,
    pub times: i32,
    pub eq: i32,
    pub gt: i32,
    pub lt: i32,
    pub comma: i32,
    pub qmark: i32,
    /// choice letters A..=E
    pub choice_a: i32,
    pub yes: i32,
    pub no: i32,
    /// start of the word region (filler vocabulary)
    pub word0: i32,
    pub n_words: usize,
}

impl Vocab {
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 64, "vocab too small: {size}");
        let word0 = 32;
        Vocab {
            size,
            pad: 0,
            bos: 1,
            eos: 2,
            sep: 3,
            digit0: 4, // 4..14
            plus: 14,
            minus: 15,
            times: 16,
            eq: 17,
            gt: 18,
            lt: 19,
            comma: 20,
            qmark: 21,
            choice_a: 22, // 22..27 = A..E
            yes: 27,
            no: 28,
            word0: word0 as i32,
            n_words: size - word0,
        }
    }

    pub fn digit(&self, d: u32) -> i32 {
        debug_assert!(d < 10);
        self.digit0 + d as i32
    }

    pub fn choice(&self, c: usize) -> i32 {
        debug_assert!(c < 5);
        self.choice_a + c as i32
    }

    /// A filler "word" token by index (mod region size).
    pub fn word(&self, i: usize) -> i32 {
        self.word0 + (i % self.n_words) as i32
    }

    /// Encode a non-negative number as digit tokens (base 10, msd first).
    pub fn number(&self, n: u32) -> Vec<i32> {
        if n == 0 {
            return vec![self.digit(0)];
        }
        let mut digits = Vec::new();
        let mut m = n;
        while m > 0 {
            digits.push(self.digit(m % 10));
            m /= 10;
        }
        digits.reverse();
        digits
    }

    /// Decode digit tokens back to a number (None if any non-digit).
    pub fn parse_number(&self, toks: &[i32]) -> Option<u32> {
        let mut n: u32 = 0;
        for t in toks {
            let d = t - self.digit0;
            if !(0..10).contains(&d) {
                return None;
            }
            n = n.checked_mul(10)?.checked_add(d as u32)?;
        }
        Some(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_roundtrip() {
        let v = Vocab::new(256);
        for n in [0u32, 1, 9, 10, 42, 105, 999] {
            assert_eq!(v.parse_number(&v.number(n)), Some(n));
        }
        assert_eq!(v.parse_number(&[v.plus]), None);
    }

    #[test]
    fn regions_disjoint() {
        let v = Vocab::new(256);
        let ids = [
            v.pad, v.bos, v.eos, v.sep, v.digit(0), v.digit(9), v.plus, v.minus,
            v.times, v.eq, v.gt, v.lt, v.comma, v.qmark, v.choice(0), v.choice(4),
            v.yes, v.no, v.word(0),
        ];
        let set: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(set.len(), ids.len());
        assert!(ids.iter().all(|i| (0..256).contains(i)));
    }

    #[test]
    fn words_wrap_in_region() {
        let v = Vocab::new(64);
        for i in 0..200 {
            let w = v.word(i);
            assert!((v.word0..v.size as i32).contains(&w));
        }
    }
}
