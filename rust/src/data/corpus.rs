//! Pretraining corpus for the in-repo base models (DESIGN.md §3: the
//! stand-in for LLaMA/MPT pretraining).
//!
//! A mixture of primitive "competency" sequences — counting runs, digit
//! arithmetic facts, comparisons, symbol patterns and key/value pairs —
//! that give a from-scratch model the skills the downstream tasks assume,
//! *without* leaking the task QA format (so w/o-tune ablation rows stay
//! near chance like the paper's zero-shot rows).

use super::vocab::Vocab;
use crate::util::rng::Rng;

/// One pretraining sequence (next-token loss over the whole thing).
pub fn sample(v: &Vocab, rng: &mut Rng, max_len: usize) -> Vec<i32> {
    let mut t = vec![v.bos];
    while t.len() + 12 < max_len {
        match rng.below(5) {
            // counting run: n, n+1, n+2, …
            0 => {
                let start = rng.range(0, 60) as u32;
                for i in 0..4 {
                    t.extend(v.number(start + i));
                    t.push(v.comma);
                }
            }
            // arithmetic fact: a + b = c  /  a - b = c
            1 => {
                let a = rng.range(1, 60) as u32;
                let b = rng.range(1, 40) as u32;
                let add = rng.bool(0.5);
                let (x, y, c) = if add {
                    (a, b, a + b)
                } else {
                    (a.max(b), a.min(b), a.max(b) - a.min(b))
                };
                t.extend(v.number(x));
                t.push(if add { v.plus } else { v.minus });
                t.extend(v.number(y));
                t.push(v.eq);
                t.extend(v.number(c));
                t.push(v.comma);
            }
            // true comparison: a > b
            2 => {
                let a = rng.range(1, 99) as u32;
                let b = rng.range(0, a as i64) as u32;
                t.extend(v.number(a));
                t.push(v.gt);
                t.extend(v.number(b));
                t.push(v.comma);
            }
            // symbol pattern: w1 w2 w1 w2 w1 w2
            3 => {
                let w1 = v.word(rng.below(v.n_words));
                let w2 = v.word(rng.below(v.n_words));
                for _ in 0..3 {
                    t.push(w1);
                    t.push(w2);
                }
                t.push(v.comma);
            }
            // key/value fact, later repeated (retrieval skill)
            _ => {
                let k = v.word(rng.below(v.n_words));
                let val = rng.range(0, 60) as u32;
                t.push(k);
                t.push(v.eq);
                t.extend(v.number(val));
                t.push(v.comma);
                t.push(k);
                t.push(v.eq);
                t.extend(v.number(val));
                t.push(v.comma);
            }
        }
    }
    t.truncate(max_len - 1);
    t.push(v.eos);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_fit_and_are_varied() {
        let v = Vocab::new(256);
        let mut rng = Rng::new(0);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..50 {
            let s = sample(&v, &mut rng, 48);
            assert!(s.len() <= 48);
            assert_eq!(s[0], v.bos);
            assert_eq!(*s.last().unwrap(), v.eos);
            assert!(s.iter().all(|t| (0..256).contains(t)));
            distinct.insert(s);
        }
        assert!(distinct.len() > 40);
    }
}
