//! Commonsense-reasoning simulants (paper Table 2 datasets, DESIGN.md §3).
//!
//! Eight distinct rule-based distributions, all answered with a single
//! token (yes/no or a choice letter) so accuracy is comparable across
//! tasks — the same protocol as the unified LLM-Adapters commonsense
//! suite. Each simulant keeps the *kind* of reasoning of its namesake:
//! boolean comparison (BoolQ), physical-continuation choice (PIQA),
//! social-relation lookup (SIQA), sequence completion (HellaSwag),
//! referent resolution (WinoGrande), single/composed rule application
//! (ARC-e/ARC-c) and fact retrieval (OBQA).

use super::vocab::Vocab;
use super::Example;
use crate::util::rng::Rng;

fn finish(v: &Vocab, mut tokens: Vec<i32>, answer: i32, max_len: usize) -> Example {
    tokens.push(v.sep);
    let answer_start = tokens.len();
    tokens.push(answer);
    tokens.push(v.eos);
    assert!(tokens.len() <= max_len);
    Example { tokens, answer_start, answer_len: 1 }
}

/// BoolQ-sim: "a > b ?" → yes/no.
pub fn boolq_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(0, 99) as u32;
    let mut b = rng.range(0, 99) as u32;
    if a == b {
        b += 1;
    }
    let mut t = vec![v.bos];
    t.extend(v.number(a));
    t.push(v.gt);
    t.extend(v.number(b));
    t.push(v.qmark);
    finish(v, t, if a > b { v.yes } else { v.no }, max_len)
}

/// PIQA-sim: a repeated "action" pattern; pick the continuation that keeps
/// the pattern going (2 options).
pub fn piqa_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let w = v.word(rng.below(v.n_words / 2));
    let other = v.word(v.n_words / 2 + rng.below(v.n_words / 2 - 1));
    let mut t = vec![v.bos, w, w, w, v.qmark];
    let correct = rng.below(2);
    for i in 0..2 {
        t.push(v.choice(i));
        t.push(if i == correct { w } else { other });
        t.push(v.comma);
    }
    finish(v, t, v.choice(correct), max_len)
}

/// SIQA-sim: a stated relation "x = y"; asked about x, pick y (3 options).
pub fn siqa_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let x = v.word(rng.below(v.n_words));
    let mut ys = [0i32; 3];
    for (i, y) in ys.iter_mut().enumerate() {
        *y = v.word((rng.below(v.n_words / 3) + i * (v.n_words / 3)).min(v.n_words - 1));
    }
    let correct = rng.below(3);
    let mut t = vec![v.bos, x, v.eq, ys[correct], v.comma, x, v.qmark];
    for (i, y) in ys.iter().enumerate() {
        t.push(v.choice(i));
        t.push(*y);
        t.push(v.comma);
    }
    finish(v, t, v.choice(correct), max_len)
}

/// HellaSwag-sim: arithmetic progression completion (4 options).
pub fn hellaswag_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let start = rng.range(1, 40) as u32;
    let d = rng.range(1, 9) as u32;
    let mut t = vec![v.bos];
    for i in 0..3 {
        t.extend(v.number(start + i * d));
        t.push(v.comma);
    }
    t.push(v.qmark);
    let correct_val = start + 3 * d;
    let mut opts = vec![correct_val];
    while opts.len() < 4 {
        let c = (correct_val as i64 + rng.range(-6, 7)).max(0) as u32;
        if !opts.contains(&c) {
            opts.push(c);
        }
    }
    rng.shuffle(&mut opts);
    let idx = opts.iter().position(|x| *x == correct_val).unwrap();
    for (i, o) in opts.iter().enumerate() {
        t.push(v.choice(i));
        t.extend(v.number(*o));
        t.push(v.comma);
    }
    finish(v, t, v.choice(idx), max_len)
}

/// WinoGrande-sim: two entities, one relation "e1 > e2"; resolve which
/// entity the question refers to (2 options).
pub fn winogrande_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let e1 = v.word(rng.below(v.n_words / 2));
    let e2 = v.word(v.n_words / 2 + rng.below(v.n_words / 2 - 1));
    let first_greater = rng.bool(0.5);
    let mut t = vec![v.bos];
    if first_greater {
        t.extend([e1, v.gt, e2]);
    } else {
        t.extend([e2, v.gt, e1]);
    }
    // question: "which is greater?"  options A=e1, B=e2
    t.extend([v.comma, v.gt, v.qmark, v.choice(0), e1, v.comma, v.choice(1), e2, v.comma]);
    finish(v, t, if first_greater { v.choice(0) } else { v.choice(1) }, max_len)
}

/// ARC-e-sim: one-rule application — successor of a number (4 options).
pub fn arc_e_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(1, 80) as u32;
    let mut t = vec![v.bos];
    t.extend(v.number(a));
    t.push(v.plus);
    t.extend(v.number(1));
    t.push(v.qmark);
    let correct = a + 1;
    let mut opts = vec![correct];
    while opts.len() < 4 {
        let c = (correct as i64 + rng.range(-4, 5)).max(0) as u32;
        if !opts.contains(&c) {
            opts.push(c);
        }
    }
    rng.shuffle(&mut opts);
    let idx = opts.iter().position(|x| *x == correct).unwrap();
    for (i, o) in opts.iter().enumerate() {
        t.push(v.choice(i));
        t.extend(v.number(*o));
        t.push(v.comma);
    }
    finish(v, t, v.choice(idx), max_len)
}

/// ARC-c-sim: two composed rules — `a + b - c` (4 options, harder than ARC-e).
pub fn arc_c_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(5, 40) as u32;
    let b = rng.range(1, 30) as u32;
    let c = rng.range(1, (a + b).min(30) as i64) as u32;
    let correct = a + b - c;
    let mut t = vec![v.bos];
    t.extend(v.number(a));
    t.push(v.plus);
    t.extend(v.number(b));
    t.push(v.minus);
    t.extend(v.number(c));
    t.push(v.qmark);
    let mut opts = vec![correct];
    while opts.len() < 4 {
        let cand = (correct as i64 + rng.range(-5, 6)).max(0) as u32;
        if !opts.contains(&cand) {
            opts.push(cand);
        }
    }
    rng.shuffle(&mut opts);
    let idx = opts.iter().position(|x| *x == correct).unwrap();
    for (i, o) in opts.iter().enumerate() {
        t.push(v.choice(i));
        t.extend(v.number(*o));
        t.push(v.comma);
    }
    finish(v, t, v.choice(idx), max_len)
}

/// OBQA-sim: "open book" — a fact `key = value` stated up front must be
/// retrieved to answer the later question (4 numeric options).
pub fn obqa_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let key = v.word(rng.below(v.n_words));
    let value = rng.range(1, 60) as u32;
    let mut t = vec![v.bos, key, v.eq];
    t.extend(v.number(value));
    // filler "book" clutter between fact and question
    for _ in 0..3 {
        t.push(v.word(rng.below(v.n_words)));
    }
    t.extend([v.comma, key, v.qmark]);
    let mut opts = vec![value];
    while opts.len() < 4 {
        let cand = (value as i64 + rng.range(-8, 9)).max(0) as u32;
        if !opts.contains(&cand) {
            opts.push(cand);
        }
    }
    rng.shuffle(&mut opts);
    let idx = opts.iter().position(|x| *x == value).unwrap();
    for (i, o) in opts.iter().enumerate() {
        t.push(v.choice(i));
        t.extend(v.number(*o));
        t.push(v.comma);
    }
    finish(v, t, v.choice(idx), max_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boolq_answer_matches_comparison() {
        let v = Vocab::new(256);
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let ex = boolq_sim(&v, &mut rng, 48);
            let gtpos = ex.tokens.iter().position(|t| *t == v.gt).unwrap();
            let a = v.parse_number(&ex.tokens[1..gtpos]).unwrap();
            let qpos = ex.tokens.iter().position(|t| *t == v.qmark).unwrap();
            let b = v.parse_number(&ex.tokens[gtpos + 1..qpos]).unwrap();
            let want = if a > b { v.yes } else { v.no };
            assert_eq!(ex.tokens[ex.answer_start], want);
        }
    }

    #[test]
    fn piqa_correct_choice_continues_pattern() {
        let v = Vocab::new(256);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let ex = piqa_sim(&v, &mut rng, 48);
            let w = ex.tokens[1];
            let letter = ex.tokens[ex.answer_start];
            let idx = (letter - v.choice(0)) as usize;
            // find the option token after choice(idx)
            let pos = ex.tokens.iter().position(|t| *t == v.choice(idx)).unwrap();
            assert_eq!(ex.tokens[pos + 1], w);
        }
    }

    #[test]
    fn obqa_requires_retrieval() {
        let v = Vocab::new(256);
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let ex = obqa_sim(&v, &mut rng, 64);
            let key = ex.tokens[1];
            // key appears twice: fact + question
            assert_eq!(ex.tokens.iter().filter(|t| **t == key).count() >= 2, true);
        }
    }

    #[test]
    fn choice_tasks_shuffle_positions() {
        // the correct letter must not be constant (else a model learns "A")
        let v = Vocab::new(256);
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..80 {
            let ex = hellaswag_sim(&v, &mut rng, 64);
            seen.insert(ex.tokens[ex.answer_start]);
        }
        assert!(seen.len() >= 3, "answers always in the same slot");
    }
}
