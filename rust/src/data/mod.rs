//! Synthetic workloads standing in for the paper's datasets (DESIGN.md §3).
//!
//! Four math-reasoning simulants (GSM8K/AQuA/MAWPS/SVAMP — paper Table 1)
//! and eight commonsense simulants (BoolQ/PIQA/SIQA/HellaSwag/WinoGrande/
//! ARC-e/ARC-c/OBQA — paper Table 2), plus the pretraining corpus the
//! in-repo base models are trained on before Shears runs.
//!
//! Every task emits `Example`s: a token sequence with a marked answer
//! span. Training uses masked next-token loss over the answer; evaluation
//! is teacher-forced exact match over the span — the same protocol shape
//! as the paper's answer-accuracy metric.

pub mod batch;
pub mod commonsense;
pub mod corpus;
pub mod math;
pub mod vocab;

pub use batch::{Batch, Batcher};
pub use vocab::Vocab;

use crate::util::rng::Rng;

/// One supervised example: tokens + answer span (absolute positions).
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub answer_start: usize,
    pub answer_len: usize,
}

/// Every synthetic task in the suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    // math reasoning (Table 1)
    Gsm8kSim,
    AquaSim,
    MawpsSim,
    SvampSim,
    // commonsense reasoning (Table 2)
    BoolqSim,
    PiqaSim,
    SiqaSim,
    HellaswagSim,
    WinograndeSim,
    ArcESim,
    ArcCSim,
    ObqaSim,
}

impl Task {
    pub const MATH: [Task; 4] =
        [Task::Gsm8kSim, Task::AquaSim, Task::MawpsSim, Task::SvampSim];

    pub const COMMONSENSE: [Task; 8] = [
        Task::BoolqSim,
        Task::PiqaSim,
        Task::SiqaSim,
        Task::HellaswagSim,
        Task::WinograndeSim,
        Task::ArcESim,
        Task::ArcCSim,
        Task::ObqaSim,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Task::Gsm8kSim => "gsm8k-sim",
            Task::AquaSim => "aqua-sim",
            Task::MawpsSim => "mawps-sim",
            Task::SvampSim => "svamp-sim",
            Task::BoolqSim => "boolq-sim",
            Task::PiqaSim => "piqa-sim",
            Task::SiqaSim => "siqa-sim",
            Task::HellaswagSim => "hellaswag-sim",
            Task::WinograndeSim => "winogrande-sim",
            Task::ArcESim => "arc-e-sim",
            Task::ArcCSim => "arc-c-sim",
            Task::ObqaSim => "obqa-sim",
        }
    }

    /// Generate one example; `max_len` bounds the sequence.
    pub fn sample(&self, v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
        match self {
            Task::Gsm8kSim => math::gsm8k_sim(v, rng, max_len),
            Task::AquaSim => math::aqua_sim(v, rng, max_len),
            Task::MawpsSim => math::mawps_sim(v, rng, max_len),
            Task::SvampSim => math::svamp_sim(v, rng, max_len),
            Task::BoolqSim => commonsense::boolq_sim(v, rng, max_len),
            Task::PiqaSim => commonsense::piqa_sim(v, rng, max_len),
            Task::SiqaSim => commonsense::siqa_sim(v, rng, max_len),
            Task::HellaswagSim => commonsense::hellaswag_sim(v, rng, max_len),
            Task::WinograndeSim => commonsense::winogrande_sim(v, rng, max_len),
            Task::ArcESim => commonsense::arc_e_sim(v, rng, max_len),
            Task::ArcCSim => commonsense::arc_c_sim(v, rng, max_len),
            Task::ObqaSim => commonsense::obqa_sim(v, rng, max_len),
        }
    }

    /// Chance accuracy (for sanity checks in benches/tests).
    pub fn chance(&self) -> f64 {
        match self {
            Task::Gsm8kSim | Task::MawpsSim | Task::SvampSim => 0.01, // open numeric
            Task::AquaSim => 0.25,
            Task::BoolqSim => 0.5,
            Task::PiqaSim | Task::WinograndeSim => 0.5,
            Task::SiqaSim => 1.0 / 3.0,
            Task::HellaswagSim | Task::ArcESim | Task::ArcCSim | Task::ObqaSim => 0.25,
        }
    }
}

/// Deterministic dataset: `count` examples from a seeded stream.
pub fn dataset(task: Task, v: &Vocab, seed: u64, count: usize, max_len: usize) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ (task as u64).wrapping_mul(0x9E37_79B9));
    (0..count).map(|_| task.sample(v, &mut rng, max_len)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_examples() {
        let v = Vocab::new(256);
        let mut rng = Rng::new(0);
        for task in Task::MATH.iter().chain(Task::COMMONSENSE.iter()) {
            for _ in 0..50 {
                let ex = task.sample(&v, &mut rng, 48);
                assert!(ex.tokens.len() <= 48, "{}", task.name());
                assert!(ex.answer_len >= 1, "{}", task.name());
                assert!(
                    ex.answer_start + ex.answer_len <= ex.tokens.len(),
                    "{}: span out of range",
                    task.name()
                );
                assert!(
                    ex.tokens.iter().all(|t| (0..256).contains(t)),
                    "{}: token out of vocab",
                    task.name()
                );
                for i in 0..ex.answer_len {
                    assert_ne!(ex.tokens[ex.answer_start + i], v.pad, "{}", task.name());
                }
            }
        }
    }

    #[test]
    fn dataset_is_deterministic() {
        let v = Vocab::new(256);
        let a = dataset(Task::Gsm8kSim, &v, 7, 5, 48);
        let b = dataset(Task::Gsm8kSim, &v, 7, 5, 48);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
        let c = dataset(Task::Gsm8kSim, &v, 8, 5, 48);
        assert!(a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens));
    }
}
