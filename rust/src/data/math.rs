//! Math-reasoning simulants (paper Table 1 datasets, DESIGN.md §3).
//!
//! Shared format: `[BOS, <problem tokens>, SEP, <answer tokens>, EOS]` —
//! the answer span is what training supervises and evaluation
//! exact-matches, mirroring the LLM-Adapters answer-accuracy protocol.
//!
//! Difficulty ordering mirrors the real datasets: MAWPS (templated single
//! op) < SVAMP (distractor number) < GSM8K (multi-step chain); AQuA is
//! multiple-choice.

use super::vocab::Vocab;
use super::Example;
use crate::util::rng::Rng;

fn finish(v: &Vocab, mut tokens: Vec<i32>, answer: Vec<i32>, max_len: usize) -> Example {
    tokens.push(v.sep);
    let answer_start = tokens.len();
    let answer_len = answer.len();
    tokens.extend(answer);
    tokens.push(v.eos);
    assert!(tokens.len() <= max_len, "example len {} > {max_len}", tokens.len());
    Example { tokens, answer_start, answer_len }
}

/// GSM8K-sim: 2–3 step arithmetic chain wrapped in "story" filler words.
/// `a ± b ± c` with everything kept in [0, 99] so answers are ≤ 2 digits.
pub fn gsm8k_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let steps = 2 + rng.below(2); // 2..=3 operations
    let mut acc = rng.range(5, 40) as i32;
    let mut t = vec![v.bos, v.word(rng.below(40)), v.word(rng.below(40))];
    t.extend(v.number(acc as u32));
    for _ in 0..steps {
        let add = rng.bool(0.5);
        let operand = if add {
            rng.range(1, (99 - acc).max(2) as i64) as i32
        } else {
            rng.range(1, acc.max(2) as i64) as i32
        };
        t.push(v.word(rng.below(40)));
        t.push(if add { v.plus } else { v.minus });
        t.extend(v.number(operand as u32));
        acc = if add { acc + operand } else { acc - operand };
    }
    t.push(v.qmark);
    finish(v, t, v.number(acc as u32), max_len)
}

/// AQuA-sim: compute `a op b`, pick among four numeric options (answer is
/// the option letter, chance = 25%).
pub fn aqua_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(2, 30) as u32;
    let b = rng.range(2, 30) as u32;
    let add = rng.bool(0.5);
    let correct = if add { a + b } else { a.max(b) - a.min(b) };
    let mut t = vec![v.bos];
    t.extend(v.number(a.max(b)));
    t.push(if add { v.plus } else { v.minus });
    t.extend(v.number(if add { a.min(b) } else { a.min(b) }));
    t.push(v.qmark);
    // four options: correct + three perturbations, shuffled
    let mut opts = vec![correct];
    while opts.len() < 4 {
        let delta = rng.range(1, 7) as u32;
        let cand = if rng.bool(0.5) { correct + delta } else { correct.saturating_sub(delta) };
        if !opts.contains(&cand) {
            opts.push(cand);
        }
    }
    rng.shuffle(&mut opts);
    let correct_idx = opts.iter().position(|x| *x == correct).unwrap();
    for (i, o) in opts.iter().enumerate() {
        t.push(v.choice(i));
        t.extend(v.number(*o));
        t.push(v.comma);
    }
    finish(v, t, vec![v.choice(correct_idx)], max_len)
}

/// MAWPS-sim: templated single-operation word problem (the easiest set).
pub fn mawps_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(2, 50) as u32;
    let b = rng.range(1, 40) as u32;
    let add = rng.bool(0.5);
    let ans = if add { a + b } else { a.max(b) - a.min(b) };
    let (x, y) = if add { (a, b) } else { (a.max(b), a.min(b)) };
    let noun = v.word(rng.below(20)); // small, reusable template vocabulary
    let mut t = vec![v.bos, noun];
    t.extend(v.number(x));
    t.push(if add { v.plus } else { v.minus });
    t.push(noun);
    t.extend(v.number(y));
    t.push(v.qmark);
    finish(v, t, v.number(ans), max_len)
}

/// SVAMP-sim: MAWPS plus an irrelevant distractor quantity — the model
/// must ignore a plausible number (SVAMP's defining perturbation).
pub fn svamp_sim(v: &Vocab, rng: &mut Rng, max_len: usize) -> Example {
    let a = rng.range(2, 50) as u32;
    let b = rng.range(1, 40) as u32;
    let distractor = rng.range(1, 60) as u32;
    let add = rng.bool(0.5);
    let ans = if add { a + b } else { a.max(b) - a.min(b) };
    let (x, y) = if add { (a, b) } else { (a.max(b), a.min(b)) };
    let noun = v.word(rng.below(20));
    let other = v.word(20 + rng.below(20)); // distractor entity ≠ noun region
    let mut t = vec![v.bos, noun];
    t.extend(v.number(x));
    // distractor clause: "other <distractor>,"
    t.push(other);
    t.extend(v.number(distractor));
    t.push(v.comma);
    t.push(if add { v.plus } else { v.minus });
    t.push(noun);
    t.extend(v.number(y));
    t.push(v.qmark);
    finish(v, t, v.number(ans), max_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v() -> Vocab {
        Vocab::new(256)
    }

    #[test]
    fn gsm8k_answers_are_consistent() {
        let v = v();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let ex = gsm8k_sim(&v, &mut rng, 48);
            // answer parses as a number in [0, 99+steps*…] bounded well below 200
            let ans = v
                .parse_number(&ex.tokens[ex.answer_start..ex.answer_start + ex.answer_len])
                .expect("numeric answer");
            assert!(ans < 200);
            assert_eq!(ex.tokens[ex.answer_start - 1], v.sep);
            assert_eq!(*ex.tokens.last().unwrap(), v.eos);
        }
    }

    #[test]
    fn aqua_answer_is_valid_choice_letter() {
        let v = v();
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ex = aqua_sim(&v, &mut rng, 48);
            assert_eq!(ex.answer_len, 1);
            let a = ex.tokens[ex.answer_start];
            assert!((v.choice(0)..=v.choice(3)).contains(&a));
        }
    }

    #[test]
    fn aqua_correct_option_matches_computation() {
        let v = v();
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let ex = aqua_sim(&v, &mut rng, 48);
            // decode problem: number op number '?'
            let toks = &ex.tokens[1..];
            let qpos = toks.iter().position(|t| *t == v.qmark).unwrap();
            let op_pos = toks[..qpos]
                .iter()
                .position(|t| *t == v.plus || *t == v.minus)
                .unwrap();
            let x = v.parse_number(&toks[..op_pos]).unwrap();
            let y = v.parse_number(&toks[op_pos + 1..qpos]).unwrap();
            let expect = if toks[op_pos] == v.plus { x + y } else { x - y };
            // decode options
            let body = &toks[qpos + 1..];
            let letter = ex.tokens[ex.answer_start];
            let idx = (letter - v.choice(0)) as usize;
            // find idx-th option value
            let mut vals = Vec::new();
            let mut i = 0;
            while i < body.len() {
                if (v.choice(0)..=v.choice(4)).contains(&body[i]) {
                    let mut j = i + 1;
                    while j < body.len() && (v.digit0..v.digit0 + 10).contains(&body[j]) {
                        j += 1;
                    }
                    vals.push(v.parse_number(&body[i + 1..j]).unwrap());
                    i = j;
                } else {
                    i += 1;
                }
            }
            assert_eq!(vals[idx], expect);
        }
    }

    #[test]
    fn mawps_single_op_correct() {
        let v = v();
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let ex = mawps_sim(&v, &mut rng, 48);
            let ans = v
                .parse_number(&ex.tokens[ex.answer_start..ex.answer_start + ex.answer_len])
                .unwrap();
            assert!(ans <= 90);
        }
    }

    #[test]
    fn svamp_contains_distractor_clause() {
        let v = v();
        let mut rng = Rng::new(5);
        let ex = svamp_sim(&v, &mut rng, 48);
        assert!(ex.tokens.contains(&v.comma));
    }
}
