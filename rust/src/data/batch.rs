//! Batch construction: examples → (x, y, loss_mask) HostTensors matching
//! the train/eval entry-point signatures.
//!
//! `y[t] = x[t+1]` (next-token targets); the loss mask selects positions
//! whose *target* lies in the answer span (supervised fine-tuning) or all
//! non-pad targets (pretraining). Shapes are fixed per config, examples
//! are padded with PAD and over-long batches cycle examples — exactly the
//! contract the AOT'd graphs expect.

use super::{Example, Vocab};
use crate::tensor::HostTensor;

#[derive(Clone, Debug)]
pub struct Batch {
    pub x: HostTensor,
    pub y: HostTensor,
    pub loss_mask: HostTensor,
    /// how many rows are real examples (tail rows may be cycled fill)
    pub real: usize,
}

/// Loss-mask policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MaskMode {
    /// supervise only the answer span (task fine-tuning)
    AnswerOnly,
    /// supervise every non-pad target (pretraining)
    FullSequence,
}

pub fn build_batch(
    examples: &[&Example],
    batch: usize,
    seq_len: usize,
    vocab: &Vocab,
    mode: MaskMode,
) -> Batch {
    assert!(!examples.is_empty());
    let mut x = vec![vocab.pad; batch * seq_len];
    let mut y = vec![vocab.pad; batch * seq_len];
    let mut m = vec![0.0f32; batch * seq_len];
    for row in 0..batch {
        let ex = examples[row % examples.len()];
        let n = ex.tokens.len().min(seq_len);
        for t in 0..n {
            x[row * seq_len + t] = ex.tokens[t];
        }
        for t in 0..seq_len {
            let target_pos = t + 1;
            if target_pos < n {
                y[row * seq_len + t] = ex.tokens[target_pos];
                let in_answer = target_pos >= ex.answer_start
                    && target_pos < ex.answer_start + ex.answer_len;
                let supervised = match mode {
                    MaskMode::AnswerOnly => in_answer,
                    MaskMode::FullSequence => true,
                };
                if supervised {
                    m[row * seq_len + t] = 1.0;
                }
            }
        }
    }
    Batch {
        x: HostTensor::from_i32(&[batch, seq_len], x),
        y: HostTensor::from_i32(&[batch, seq_len], y),
        loss_mask: HostTensor::from_f32(&[batch, seq_len], m),
        real: examples.len().min(batch),
    }
}

/// Iterates a dataset as fixed-shape batches (cycling at the tail).
pub struct Batcher<'a> {
    examples: &'a [Example],
    batch: usize,
    seq_len: usize,
    vocab: &'a Vocab,
    mode: MaskMode,
    pos: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(
        examples: &'a [Example],
        batch: usize,
        seq_len: usize,
        vocab: &'a Vocab,
        mode: MaskMode,
    ) -> Self {
        assert!(!examples.is_empty());
        Batcher { examples, batch, seq_len, vocab, mode, pos: 0 }
    }

    /// The dataset cursor — recorded by training checkpoints so a
    /// rollback or resume replays the exact same batch sequence.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Restore the dataset cursor from a checkpoint (modulo the
    /// dataset length, so a cursor from an identical dataset always
    /// lands in range).
    pub fn set_pos(&mut self, pos: usize) {
        self.pos = pos % self.examples.len();
    }

    /// Next training batch, cycling the dataset forever.
    pub fn next_cyclic(&mut self) -> Batch {
        let refs: Vec<&Example> = (0..self.batch)
            .map(|i| &self.examples[(self.pos + i) % self.examples.len()])
            .collect();
        self.pos = (self.pos + self.batch) % self.examples.len();
        build_batch(&refs, self.batch, self.seq_len, self.vocab, self.mode)
    }

    /// One pass over the dataset for evaluation (last batch padded;
    /// `Batch::real` says how many rows count).
    pub fn epoch(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.examples.len() {
            let hi = (i + self.batch).min(self.examples.len());
            let refs: Vec<&Example> = self.examples[i..hi].iter().collect();
            out.push(build_batch(&refs, self.batch, self.seq_len, self.vocab, self.mode));
            i = hi;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{dataset, Task};

    #[test]
    fn shapes_and_shift() {
        let v = Vocab::new(256);
        let ex = Example { tokens: vec![1, 10, 11, 3, 12, 2], answer_start: 4, answer_len: 1 };
        let b = build_batch(&[&ex], 2, 8, &v, MaskMode::AnswerOnly);
        assert_eq!(b.x.shape, vec![2, 8]);
        let x = b.x.i32s();
        let y = b.y.i32s();
        // shift: y[t] == x[t+1] where defined
        for t in 0..5 {
            assert_eq!(y[t], x[t + 1]);
        }
        // answer-only mask: only position 3 (target = index 4 = answer) is on
        let m = b.loss_mask.f32s();
        assert_eq!(m[3], 1.0);
        assert_eq!(m.iter().take(8).sum::<f32>(), 1.0);
        // second row is cycled fill of the same example
        assert_eq!(x[8], 1);
        assert_eq!(b.real, 1);
    }

    #[test]
    fn full_sequence_mask_covers_non_pad() {
        let v = Vocab::new(256);
        let ex = Example { tokens: vec![1, 10, 11, 2], answer_start: 2, answer_len: 1 };
        let b = build_batch(&[&ex], 1, 6, &v, MaskMode::FullSequence);
        let m = b.loss_mask.f32s();
        assert_eq!(&m[..4], &[1.0, 1.0, 1.0, 0.0]); // targets at t=0..2 exist
    }

    #[test]
    fn epoch_covers_all_examples_once() {
        let v = Vocab::new(256);
        let ds = dataset(Task::BoolqSim, &v, 1, 10, 48);
        let batcher = Batcher::new(&ds, 4, 48, &v, MaskMode::AnswerOnly);
        let batches = batcher.epoch();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches.iter().map(|b| b.real).sum::<usize>(), 10);
        assert_eq!(batches[2].real, 2);
    }

    #[test]
    fn cyclic_advances() {
        let v = Vocab::new(256);
        let ds = dataset(Task::BoolqSim, &v, 1, 6, 48);
        let mut batcher = Batcher::new(&ds, 4, 48, &v, MaskMode::AnswerOnly);
        let a = batcher.next_cyclic();
        let b = batcher.next_cyclic();
        assert_ne!(a.x.i32s(), b.x.i32s());
    }
}
