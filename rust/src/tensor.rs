//! Host-side tensors: the L3 representation of every model parameter,
//! batch, mask, and statistic, with a simple binary checkpoint codec and
//! (under the `xla` feature) lossless conversion to/from `xla::Literal`.
//!
//! Only f32 and i32 exist in the stack (DESIGN.md §3: FP16→f32
//! substitution), which keeps this deliberately small.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use anyhow::Context;
use std::io::{Read, Write};

#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

/// Native-endian byte view of a numeric slice, for the checkpoint
/// codec and `xla::Literal` conversion. Private on purpose: only ever
/// instantiated at f32/i32.
fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: `v` is an initialized slice of plain-old-data numerics
    // (f32/i32 — no padding, no invalid bit patterns as bytes), the
    // cast only narrows alignment, and the length covers exactly the
    // same memory, so the byte view is valid for `v`'s lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor {
            shape: shape.to_vec(),
            data: Data::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        HostTensor {
            shape: shape.to_vec(),
            data: Data::F32(vec![1.0; shape.iter().product()]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        HostTensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor { shape: vec![], data: Data::F32(vec![x]) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, Data::F32(_))
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Elementwise closeness against another f32 tensor:
    /// `|a - b| <= atol + rtol·|b|` for every element, same shape.
    /// Returns the first offending index (test/diagnostic helper).
    pub fn approx_eq(&self, other: &HostTensor, atol: f32, rtol: f32) -> Result<(), String> {
        if self.shape != other.shape {
            return Err(format!("shape {:?} vs {:?}", self.shape, other.shape));
        }
        for (i, (a, b)) in self.f32s().iter().zip(other.f32s()).enumerate() {
            let tol = atol + rtol * b.abs();
            if (a - b).abs() > tol {
                return Err(format!("[{i}]: {a} vs {b} (tol {tol})"));
            }
        }
        Ok(())
    }

    /// Count of exactly-zero entries (sparsity accounting, paper Table 3).
    pub fn zeros_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.iter().filter(|x| **x == 0.0).count(),
            Data::I32(v) => v.iter().filter(|x| **x == 0).count(),
        }
    }

    pub fn sparsity(&self) -> f64 {
        self.zeros_count() as f64 / self.numel().max(1) as f64
    }

    // ------------------------------------------------------ Literal I/O

    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = match &self.data {
            Data::F32(v) => bytes_of(v),
            Data::I32(v) => bytes_of(v),
        };
        let ty = match self.data {
            Data::F32(_) => xla::ElementType::F32,
            Data::I32(_) => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)
            .context("literal from host tensor")
    }

    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor {
                shape: dims,
                data: Data::F32(lit.to_vec::<f32>().context("literal f32 data")?),
            }),
            xla::ElementType::S32 => Ok(HostTensor {
                shape: dims,
                data: Data::I32(lit.to_vec::<i32>().context("literal i32 data")?),
            }),
            ty => bail!("unsupported literal element type {ty:?}"),
        }
    }

    // --------------------------------------------------- checkpoint codec
    //
    // format: [tag u8][ndim u32][dims u64...][len u64][payload]

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let (tag, bytes): (u8, &[u8]) = match &self.data {
            Data::F32(v) => (0, bytes_of(v)),
            Data::I32(v) => (1, bytes_of(v)),
        };
        w.write_all(&[tag])?;
        w.write_all(&(self.shape.len() as u32).to_le_bytes())?;
        for d in &self.shape {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        w.write_all(&(bytes.len() as u64).to_le_bytes())?;
        w.write_all(bytes)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let ndim = u32::from_le_bytes(b4) as usize;
        if ndim > 16 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut b8 = [0u8; 8];
        for _ in 0..ndim {
            r.read_exact(&mut b8)?;
            shape.push(u64::from_le_bytes(b8) as usize);
        }
        r.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        if len != shape.iter().product::<usize>() * 4 {
            bail!("corrupt checkpoint: payload {len} vs shape {shape:?}");
        }
        let mut bytes = vec![0u8; len];
        r.read_exact(&mut bytes)?;
        let data = match tag[0] {
            0 => Data::F32(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            1 => Data::I32(
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            t => bail!("corrupt checkpoint: tag {t}"),
        };
        Ok(HostTensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_accessors() {
        let t = HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 0.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.zeros_count(), 1);
        assert!((t.sparsity() - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::from_f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn checkpoint_roundtrip_f32_i32() {
        let a = HostTensor::from_f32(&[3, 2], vec![0.5, -1.5, 2.0, 0.0, 9.9, 1e-7]);
        let b = HostTensor::from_i32(&[4], vec![1, -2, 3, i32::MAX]);
        let s = HostTensor::scalar_f32(3.25);
        let mut buf = Vec::new();
        a.write_to(&mut buf).unwrap();
        b.write_to(&mut buf).unwrap();
        s.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        assert_eq!(HostTensor::read_from(&mut r).unwrap(), a);
        assert_eq!(HostTensor::read_from(&mut r).unwrap(), b);
        assert_eq!(HostTensor::read_from(&mut r).unwrap(), s);
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let mut buf = Vec::new();
        HostTensor::ones(&[2, 2]).write_to(&mut buf).unwrap();
        buf[1] = 99; // ndim
        assert!(HostTensor::read_from(&mut &buf[..]).is_err());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);

        let ti = HostTensor::from_i32(&[3], vec![7, -8, 9]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);

        let s = HostTensor::scalar_f32(2.5);
        let lit = s.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), s);
    }
}
