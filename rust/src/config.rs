//! Experiment configuration: a TOML-lite file format + typed view.
//!
//! Mirrors the paper's hyperparameter tables (7–9) at reproduction scale;
//! `configs/*.toml` in the repo root hold one file per experiment. Format
//! subset: `[section]` headers, `key = value` with string / number / bool
//! / `[a, b, c]` arrays, `#` comments.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

/// Parsed config: section -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value '{}'", lineno + 1, v.trim()))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn parse_value(s: &str) -> Result<Value> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let items = inner
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(parse_value)
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Arr(items));
    }
    if let Some(q) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(q.to_string()));
    }
    if let Ok(n) = s.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    // bare words are strings (config ergonomics)
    Ok(Value::Str(s.to_string()))
}

/// Typed training hyperparameters (paper Tables 7–9, scaled).
#[derive(Clone, Debug)]
pub struct TrainHp {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
}

impl TrainHp {
    pub fn from_config(cfg: &Config, section: &str) -> TrainHp {
        TrainHp {
            steps: cfg.usize_or(section, "steps", 300),
            lr: cfg.f64_or(section, "lr", 3e-3),
            warmup: cfg.usize_or(section, "warmup", 20),
            seed: cfg.usize_or(section, "seed", 42) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            r#"
            # Shears experiment
            [model]
            config = "llama-sim-s"
            [train]
            steps = 250
            lr = 3e-4        # paper Table 7
            ranks = [8, 6, 4]
            resume = false
            "#,
        )
        .unwrap();
        assert_eq!(c.str_or("model", "config", ""), "llama-sim-s");
        assert_eq!(c.usize_or("train", "steps", 0), 250);
        assert!((c.f64_or("train", "lr", 0.0) - 3e-4).abs() < 1e-12);
        assert_eq!(c.get("train", "resume"), Some(&Value::Bool(false)));
        match c.get("train", "ranks") {
            Some(Value::Arr(v)) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        let hp = TrainHp::from_config(&c, "train");
        assert_eq!(hp.steps, 300);
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[x]\njust_a_word_without_equals value").is_err());
    }
}
