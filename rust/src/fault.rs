//! Deterministic fault injection for the whole crate — the serving
//! stack's attributable-fault taxonomy plus injectors for the offline
//! pipeline (eval workers, training loop), all scheduled from one
//! [`FaultPlan`].
//!
//! A [`FaultPlan`] schedules injected failures against cumulative
//! **attempt counters**. Serving consumes the step-attempt counter
//! (every call to [`crate::serve::StepEngine::step`] with at least one
//! active slot consumes one attempt, whether or not it completes);
//! the eval router consumes the eval-attempt counter (one per batched
//! forward); the training loop consumes the train-attempt counter
//! (one per optimizer step). A given plan therefore replays the exact
//! same failure at the exact same point in every run — recovery paths
//! are pinned by tests, not by hoping a real fault shows up. The
//! counters live on the plan itself and supervisors move the plan from
//! a dead component to its replacement, so injections keep their
//! global indices across a supervised restart (a `panic@N+1` plan
//! exhausts the restart budget deterministically, and a one-shot
//! `nanloss@k` does not re-fire while the rolled-back steps replay).
//!
//! Plans come from the API ([`crate::serve::ServerOpts`]`::fault`,
//! [`crate::coordinator::RouterOpts`]`::fault`,
//! [`crate::train::TrainOpts`]`::fault`) or — when the API plan is
//! empty — from the `SHEARS_FAULT` environment variable, so operators
//! can run recovery drills against a live binary. Grammar:
//! comma-separated `kind@start[+period][:arg]`, attempts 0-based:
//!
//! ```text
//!   panic@3       panic inside step attempt 3 (exercises the supervisor)
//!   error@5       step attempt 5 fails; every slot recovers via re-prefill
//!   error@5:1     …and slot 1's recovery prefill fails too (quarantine)
//!   nan@4:2       poison slot 2's logits row with NaN on attempt 4
//!   delay@2:8     sleep 8 ms before attempt 2 (deadline-overrun tests)
//!   rankdelay@0+1:50  every attempt, sleep 50 µs × the sum of active
//!                     slots' adapter ranks — emulates compute that
//!                     scales with LoRA rank, so brownout degradation
//!                     (rank truncation) measurably buys back latency
//!   evalerr@2     eval attempt 2 fails inside the router worker —
//!                 exercises the supervised retry path
//!   evalhang@4:300  eval attempt 4 stalls 300 ms (default 60000) —
//!                   exercises the per-call timeout + worker respawn
//!   nanloss@6     report train step 6's loss as NaN (weights are
//!                 untouched) — exercises checkpoint rollback
//!   panic@6+10    periodic: fires on attempts 6, 16, 26, …
//! ```
//!
//! An **empty plan is a single branch** on the hot path
//! ([`FaultPlan::is_empty`]) — no counter bookkeeping, no scan — so
//! the fault layer rides in production builds without costing the
//! zero-alloc warm step anything (`rust/tests/alloc_count.rs`).

use anyhow::{bail, Context, Result};
use std::fmt;

/// Why a request ended without a normal completion — shared by
/// injected and organic failures so stream errors and
/// [`crate::serve::GenResponse`]`::fault` stay attributable either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// the engine step panicked (caught by the server's supervisor;
    /// every in-flight request fails and the engine is rebuilt)
    StepPanic,
    /// the batched decode step errored and this slot's own recovery
    /// re-prefill failed too
    StepError,
    /// the slot's logits row contained NaN/±inf — its KV column is no
    /// longer trusted
    NanLogits,
    /// past `GenRequest::deadline` with `ServerOpts::enforce_deadlines`
    DeadlineExceeded,
    /// past the hard per-request `GenRequest::max_wall` budget
    WallClockExceeded,
    /// cancelled by the caller (`StreamHandle::cancel`)
    Cancelled,
    /// the caller dropped its `StreamHandle` before the stream ended
    Abandoned,
    /// the server is going away (restart budget exhausted / drain)
    Shutdown,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::StepPanic => "step-panic",
            FaultKind::StepError => "step-error",
            FaultKind::NanLogits => "nan-logits",
            FaultKind::DeadlineExceeded => "deadline-exceeded",
            FaultKind::WallClockExceeded => "wall-clock-exceeded",
            FaultKind::Cancelled => "cancelled",
            FaultKind::Abandoned => "abandoned",
            FaultKind::Shutdown => "shutdown",
        }
    }

    /// Cancellations are the caller's (or the clock's) doing; faults
    /// are the engine's. The two feed different metrics counters.
    pub fn is_cancellation(self) -> bool {
        matches!(
            self,
            FaultKind::DeadlineExceeded
                | FaultKind::WallClockExceeded
                | FaultKind::Cancelled
                | FaultKind::Abandoned
        )
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One failed or cancelled request's attribution record: request id,
/// the KV slot it occupied (`None` = it never left the queue), what
/// kind of fault, and the underlying detail. Carried on
/// [`crate::serve::GenResponse`]`::fault` and formatted into stream
/// errors so a multi-tenant operator can tell whose request died,
/// where, and why.
#[derive(Clone, Debug)]
pub struct ServeFault {
    pub request: u64,
    pub slot: Option<usize>,
    pub kind: FaultKind,
    pub detail: String,
}

impl fmt::Display for ServeFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.slot {
            Some(s) => {
                write!(f, "request {} (slot {s}) fault {}: {}", self.request, self.kind, self.detail)
            }
            None => {
                write!(f, "request {} (queued) fault {}: {}", self.request, self.kind, self.detail)
            }
        }
    }
}

/// What to inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectKind {
    /// panic inside the engine step — exercises `catch_unwind`
    /// supervision and the restart budget
    Panic,
    /// the batched step returns an error before touching the model;
    /// `slot` (if set) also fails its recovery re-prefill, so exactly
    /// that request retires with a [`FaultKind::StepError`] fault
    Error { slot: Option<usize> },
    /// overwrite `slot`'s logits row with NaN after the model step —
    /// exercises the non-finite quarantine
    NanLogits { slot: usize },
    /// sleep `ms` before the step — deadline/wall-clock overrun tests
    Delay { ms: u64 },
    /// sleep `us` microseconds **per active adapter rank** before the
    /// step (the engine multiplies by the sum of active slots'
    /// [`crate::ops::model::AdapterBinding::active_rank`]) — a
    /// deterministic stand-in for rank-proportional compute, the load
    /// model the brownout overload drills are pinned against
    RankDelay { us: u64 },
    /// the router worker fails this batched eval forward — exercises
    /// the supervised retry + backoff path (eval-attempt counter)
    EvalError,
    /// the router worker stalls `ms` milliseconds inside this eval —
    /// exercises the per-call timeout and worker respawn
    /// (eval-attempt counter)
    EvalHang { ms: u64 },
    /// report this optimizer step's loss as NaN without touching any
    /// weight — exercises checkpoint rollback in `train_loop`
    /// (train-attempt counter)
    NanLoss,
}

impl InjectKind {
    /// Which attempt counter this injector is keyed by.
    fn scope(self) -> Scope {
        match self {
            InjectKind::EvalError | InjectKind::EvalHang { .. } => Scope::Eval,
            InjectKind::NanLoss => Scope::Train,
            _ => Scope::Serve,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Scope {
    Serve,
    Eval,
    Train,
}

/// An [`InjectKind`] scheduled against its scope's attempt counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Injection {
    /// first attempt (0-based) this fires on
    pub at: u64,
    /// re-fire every `period` attempts after `at`; `0` = fire once
    pub period: u64,
    pub kind: InjectKind,
}

impl Injection {
    fn fires(&self, attempt: u64) -> bool {
        if attempt < self.at {
            return false;
        }
        if self.period == 0 {
            attempt == self.at
        } else {
            (attempt - self.at) % self.period == 0
        }
    }
}

/// Everything firing on one serve step attempt — plain copyable data,
/// built without allocating, so consulting the plan keeps warm steps
/// alloc-free even with injections armed (just not firing).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fire {
    /// the attempt index this record describes (for error messages)
    pub attempt: u64,
    pub delay_ms: u64,
    /// microseconds to sleep per unit of active adapter rank in the
    /// batch (the engine supplies the rank sum)
    pub rank_delay_us: u64,
    pub panic: bool,
    pub error: bool,
    /// slot whose recovery prefill the injected error also poisons
    pub error_slot: Option<usize>,
    /// slot whose logits row gets poisoned with NaN
    pub nan_slot: Option<usize>,
}

impl Fire {
    pub fn is_clean(&self) -> bool {
        self.delay_ms == 0
            && self.rank_delay_us == 0
            && !self.panic
            && !self.error
            && self.nan_slot.is_none()
    }
}

/// Everything firing on one router eval attempt.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalFire {
    pub attempt: u64,
    /// the worker fails this batched forward with an injected error
    pub error: bool,
    /// milliseconds the worker stalls inside this forward
    pub hang_ms: u64,
}

impl EvalFire {
    pub fn is_clean(&self) -> bool {
        !self.error && self.hang_ms == 0
    }
}

/// Everything firing on one optimizer-step attempt.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainFire {
    pub attempt: u64,
    /// report this step's loss as NaN (weights are never touched)
    pub nan_loss: bool,
}

impl TrainFire {
    pub fn is_clean(&self) -> bool {
        !self.nan_loss
    }
}

/// A deterministic fault schedule (see the module docs for the
/// grammar and counter semantics).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    injections: Vec<Injection>,
    /// serve step attempts consumed (survives engine rebuilds)
    attempts: u64,
    /// eval-router forward attempts consumed (survives respawns)
    eval_attempts: u64,
    /// optimizer-step attempts consumed (survives rollbacks — a
    /// rolled-back step was still an attempt, so one-shot injections
    /// don't re-fire during the deterministic replay)
    train_attempts: u64,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// An empty plan is the production state: the component's only
    /// cost is this check.
    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// Serve step attempts consumed so far (survives engine rebuilds —
    /// the supervisor moves the plan, counter and all).
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    /// Eval forward attempts consumed so far (survives worker
    /// respawns — the router owns the plan, not the worker).
    pub fn eval_attempts(&self) -> u64 {
        self.eval_attempts
    }

    /// Optimizer step attempts consumed so far (monotonic across
    /// rollbacks).
    pub fn train_attempts(&self) -> u64 {
        self.train_attempts
    }

    pub fn push(&mut self, inj: Injection) {
        self.injections.push(inj);
    }

    pub fn panic_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Panic });
        self
    }

    pub fn panic_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::Panic });
        self
    }

    pub fn error_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Error { slot: None } });
        self
    }

    pub fn error_at_slot(mut self, at: u64, slot: usize) -> FaultPlan {
        self.injections
            .push(Injection { at, period: 0, kind: InjectKind::Error { slot: Some(slot) } });
        self
    }

    pub fn error_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::Error { slot: None } });
        self
    }

    pub fn nan_at(mut self, at: u64, slot: usize) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::NanLogits { slot } });
        self
    }

    pub fn delay_at(mut self, at: u64, ms: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Delay { ms } });
        self
    }

    pub fn rank_delay_at(mut self, at: u64, us: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::RankDelay { us } });
        self
    }

    pub fn rank_delay_every(mut self, at: u64, period: u64, us: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::RankDelay { us } });
        self
    }

    pub fn eval_error_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::EvalError });
        self
    }

    pub fn eval_error_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::EvalError });
        self
    }

    pub fn eval_hang_at(mut self, at: u64, ms: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::EvalHang { ms } });
        self
    }

    pub fn nan_loss_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::NanLoss });
        self
    }

    pub fn nan_loss_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::NanLoss });
        self
    }

    /// Consume one serve step attempt and collect what fires on it.
    /// Called by the engine once per step with a non-empty plan; never
    /// allocates. Eval- and train-scoped injections are invisible here
    /// — they ride their own counters.
    pub fn fire(&mut self) -> Fire {
        let attempt = self.attempts;
        self.attempts += 1;
        let mut f = Fire { attempt, ..Fire::default() };
        for inj in &self.injections {
            if inj.kind.scope() != Scope::Serve || !inj.fires(attempt) {
                continue;
            }
            match inj.kind {
                InjectKind::Panic => f.panic = true,
                InjectKind::Error { slot } => {
                    f.error = true;
                    if slot.is_some() {
                        f.error_slot = slot;
                    }
                }
                InjectKind::NanLogits { slot } => {
                    // first match wins — one quarantine target per step
                    if f.nan_slot.is_none() {
                        f.nan_slot = Some(slot);
                    }
                }
                InjectKind::Delay { ms } => f.delay_ms += ms,
                InjectKind::RankDelay { us } => f.rank_delay_us += us,
                InjectKind::EvalError | InjectKind::EvalHang { .. } | InjectKind::NanLoss => {
                    unreachable!("non-serve scope filtered above")
                }
            }
        }
        f
    }

    /// Consume one eval forward attempt and collect what fires on it
    /// (the eval router calls this before each batched forward).
    pub fn fire_eval(&mut self) -> EvalFire {
        let attempt = self.eval_attempts;
        self.eval_attempts += 1;
        let mut f = EvalFire { attempt, ..EvalFire::default() };
        for inj in &self.injections {
            if !inj.fires(attempt) {
                continue;
            }
            match inj.kind {
                InjectKind::EvalError => f.error = true,
                InjectKind::EvalHang { ms } => f.hang_ms += ms,
                _ => {}
            }
        }
        f
    }

    /// Consume one optimizer-step attempt and collect what fires on it
    /// (`train_loop` calls this after computing each step's loss).
    pub fn fire_train(&mut self) -> TrainFire {
        let attempt = self.train_attempts;
        self.train_attempts += 1;
        let mut f = TrainFire { attempt, ..TrainFire::default() };
        for inj in &self.injections {
            if !inj.fires(attempt) {
                continue;
            }
            if inj.kind == InjectKind::NanLoss {
                f.nan_loss = true;
            }
        }
        f
    }

    /// Parse the `SHEARS_FAULT` grammar (module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, sched) = part
                .split_once('@')
                .with_context(|| format!("fault '{part}': expected kind@start[+period][:arg]"))?;
            let (sched, arg) = match sched.split_once(':') {
                Some((s, a)) => (s, Some(a)),
                None => (sched, None),
            };
            let (at, period) = match sched.split_once('+') {
                Some((a, p)) => (
                    a.parse::<u64>().with_context(|| format!("fault '{part}': bad start '{a}'"))?,
                    p.parse::<u64>()
                        .with_context(|| format!("fault '{part}': bad period '{p}'"))?,
                ),
                None => (
                    sched
                        .parse::<u64>()
                        .with_context(|| format!("fault '{part}': bad start '{sched}'"))?,
                    0,
                ),
            };
            let parse_arg = |what: &str| -> Result<u64> {
                arg.with_context(|| format!("fault '{part}': '{kind}' needs :{what}"))?
                    .parse::<u64>()
                    .with_context(|| format!("fault '{part}': bad {what}"))
            };
            let kind = match kind {
                "panic" => {
                    ensure_no_arg(part, "panic", arg)?;
                    InjectKind::Panic
                }
                "error" => InjectKind::Error {
                    slot: match arg {
                        Some(_) => Some(parse_arg("slot")? as usize),
                        None => None,
                    },
                },
                "nan" => InjectKind::NanLogits { slot: parse_arg("slot")? as usize },
                "delay" => InjectKind::Delay { ms: parse_arg("ms")? },
                "rankdelay" => InjectKind::RankDelay { us: parse_arg("us")? },
                "evalerr" => {
                    ensure_no_arg(part, "evalerr", arg)?;
                    InjectKind::EvalError
                }
                "evalhang" => InjectKind::EvalHang {
                    ms: match arg {
                        Some(_) => parse_arg("ms")?,
                        // long enough that any sane --eval-timeout-ms
                        // trips first
                        None => 60_000,
                    },
                },
                "nanloss" => {
                    ensure_no_arg(part, "nanloss", arg)?;
                    InjectKind::NanLoss
                }
                other => bail!(
                    "fault '{part}': unknown kind '{other}' \
                     (panic|error|nan|delay|rankdelay|evalerr|evalhang|nanloss)"
                ),
            };
            plan.injections.push(Injection { at, period, kind });
        }
        Ok(plan)
    }

    /// The `SHEARS_FAULT` plan, `None` when unset or blank. A parse
    /// error is a real error — a typoed drill must fail loudly, not
    /// silently run fault-free.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("SHEARS_FAULT") {
            Ok(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(&s)?)),
            _ => Ok(None),
        }
    }
}

fn ensure_no_arg(part: &str, kind: &str, arg: Option<&str>) -> Result<()> {
    if arg.is_some() {
        bail!("fault '{part}': '{kind}' takes no :arg");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_kind_and_schedule() {
        let p = FaultPlan::parse("panic@3, error@5:1 ,nan@4:2,delay@2:8,error@7+100,rankdelay@0+1:50")
            .unwrap();
        assert_eq!(p.injections.len(), 6);
        assert_eq!(p.injections[0], Injection { at: 3, period: 0, kind: InjectKind::Panic });
        assert_eq!(
            p.injections[1],
            Injection { at: 5, period: 0, kind: InjectKind::Error { slot: Some(1) } }
        );
        assert_eq!(
            p.injections[2],
            Injection { at: 4, period: 0, kind: InjectKind::NanLogits { slot: 2 } }
        );
        assert_eq!(p.injections[3], Injection { at: 2, period: 0, kind: InjectKind::Delay { ms: 8 } });
        assert_eq!(
            p.injections[4],
            Injection { at: 7, period: 100, kind: InjectKind::Error { slot: None } }
        );
        assert_eq!(
            p.injections[5],
            Injection { at: 0, period: 1, kind: InjectKind::RankDelay { us: 50 } }
        );
    }

    #[test]
    fn parse_covers_the_pipeline_kinds() {
        let p = FaultPlan::parse("evalerr@2,evalhang@4:300,evalhang@9,nanloss@6,nanloss@1+5").unwrap();
        assert_eq!(p.injections.len(), 5);
        assert_eq!(p.injections[0], Injection { at: 2, period: 0, kind: InjectKind::EvalError });
        assert_eq!(
            p.injections[1],
            Injection { at: 4, period: 0, kind: InjectKind::EvalHang { ms: 300 } }
        );
        assert_eq!(
            p.injections[2],
            Injection { at: 9, period: 0, kind: InjectKind::EvalHang { ms: 60_000 } },
            "evalhang defaults to a stall any sane timeout trips first"
        );
        assert_eq!(p.injections[3], Injection { at: 6, period: 0, kind: InjectKind::NanLoss });
        assert_eq!(p.injections[4], Injection { at: 1, period: 5, kind: InjectKind::NanLoss });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("panic").is_err(), "missing @start");
        assert!(FaultPlan::parse("panic@x").is_err(), "bad start");
        assert!(FaultPlan::parse("nan@3").is_err(), "nan needs a slot");
        assert!(FaultPlan::parse("delay@3").is_err(), "delay needs ms");
        assert!(FaultPlan::parse("rankdelay@3").is_err(), "rankdelay needs us");
        assert!(FaultPlan::parse("panic@3:1").is_err(), "panic takes no arg");
        assert!(FaultPlan::parse("evalerr@3:1").is_err(), "evalerr takes no arg");
        assert!(FaultPlan::parse("nanloss@3:1").is_err(), "nanloss takes no arg");
        assert!(FaultPlan::parse("evalhang@3:x").is_err(), "bad evalhang ms");
        assert!(FaultPlan::parse("explode@1").is_err(), "unknown kind");
        assert!(FaultPlan::parse("error@1+z").is_err(), "bad period");
        let p = FaultPlan::parse(" ").unwrap();
        assert!(p.is_empty(), "blank spec is the empty plan");
    }

    #[test]
    fn one_shot_fires_exactly_once_periodic_repeats() {
        let one = Injection { at: 3, period: 0, kind: InjectKind::Panic };
        assert!(!one.fires(2));
        assert!(one.fires(3));
        assert!(!one.fires(4));
        let rep = Injection { at: 6, period: 10, kind: InjectKind::Panic };
        assert!(!rep.fires(5));
        assert!(rep.fires(6));
        assert!(!rep.fires(7));
        assert!(rep.fires(16));
        assert!(rep.fires(26));
    }

    #[test]
    fn fire_advances_the_attempt_counter_and_aggregates() {
        let mut p =
            FaultPlan::none().delay_at(1, 4).nan_at(1, 2).error_at_slot(1, 0).rank_delay_at(1, 9);
        let f0 = p.fire();
        assert_eq!(f0.attempt, 0);
        assert!(f0.is_clean());
        let f1 = p.fire();
        assert_eq!(f1.attempt, 1);
        assert!(!f1.is_clean());
        assert_eq!(f1.delay_ms, 4);
        assert_eq!(f1.rank_delay_us, 9);
        assert_eq!(f1.nan_slot, Some(2));
        assert!(f1.error);
        assert_eq!(f1.error_slot, Some(0));
        assert!(!f1.panic);
        assert!(p.fire().is_clean());
        assert_eq!(p.attempts(), 3);
    }

    #[test]
    fn scoped_counters_are_independent() {
        // the same schedule index on every counter: a serve panic, an
        // eval error, and a nan loss all "at 1" fire independently on
        // their own second attempt
        let mut p = FaultPlan::none().panic_at(1).eval_error_at(1).nan_loss_at(1);
        assert!(p.fire().is_clean());
        assert!(p.fire_eval().is_clean());
        assert!(p.fire_train().is_clean());
        let s = p.fire();
        let e = p.fire_eval();
        let t = p.fire_train();
        assert!(s.panic && !s.error, "serve scope sees only the panic");
        assert!(e.error && e.hang_ms == 0, "eval scope sees only the eval error");
        assert!(t.nan_loss, "train scope sees only the nan loss");
        assert_eq!((p.attempts(), p.eval_attempts(), p.train_attempts()), (2, 2, 2));
        // cross-scope invisibility: a serve fire never reports eval kinds
        assert!(p.fire().is_clean());
        assert!(p.fire_eval().is_clean());
        assert!(p.fire_train().is_clean());
    }

    #[test]
    fn eval_hang_aggregates_and_train_replay_does_not_refire() {
        let mut p = FaultPlan::none().eval_hang_at(0, 25).eval_hang_at(0, 10).nan_loss_at(0);
        let e = p.fire_eval();
        assert_eq!(e.hang_ms, 35, "coincident hangs aggregate");
        assert!(p.fire_train().nan_loss);
        // the rolled-back step replays as a NEW attempt — the one-shot
        // injection is spent, so the replay converges
        assert!(p.fire_train().is_clean());
    }

    #[test]
    fn fault_display_is_attributable() {
        let f = ServeFault {
            request: 7,
            slot: Some(2),
            kind: FaultKind::NanLogits,
            detail: "non-finite logits row".into(),
        };
        let s = f.to_string();
        assert!(s.contains("request 7"), "{s}");
        assert!(s.contains("slot 2"), "{s}");
        assert!(s.contains("nan-logits"), "{s}");
        let q = ServeFault {
            request: 9,
            slot: None,
            kind: FaultKind::Shutdown,
            detail: "restart budget exhausted".into(),
        };
        assert!(q.to_string().contains("(queued)"));
    }

    #[test]
    fn cancellation_kinds_partition_the_taxonomy() {
        for k in [
            FaultKind::DeadlineExceeded,
            FaultKind::WallClockExceeded,
            FaultKind::Cancelled,
            FaultKind::Abandoned,
        ] {
            assert!(k.is_cancellation(), "{k}");
        }
        for k in [FaultKind::StepPanic, FaultKind::StepError, FaultKind::NanLogits, FaultKind::Shutdown]
        {
            assert!(!k.is_cancellation(), "{k}");
        }
    }
}
