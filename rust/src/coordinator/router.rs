//! Eval request router with dynamic batching (the vLLM-router-shaped
//! component of L3; see DESIGN.md §5) — now **supervised**: a wedged
//! or panicked backend worker costs one retried evaluation, not a hung
//! search.
//!
//! Callers submit evaluation requests (a set of examples + an optional
//! sub-adapter rank mask) from any thread; a dedicated runtime thread
//! owns the backend (PJRT handles and the native exe cache are not
//! `Send`) and coalesces queued examples into full `batch_eval`-sized
//! forwards. Examples from *different* requests sharing the same rank
//! mask ride the same forward pass — dynamic batching — and results
//! are scattered back per request.
//!
//! Resilience contract ([`RouterOpts`]):
//! - [`EvalRouter::eval`] waits for each reply at most
//!   `eval_timeout` (default off — wait forever, the legacy
//!   behaviour). A timeout or a dead worker triggers a **respawn from
//!   the retained host stores** (resident weights are re-uploaded; no
//!   disk round-trip) and the whole request is retried with
//!   exponential backoff, up to `max_retries`.
//! - Throughput counters live in shared atomics, so
//!   [`EvalRouter::metrics`] never messages the worker and cannot
//!   block on a wedged thread; counters survive respawns.
//! - Worker shutdown (drop or respawn) waits at most
//!   `control_timeout`, then **detaches** the wedged thread instead of
//!   joining it — the PR 8 control-plane rule, applied to the offline
//!   path.
//! - A [`FaultPlan`] (API or `SHEARS_FAULT` when the API plan is
//!   empty) injects `evalerr` / `evalhang` faults before coalesced
//!   forwards, keyed by the plan's eval-attempt counter, which lives
//!   outside the worker so injections keep their indices across
//!   respawns.

use crate::data::batch::{build_batch, MaskMode};
use crate::data::{Example, Vocab};
use crate::fault::{EvalFire, FaultPlan};
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use crate::train::{exact_match, ForwardSession};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One queued example with its reply slot.
struct Pending {
    example: Example,
    mask_key: Vec<u8>,
    reply: Sender<Result<bool, String>>,
    enqueued: Instant,
}

enum Msg {
    Eval {
        examples: Vec<Example>,
        rank_mask: Option<HostTensor>,
        reply: Sender<Result<bool, String>>,
    },
    Shutdown,
}

/// Router throughput/latency/resilience counters.
#[derive(Clone, Debug, Default)]
pub struct RouterMetrics {
    pub requests: u64,
    pub examples: u64,
    pub forwards: u64,
    /// mean examples per forward (batching efficiency)
    pub mean_occupancy: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// whole-request retries after a failed/timed-out attempt
    pub retries: u64,
    /// worker threads rebuilt from the retained host stores
    pub respawns: u64,
    /// per-reply waits that hit `eval_timeout`
    pub timeouts: u64,
}

/// How the router is spawned and supervised. `..Default::default()`
/// gives the legacy behaviour: no eval timeout, retries armed but
/// never triggered (nothing times out and organic errors are rare),
/// bounded 2 s control-plane waits.
#[derive(Clone, Debug)]
pub struct RouterOpts {
    /// `native|pjrt|auto`, same grammar as `--backend` — an explicit
    /// spec, so the spawner's backend choice is never overridden by
    /// env/auto-detection
    pub backend: String,
    pub artifacts_dir: String,
    pub config: String,
    pub entry: String,
    /// grace period to coalesce concurrent requests into one forward
    pub max_wait: Duration,
    /// per-reply wait in [`EvalRouter::eval`]; `None` = wait forever
    pub eval_timeout: Option<Duration>,
    /// whole-request retries after a timeout / dead worker / eval error
    pub max_retries: u32,
    /// first retry backoff; doubles per retry
    pub retry_backoff: Duration,
    /// bound on waiting for a worker thread to exit before detaching it
    pub control_timeout: Duration,
    /// deterministic fault injection (`evalerr`/`evalhang`); when
    /// empty, `SHEARS_FAULT` is consulted at spawn
    pub fault: FaultPlan,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts {
            backend: "auto".into(),
            artifacts_dir: "artifacts".into(),
            config: String::new(),
            entry: "forward_eval_base".into(),
            max_wait: Duration::from_millis(30),
            eval_timeout: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(10),
            control_timeout: Duration::from_secs(2),
            fault: FaultPlan::none(),
        }
    }
}

/// Counters + fault plan shared between the handle and every worker
/// generation. Metrics read these directly — no worker round-trip —
/// and a respawned worker keeps counting where its predecessor
/// stopped.
struct Shared {
    // ORDERING(requests): counter — metrics statistic only.
    requests: AtomicU64,
    // ORDERING(examples): counter — metrics statistic only.
    examples: AtomicU64,
    // ORDERING(forwards): counter — metrics statistic only.
    forwards: AtomicU64,
    // ORDERING(retries): counter — metrics statistic only.
    retries: AtomicU64,
    // ORDERING(respawns): counter — statistic; respawn *mutual
    // exclusion* is the worker mutex's generation check, never this.
    respawns: AtomicU64,
    // ORDERING(timeouts): counter — metrics statistic only.
    timeouts: AtomicU64,
    latencies_ms: Mutex<Vec<f64>>,
    fault: Mutex<FaultPlan>,
}

impl Shared {
    fn new(fault: FaultPlan) -> Shared {
        Shared {
            requests: AtomicU64::new(0),
            examples: AtomicU64::new(0),
            forwards: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            latencies_ms: Mutex::new(Vec::new()),
            fault: Mutex::new(fault),
        }
    }
}

/// One worker generation: its inbox, join handle, and a generation id
/// so two concurrent callers that both observe a wedge don't respawn
/// twice (the second sees the generation already moved on).
struct Worker {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
    generation: u64,
}

/// Handle to the supervised router.
pub struct EvalRouter {
    opts: RouterOpts,
    stores: Arc<Vec<ParamStore>>,
    shared: Arc<Shared>,
    worker: Mutex<Worker>,
}

enum Attempt {
    Done(f64),
    /// retry the whole request; the worker was already respawned if it
    /// needed to be
    Retry(String),
}

impl EvalRouter {
    /// Spawn the router with the legacy signature (no eval timeout, no
    /// injected faults) — existing call sites keep working.
    pub fn spawn(
        backend: String,
        artifacts_dir: String,
        config_name: String,
        entry_name: String,
        stores: Vec<ParamStore>,
        max_wait: Duration,
    ) -> Result<EvalRouter> {
        EvalRouter::with_opts(
            RouterOpts {
                backend,
                artifacts_dir,
                config: config_name,
                entry: entry_name,
                max_wait,
                ..RouterOpts::default()
            },
            stores,
        )
    }

    /// Spawn the router with full supervision options. The runtime
    /// thread builds its own backend from `opts.backend` over
    /// `opts.artifacts_dir` and uploads the retained `stores` — the
    /// same stores a respawn re-uploads from.
    pub fn with_opts(mut opts: RouterOpts, stores: Vec<ParamStore>) -> Result<EvalRouter> {
        if opts.fault.is_empty() {
            if let Some(plan) = FaultPlan::from_env()? {
                opts.fault = plan;
            }
        }
        let stores = Arc::new(stores);
        let shared = Arc::new(Shared::new(std::mem::take(&mut opts.fault)));
        let (tx, join) = spawn_worker(&opts, &stores, &shared, 0)?;
        Ok(EvalRouter {
            opts,
            stores,
            shared,
            worker: Mutex::new(Worker { tx, join: Some(join), generation: 0 }),
        })
    }

    /// Evaluate examples; returns exact-match accuracy. Blocks, but
    /// never forever when `eval_timeout` is set: a wedged worker is
    /// respawned and the request retried (`max_retries`, exponential
    /// backoff) before giving up with a clean error.
    pub fn eval(&self, examples: Vec<Example>, rank_mask: Option<HostTensor>) -> Result<f64> {
        let mut backoff = self.opts.retry_backoff;
        let mut tries = 0u32;
        loop {
            match self.try_eval(&examples, &rank_mask)? {
                Attempt::Done(acc) => return Ok(acc),
                Attempt::Retry(reason) => {
                    if tries >= self.opts.max_retries {
                        bail!("router eval failed after {tries} retries: {reason}");
                    }
                    tries += 1;
                    self.shared.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(backoff);
                    backoff = backoff.saturating_mul(2);
                }
            }
        }
    }

    fn try_eval(
        &self,
        examples: &[Example],
        rank_mask: &Option<HostTensor>,
    ) -> Result<Attempt> {
        let n = examples.len();
        let (reply, rx) = channel();
        let generation = {
            let w = self.worker.lock().unwrap_or_else(|e| e.into_inner());
            let msg = Msg::Eval {
                examples: examples.to_vec(),
                rank_mask: rank_mask.clone(),
                reply,
            };
            if w.tx.send(msg).is_err() {
                // worker died before we could even enqueue
                let generation = w.generation;
                drop(w);
                self.respawn(generation, "worker inbox closed")?;
                return Ok(Attempt::Retry("worker inbox closed".into()));
            }
            w.generation
        };
        let mut correct = 0usize;
        for _ in 0..n {
            let msg = match self.opts.eval_timeout {
                Some(t) => match rx.recv_timeout(t) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => {
                        self.shared.timeouts.fetch_add(1, Ordering::Relaxed);
                        self.respawn(generation, "eval reply timed out")?;
                        return Ok(Attempt::Retry(format!(
                            "eval reply timed out after {t:?}"
                        )));
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        self.respawn(generation, "worker dropped replies")?;
                        return Ok(Attempt::Retry("worker dropped replies".into()));
                    }
                },
                None => match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        self.respawn(generation, "worker dropped replies")?;
                        return Ok(Attempt::Retry("worker dropped replies".into()));
                    }
                },
            };
            match msg {
                Ok(ok) => correct += ok as usize,
                // the worker is alive and attributed the failure — no
                // respawn, just retry the request (injected faults and
                // transient backend errors land here)
                Err(e) => return Ok(Attempt::Retry(format!("router eval error: {e}"))),
            }
        }
        Ok(Attempt::Done(correct as f64 / n.max(1) as f64))
    }

    /// Replace the worker whose generation was `observed`. If another
    /// caller already respawned (generation moved on), this is a no-op
    /// — the fresh worker must not be killed for its predecessor's
    /// wedge. The old thread gets `control_timeout` to exit, then is
    /// detached (never a blocking join on a wedged backend).
    fn respawn(&self, observed: u64, reason: &str) -> Result<()> {
        let mut w = self.worker.lock().unwrap_or_else(|e| e.into_inner());
        if w.generation != observed {
            return Ok(());
        }
        crate::warn_!("eval router: respawning worker (generation {observed}): {reason}");
        let _ = w.tx.send(Msg::Shutdown);
        if let Some(join) = w.join.take() {
            let deadline = Instant::now() + self.opts.control_timeout;
            while !join.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            if join.is_finished() {
                let _ = join.join();
            }
            // else: detach — dropping the handle leaves the wedged
            // thread to die with its (now disconnected) inbox
        }
        let generation = observed + 1;
        let (tx, join) = spawn_worker(&self.opts, &self.stores, &self.shared, generation)?;
        *w = Worker { tx, join: Some(join), generation };
        self.shared.respawns.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Snapshot of the shared counters. Never messages the worker —
    /// safe to call (and returns promptly) even while the backend
    /// thread is wedged mid-forward.
    pub fn metrics(&self) -> Result<RouterMetrics> {
        let s = &self.shared;
        let examples = s.examples.load(Ordering::Relaxed);
        let forwards = s.forwards.load(Ordering::Relaxed);
        let mut sorted = s.latencies_ms.lock().unwrap_or_else(|e| e.into_inner()).clone();
        crate::util::sort_for_percentiles(&mut sorted);
        Ok(RouterMetrics {
            requests: s.requests.load(Ordering::Relaxed),
            examples,
            forwards,
            mean_occupancy: if forwards > 0 { examples as f64 / forwards as f64 } else { 0.0 },
            // shared nearest-rank percentile (crate::util) — small
            // samples report the true tail instead of an interior
            // element, and the router cannot drift from the serving
            // metrics path
            p50_latency_ms: crate::util::percentile(&sorted, 0.50),
            p99_latency_ms: crate::util::percentile(&sorted, 0.99),
            retries: s.retries.load(Ordering::Relaxed),
            respawns: s.respawns.load(Ordering::Relaxed),
            timeouts: s.timeouts.load(Ordering::Relaxed),
        })
    }
}

impl Drop for EvalRouter {
    fn drop(&mut self) {
        let mut w = self.worker.lock().unwrap_or_else(|e| e.into_inner());
        let _ = w.tx.send(Msg::Shutdown);
        if let Some(join) = w.join.take() {
            let deadline = Instant::now() + self.opts.control_timeout;
            while !join.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            if join.is_finished() {
                let _ = join.join();
            }
            // else: detach — dropping a router must not hang the
            // caller on a wedged backend thread
        }
    }
}

fn spawn_worker(
    opts: &RouterOpts,
    stores: &Arc<Vec<ParamStore>>,
    shared: &Arc<Shared>,
    generation: u64,
) -> Result<(Sender<Msg>, std::thread::JoinHandle<()>)> {
    let (tx, rx) = channel::<Msg>();
    let (backend, artifacts, config, entry) = (
        opts.backend.clone(),
        opts.artifacts_dir.clone(),
        opts.config.clone(),
        opts.entry.clone(),
    );
    let max_wait = opts.max_wait;
    let stores = Arc::clone(stores);
    let shared = Arc::clone(shared);
    let join = std::thread::Builder::new()
        .name(format!("shears-eval-router-{generation}"))
        .spawn(move || {
            if let Err(e) =
                worker_main(rx, &backend, &artifacts, &config, &entry, &stores, max_wait, &shared)
            {
                crate::warn_!("router worker exited with error: {e:#}");
            }
        })
        .context("spawn router worker thread")?;
    Ok((tx, join))
}

fn mask_key(m: &Option<HostTensor>) -> Vec<u8> {
    match m {
        None => Vec::new(),
        Some(t) => t.f32s().iter().flat_map(|x| x.to_le_bytes()).collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rx: Receiver<Msg>,
    backend: &str,
    artifacts_dir: &str,
    config_name: &str,
    entry_name: &str,
    stores: &[ParamStore],
    max_wait: Duration,
    shared: &Shared,
) -> Result<()> {
    let rt = Runtime::from_flag(backend, artifacts_dir)?;
    let manifest = rt.manifest()?;
    let cfg = manifest.config(config_name)?;
    let vocab = Vocab::new(cfg.vocab);
    // stores are frozen for the worker's lifetime: upload once, serve
    // every coalesced batch from resident (prepared-weight) buffers —
    // a respawn re-uploads from the same retained host stores
    let store_refs: Vec<&ParamStore> = stores.iter().collect();
    let session = ForwardSession::new(&rt, cfg, entry_name, &store_refs)?;
    let mut masks_by_key: std::collections::HashMap<Vec<u8>, HostTensor> = Default::default();

    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut open = true;

    while open || !queue.is_empty() {
        // 1. drain the channel (blocking only when idle)
        let msg = if queue.is_empty() && open {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => {
                    open = false;
                    None
                }
            }
        } else {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(std::sync::mpsc::TryRecvError::Empty) => None,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    None
                }
            }
        };
        match msg {
            Some(Msg::Eval { examples, rank_mask, reply }) => {
                shared.requests.fetch_add(1, Ordering::Relaxed);
                let key = mask_key(&rank_mask);
                if let Some(m) = rank_mask {
                    masks_by_key.entry(key.clone()).or_insert(m);
                }
                let now = Instant::now();
                for example in examples {
                    shared.examples.fetch_add(1, Ordering::Relaxed);
                    queue.push_back(Pending {
                        example,
                        mask_key: key.clone(),
                        reply: reply.clone(),
                        enqueued: now,
                    });
                }
                // keep draining to coalesce concurrent requests
                if queue.len() < cfg.batch_eval {
                    // small grace period for more arrivals
                    let deadline = Instant::now() + max_wait;
                    while queue.len() < cfg.batch_eval && Instant::now() < deadline {
                        match rx.try_recv() {
                            Ok(Msg::Eval { examples, rank_mask, reply }) => {
                                shared.requests.fetch_add(1, Ordering::Relaxed);
                                let key = mask_key(&rank_mask);
                                if let Some(m) = rank_mask {
                                    masks_by_key.entry(key.clone()).or_insert(m);
                                }
                                let now = Instant::now();
                                for example in examples {
                                    shared.examples.fetch_add(1, Ordering::Relaxed);
                                    queue.push_back(Pending {
                                        example,
                                        mask_key: key.clone(),
                                        reply: reply.clone(),
                                        enqueued: now,
                                    });
                                }
                            }
                            Ok(Msg::Shutdown) | Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                    }
                }
            }
            Some(Msg::Shutdown) => {
                open = false;
            }
            None => {}
        }

        // 2. run one coalesced batch for the mask group at the queue head
        if let Some(head_key) = queue.front().map(|p| p.mask_key.clone()) {
            let mut group: Vec<Pending> = Vec::with_capacity(cfg.batch_eval);
            let mut rest: VecDeque<Pending> = VecDeque::new();
            while let Some(p) = queue.pop_front() {
                if p.mask_key == head_key && group.len() < cfg.batch_eval {
                    group.push(p);
                } else {
                    rest.push_back(p);
                }
            }
            queue = rest;

            // consult the fault plan before touching the backend: one
            // eval attempt per coalesced forward, counter shared with
            // every worker generation
            let fire = {
                let mut plan = shared.fault.lock().unwrap_or_else(|e| e.into_inner());
                if plan.is_empty() { EvalFire::default() } else { plan.fire_eval() }
            };
            if fire.hang_ms > 0 {
                // emulate a wedged backend; with an eval timeout armed
                // the caller respawns around us, our replies land in a
                // dropped channel, and this generation exits on its
                // disconnected inbox
                std::thread::sleep(Duration::from_millis(fire.hang_ms));
            }
            if fire.error {
                let msg = format!("injected eval fault (attempt {})", fire.attempt);
                for p in &group {
                    let _ = p.reply.send(Err(msg.clone()));
                }
                continue;
            }

            let exs: Vec<&Example> = group.iter().map(|p| &p.example).collect();
            let batch = build_batch(&exs, cfg.batch_eval, cfg.seq_len, &vocab, MaskMode::AnswerOnly);
            let mask_ref = if head_key.is_empty() { None } else { masks_by_key.get(&head_key) };
            shared.forwards.fetch_add(1, Ordering::Relaxed);
            match session.logits(&batch.x, mask_ref) {
                Ok(logits) => {
                    let mut lat = shared.latencies_ms.lock().unwrap_or_else(|e| e.into_inner());
                    for (row, p) in group.iter().enumerate() {
                        let ok = exact_match(&p.example, &logits, row, cfg.seq_len, cfg.vocab);
                        lat.push(p.enqueued.elapsed().as_secs_f64() * 1e3);
                        let _ = p.reply.send(Ok(ok));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in &group {
                        let _ = p.reply.send(Err(msg.clone()));
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_key_distinguishes_masks() {
        let a = Some(HostTensor::from_f32(&[2], vec![1.0, 0.0]));
        let b = Some(HostTensor::from_f32(&[2], vec![1.0, 1.0]));
        assert_ne!(mask_key(&a), mask_key(&b));
        assert_eq!(mask_key(&None), Vec::<u8>::new());
        assert_eq!(mask_key(&a), mask_key(&a.clone()));
    }

    #[test]
    fn router_opts_default_is_the_legacy_contract() {
        let o = RouterOpts::default();
        assert!(o.eval_timeout.is_none(), "no per-reply timeout unless asked");
        assert!(o.fault.is_empty());
        assert!(o.max_retries > 0);
        assert!(o.control_timeout > Duration::ZERO);
    }
}
