//! Eval request router with dynamic batching (the vLLM-router-shaped
//! component of L3; see DESIGN.md §5).
//!
//! Callers submit evaluation requests (a set of examples + an optional
//! sub-adapter rank mask) from any thread; a dedicated runtime thread
//! owns the backend (PJRT handles and the native exe cache are not
//! `Send`) and coalesces
//! queued examples into full `batch_eval`-sized forwards. Examples from
//! *different* requests sharing the same rank mask ride the same forward
//! pass — dynamic batching — and results are scattered back per request.

use crate::data::batch::{build_batch, MaskMode};
use crate::data::{Example, Vocab};
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use crate::train::{exact_match, ForwardSession};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One queued example with its reply slot.
struct Pending {
    example: Example,
    mask_key: Vec<u8>,
    reply: Sender<Result<bool, String>>,
    enqueued: Instant,
}

enum Msg {
    Eval {
        examples: Vec<Example>,
        rank_mask: Option<HostTensor>,
        reply: Sender<Result<bool, String>>,
    },
    Metrics(Sender<RouterMetrics>),
    Shutdown,
}

/// Router throughput/latency counters.
#[derive(Clone, Debug, Default)]
pub struct RouterMetrics {
    pub requests: u64,
    pub examples: u64,
    pub forwards: u64,
    /// mean examples per forward (batching efficiency)
    pub mean_occupancy: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
}

/// Handle to the router thread.
pub struct EvalRouter {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl EvalRouter {
    /// Spawn the router. The runtime thread builds its own backend from
    /// `backend` (`native|pjrt|auto`, same grammar as `--backend`) over
    /// `artifacts_dir` and owns the stores — an explicit spec, so the
    /// spawner's backend choice is never overridden by env/auto-detection.
    pub fn spawn(
        backend: String,
        artifacts_dir: String,
        config_name: String,
        entry_name: String,
        stores: Vec<ParamStore>,
        max_wait: Duration,
    ) -> Result<EvalRouter> {
        let (tx, rx) = channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("shears-eval-router".into())
            .spawn(move || {
                if let Err(e) = router_main(
                    rx,
                    &backend,
                    &artifacts_dir,
                    &config_name,
                    &entry_name,
                    stores,
                    max_wait,
                ) {
                    crate::warn_!("router exited with error: {e:#}");
                }
            })
            .context("spawn router thread")?;
        Ok(EvalRouter { tx, join: Some(join) })
    }

    /// Evaluate examples; returns exact-match accuracy. Blocks.
    pub fn eval(&self, examples: Vec<Example>, rank_mask: Option<HostTensor>) -> Result<f64> {
        let n = examples.len();
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Eval { examples, rank_mask, reply })
            .ok()
            .context("router gone")?;
        let mut correct = 0usize;
        for _ in 0..n {
            match rx.recv().context("router dropped replies")? {
                Ok(ok) => correct += ok as usize,
                Err(e) => anyhow::bail!("router eval error: {e}"),
            }
        }
        Ok(correct as f64 / n.max(1) as f64)
    }

    pub fn metrics(&self) -> Result<RouterMetrics> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Metrics(tx)).ok().context("router gone")?;
        rx.recv().context("router dropped metrics")
    }
}

impl Drop for EvalRouter {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn mask_key(m: &Option<HostTensor>) -> Vec<u8> {
    match m {
        None => Vec::new(),
        Some(t) => t.f32s().iter().flat_map(|x| x.to_le_bytes()).collect(),
    }
}

fn router_main(
    rx: Receiver<Msg>,
    backend: &str,
    artifacts_dir: &str,
    config_name: &str,
    entry_name: &str,
    stores: Vec<ParamStore>,
    max_wait: Duration,
) -> Result<()> {
    let rt = Runtime::from_flag(backend, artifacts_dir)?;
    let manifest = rt.manifest()?;
    let cfg = manifest.config(config_name)?;
    let vocab = Vocab::new(cfg.vocab);
    // stores are frozen for the router's lifetime: upload once, serve
    // every coalesced batch from resident (prepared-weight) buffers
    let store_refs: Vec<&ParamStore> = stores.iter().collect();
    let session = ForwardSession::new(&rt, cfg, entry_name, &store_refs)?;
    let mut masks_by_key: std::collections::HashMap<Vec<u8>, HostTensor> = Default::default();

    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut latencies_ms: Vec<f64> = Vec::new();
    let mut metrics = RouterMetrics::default();
    let mut open = true;

    while open || !queue.is_empty() {
        // 1. drain the channel (blocking only when idle)
        let msg = if queue.is_empty() && open {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => {
                    open = false;
                    None
                }
            }
        } else {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(std::sync::mpsc::TryRecvError::Empty) => None,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    None
                }
            }
        };
        match msg {
            Some(Msg::Eval { examples, rank_mask, reply }) => {
                metrics.requests += 1;
                let key = mask_key(&rank_mask);
                if let Some(m) = rank_mask {
                    masks_by_key.entry(key.clone()).or_insert(m);
                }
                let now = Instant::now();
                for example in examples {
                    metrics.examples += 1;
                    queue.push_back(Pending {
                        example,
                        mask_key: key.clone(),
                        reply: reply.clone(),
                        enqueued: now,
                    });
                }
                // keep draining to coalesce concurrent requests
                if queue.len() < cfg.batch_eval {
                    // small grace period for more arrivals
                    let deadline = Instant::now() + max_wait;
                    while queue.len() < cfg.batch_eval && Instant::now() < deadline {
                        match rx.try_recv() {
                            Ok(Msg::Eval { examples, rank_mask, reply }) => {
                                metrics.requests += 1;
                                let key = mask_key(&rank_mask);
                                if let Some(m) = rank_mask {
                                    masks_by_key.entry(key.clone()).or_insert(m);
                                }
                                let now = Instant::now();
                                for example in examples {
                                    metrics.examples += 1;
                                    queue.push_back(Pending {
                                        example,
                                        mask_key: key.clone(),
                                        reply: reply.clone(),
                                        enqueued: now,
                                    });
                                }
                            }
                            Ok(Msg::Metrics(tx)) => {
                                send_metrics(&tx, &metrics, &latencies_ms);
                            }
                            Ok(Msg::Shutdown) | Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                    }
                }
            }
            Some(Msg::Metrics(tx)) => {
                send_metrics(&tx, &metrics, &latencies_ms);
                continue;
            }
            Some(Msg::Shutdown) => {
                open = false;
            }
            None => {}
        }

        // 2. run one coalesced batch for the mask group at the queue head
        if let Some(head_key) = queue.front().map(|p| p.mask_key.clone()) {
            let mut group: Vec<Pending> = Vec::with_capacity(cfg.batch_eval);
            let mut rest: VecDeque<Pending> = VecDeque::new();
            while let Some(p) = queue.pop_front() {
                if p.mask_key == head_key && group.len() < cfg.batch_eval {
                    group.push(p);
                } else {
                    rest.push_back(p);
                }
            }
            queue = rest;
            let exs: Vec<&Example> = group.iter().map(|p| &p.example).collect();
            let batch = build_batch(&exs, cfg.batch_eval, cfg.seq_len, &vocab, MaskMode::AnswerOnly);
            let mask_ref = if head_key.is_empty() { None } else { masks_by_key.get(&head_key) };
            metrics.forwards += 1;
            match session.logits(&batch.x, mask_ref) {
                Ok(logits) => {
                    for (row, p) in group.iter().enumerate() {
                        let ok = exact_match(&p.example, &logits, row, cfg.seq_len, cfg.vocab);
                        latencies_ms.push(p.enqueued.elapsed().as_secs_f64() * 1e3);
                        let _ = p.reply.send(Ok(ok));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for p in &group {
                        let _ = p.reply.send(Err(msg.clone()));
                    }
                }
            }
        }
    }
    Ok(())
}

fn send_metrics(tx: &Sender<RouterMetrics>, m: &RouterMetrics, lat: &[f64]) {
    let mut out = m.clone();
    out.mean_occupancy = if m.forwards > 0 {
        m.examples as f64 / m.forwards as f64
    } else {
        0.0
    };
    // shared nearest-rank percentile (crate::util) — small samples
    // report the true tail instead of an interior element, and the
    // router cannot drift from the serving metrics path
    let mut sorted = lat.to_vec();
    crate::util::sort_for_percentiles(&mut sorted);
    out.p50_latency_ms = crate::util::percentile(&sorted, 0.50);
    out.p99_latency_ms = crate::util::percentile(&sorted, 0.99);
    let _ = tx.send(out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_key_distinguishes_masks() {
        let a = Some(HostTensor::from_f32(&[2], vec![1.0, 0.0]));
        let b = Some(HostTensor::from_f32(&[2], vec![1.0, 1.0]));
        assert_ne!(mask_key(&a), mask_key(&b));
        assert_eq!(mask_key(&None), Vec::<u8>::new());
        assert_eq!(mask_key(&a), mask_key(&a.clone()));
    }
}
