//! Layer-3 coordination: the Shears pipeline (paper Figure 1) and the
//! eval request router with dynamic batching.

pub mod pipeline;
pub mod router;

pub use pipeline::{PipelineOpts, PipelineReport, ShearsPipeline};
pub use router::{EvalRouter, RouterMetrics, RouterOpts};
