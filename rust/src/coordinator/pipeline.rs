//! The Shears pipeline (paper §3, Figure 1):
//!
//! ```text
//!   stage 0  pretrain base model        (stand-in for LLaMA/MPT weights)
//!   stage 1  unstructured sparsification  — Wanda / SparseGPT / magnitude
//!   stage 2  super-adapter training (NLS) — random sub-adapter per step
//!   stage 3  sub-adapter search           — heuristic, then optional
//!                                           hill-climbing / RNSGA-II
//!   stage 4  evaluation                   — per-task answer accuracy
//! ```
//!
//! Stage 0 is cached to `workdir` (keyed by config/steps/seed) because
//! every experiment in the bench suite shares the same pretrained base —
//! the analogue of downloading the same LLaMA checkpoint once.

use crate::coordinator::router::{EvalRouter, RouterOpts};
use crate::data::batch::{Batcher, MaskMode};
use crate::data::{self, corpus, Example, Task, Vocab};
use crate::model::{Manifest, ModelConfig, ParamStore};
use crate::nls::{SearchSpace, SubAdapterConfig};
use crate::pruning::{self, CalibStats, Method};
use crate::runtime::Runtime;
use crate::search::{hill_climb_durable, CachedEvaluator, DurableOpts};
use crate::train::{evaluate, train_loop, TrainLog, TrainOpts};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Duration;

/// Everything a Shears run needs (defaults = quick tiny-config run).
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub config: String,
    pub method: Method,
    pub sparsity: f64,
    pub pretrain_steps: usize,
    pub train_steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub tasks: Vec<Task>,
    pub train_examples: usize,
    pub eval_examples: usize,
    pub calib_batches: usize,
    /// run hill-climbing refinement after the heuristic (paper §3.3)
    pub hill_climb_budget: usize,
    /// examples used per search evaluation (smaller = cheaper search)
    pub search_eval_examples: usize,
    pub workdir: Option<PathBuf>,
    /// snapshot train state / search state every N steps (0 = resilience
    /// guards off: legacy single-shot behavior)
    pub checkpoint_every: usize,
    /// pick up train / search runs from their durable state under
    /// `workdir` (no-op when no state exists)
    pub resume: bool,
    /// training divergence rollbacks tolerated before aborting
    pub rollback_budget: usize,
    /// run search evals through a supervised [`EvalRouter`] worker with
    /// this per-call timeout (0 = in-process evals, no supervision)
    pub eval_timeout_ms: u64,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts {
            config: "tiny-llama".into(),
            method: Method::Wanda,
            sparsity: 0.5,
            pretrain_steps: 200,
            train_steps: 150,
            lr: 3e-3,
            seed: 42,
            tasks: vec![Task::Gsm8kSim],
            train_examples: 256,
            eval_examples: 64,
            calib_batches: 4,
            hill_climb_budget: 0,
            search_eval_examples: 32,
            workdir: None,
            checkpoint_every: 0,
            resume: false,
            rollback_budget: 3,
            eval_timeout_ms: 0,
        }
    }
}

/// Per-task accuracy plus the chosen sub-adapter.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub config: String,
    pub method: String,
    pub sparsity_target: f64,
    pub sparsity_measured: f64,
    pub sub_adapter: SubAdapterConfig,
    pub task_accuracy: Vec<(String, f64)>,
    pub pretrain_log: TrainLog,
    pub train_log: TrainLog,
    pub nonzero_params: usize,
    pub total_params: usize,
}

impl PipelineReport {
    pub fn mean_accuracy(&self) -> f64 {
        let n = self.task_accuracy.len().max(1);
        self.task_accuracy.iter().map(|(_, a)| a).sum::<f64>() / n as f64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", s(&self.config)),
            ("method", s(&self.method)),
            ("sparsity_target", num(self.sparsity_target)),
            ("sparsity_measured", num(self.sparsity_measured)),
            (
                "sub_adapter",
                arr(self.sub_adapter.ranks.iter().map(|r| num(*r as f64)).collect()),
            ),
            (
                "task_accuracy",
                obj(self
                    .task_accuracy
                    .iter()
                    .map(|(t, a)| (t.as_str(), num(*a)))
                    .collect()),
            ),
            ("mean_accuracy", num(self.mean_accuracy())),
            ("nonzero_params", num(self.nonzero_params as f64)),
            ("total_params", num(self.total_params as f64)),
        ])
    }
}

pub struct ShearsPipeline<'rt> {
    pub rt: &'rt Runtime,
    pub manifest: &'rt Manifest,
    pub cfg: &'rt ModelConfig,
    pub vocab: Vocab,
    pub opts: PipelineOpts,
}

impl<'rt> ShearsPipeline<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &'rt Manifest,
        opts: PipelineOpts,
    ) -> Result<Self> {
        let cfg = manifest.config(&opts.config)?;
        let vocab = Vocab::new(cfg.vocab);
        Ok(ShearsPipeline { rt, manifest, cfg, vocab, opts })
    }

    // ------------------------------------------------- stage 0: pretrain

    fn pretrain_ckpt_path(&self) -> Option<PathBuf> {
        self.workdir_file(&format!(
            "pretrain_{}_{}steps_seed{}.bin",
            self.cfg.name, self.opts.pretrain_steps, self.opts.seed
        ))
    }

    /// A file under `workdir` (created on demand), or `None` when the
    /// pipeline runs without a workdir.
    fn workdir_file(&self, name: &str) -> Option<PathBuf> {
        self.opts.workdir.as_ref().map(|d| {
            let _ = std::fs::create_dir_all(d);
            d.join(name)
        })
    }

    /// Guarded-train defaults shared by the pretrain and super-adapter
    /// stages: periodic last-good checkpoints (divergence rollback) that
    /// also persist under `workdir` for `resume`.
    fn guarded_train_defaults(&self, state_file: &str) -> TrainOpts {
        TrainOpts {
            checkpoint_every: self.opts.checkpoint_every,
            checkpoint_path: self.workdir_file(state_file),
            resume: self.opts.resume,
            rollback_budget: self.opts.rollback_budget,
            ..TrainOpts::default()
        }
    }

    /// Pretrain the base model on the synthetic corpus (or load the cache).
    pub fn pretrained_base(&self) -> Result<(ParamStore, TrainLog)> {
        if let Some(path) = self.pretrain_ckpt_path() {
            if path.exists() {
                crate::info!("pretrain cache hit: {}", path.display());
                return Ok((ParamStore::load(&path)?, TrainLog::default()));
            }
        }
        let mut rng = Rng::new(self.opts.seed);
        let mut base = ParamStore::init_base(self.cfg, &mut rng, 0.05);
        // all-ones prune masks: pretraining is full-FT without sparsity
        let mut masks = ParamStore::new();
        for p in &self.cfg.prunable {
            masks.insert(&p.name, crate::tensor::HostTensor::ones(&p.shape));
        }
        let corpus: Vec<Example> = {
            let mut crng = rng.fork(1);
            (0..self.opts.train_examples.max(256))
                .map(|_| {
                    let toks = corpus::sample(&self.vocab, &mut crng, self.cfg.seq_len);
                    let n = toks.len();
                    Example { tokens: toks, answer_start: 1, answer_len: n - 1 }
                })
                .collect()
        };
        let mut batcher = Batcher::new(
            &corpus,
            self.cfg.batch_train,
            self.cfg.seq_len,
            &self.vocab,
            MaskMode::FullSequence,
        );
        let opts = TrainOpts {
            steps: self.opts.pretrain_steps,
            lr: self.opts.lr,
            warmup: (self.opts.pretrain_steps / 10).max(5),
            seed: self.opts.seed,
            sample_nls: false,
            log_every: 50,
            ..self.guarded_train_defaults(&format!(
                "pretrain_{}_{}steps_seed{}.train_state.bin",
                self.cfg.name, self.opts.pretrain_steps, self.opts.seed
            ))
        };
        let frozen = ParamStore::new(); // full-FT: nothing frozen
        let log = train_loop(
            self.rt,
            self.cfg,
            "train_step_full",
            &frozen,
            &mut base,
            Some(&masks),
            &mut batcher,
            None,
            &opts,
        )?;
        if let Some(path) = self.pretrain_ckpt_path() {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            base.save(&path)?;
            crate::info!("pretrain cached: {}", path.display());
        }
        Ok((base, log))
    }

    // ----------------------------------------------------- stage 1: prune

    /// Calibration batches come from the task mixture (the data the model
    /// will be tuned on — same choice as the paper's use of task data).
    pub fn calibration_batches(&self) -> Vec<crate::data::Batch> {
        let examples = self.task_mixture(0xCA11B, self.opts.calib_batches * self.cfg.batch_eval);
        let batcher = Batcher::new(
            &examples,
            self.cfg.batch_eval,
            self.cfg.seq_len,
            &self.vocab,
            MaskMode::AnswerOnly,
        );
        batcher.epoch()
    }

    pub fn prune_stage(
        &self,
        base: &mut ParamStore,
    ) -> Result<(ParamStore, Option<CalibStats>)> {
        let stats = if self.opts.method.needs_stats() && self.opts.sparsity > 0.0 {
            let batches = self.calibration_batches();
            Some(pruning::collect_stats(self.rt, self.cfg, base, &batches)?)
        } else {
            None
        };
        let masks = pruning::prune(
            self.rt,
            self.manifest,
            self.cfg,
            base,
            self.opts.method,
            self.opts.sparsity,
            stats.as_ref(),
        )?;
        Ok((masks, stats))
    }

    // ----------------------------------------- stage 2: super-adapter NLS

    fn task_mixture(&self, salt: u64, count: usize) -> Vec<Example> {
        let mut out = Vec::with_capacity(count);
        let per = count.div_ceil(self.opts.tasks.len());
        for task in &self.opts.tasks {
            out.extend(data::dataset(
                *task,
                &self.vocab,
                self.opts.seed ^ salt,
                per,
                self.cfg.seq_len,
            ));
        }
        let mut rng = Rng::new(self.opts.seed ^ salt ^ 0xF00D);
        rng.shuffle(&mut out);
        out.truncate(count);
        out
    }

    /// Fine-tune the super-adapter with NLS sampling (paper §3.2).
    pub fn super_train(
        &self,
        base: &ParamStore,
        space: &SearchSpace,
    ) -> Result<(ParamStore, TrainLog)> {
        let mut rng = Rng::new(self.opts.seed ^ 0xADA9);
        let mut adapters = ParamStore::init_adapters(self.cfg, &mut rng);
        let train_data = self.task_mixture(0x7EA1, self.opts.train_examples);
        let mut batcher = Batcher::new(
            &train_data,
            self.cfg.batch_train,
            self.cfg.seq_len,
            &self.vocab,
            MaskMode::AnswerOnly,
        );
        let opts = TrainOpts {
            steps: self.opts.train_steps,
            lr: self.opts.lr,
            warmup: (self.opts.train_steps / 10).max(5),
            seed: self.opts.seed,
            sample_nls: true,
            log_every: 50,
            ..self.guarded_train_defaults(&format!(
                "super_{}_{}steps_seed{}.train_state.bin",
                self.cfg.name, self.opts.train_steps, self.opts.seed
            ))
        };
        let log = train_loop(
            self.rt,
            self.cfg,
            "train_step_nls",
            base,
            &mut adapters,
            None,
            &mut batcher,
            Some(space),
            &opts,
        )?;
        Ok((adapters, log))
    }

    // ------------------------------------------------- stage 3: search

    /// Heuristic (Eq. 3) + optional hill-climbing refinement.
    ///
    /// With `checkpoint_every > 0` the climb snapshots durable state
    /// under `workdir` (and `resume` picks it up, replaying nothing the
    /// eval cache already paid for). With `eval_timeout_ms > 0`
    /// candidate evals run in a supervised [`EvalRouter`] worker: a
    /// wedged or failing eval is retried against a respawned worker
    /// instead of hanging the whole search.
    pub fn search_stage(
        &self,
        base: &ParamStore,
        adapters: &ParamStore,
        space: &SearchSpace,
    ) -> Result<SubAdapterConfig> {
        let start = space.heuristic();
        if self.opts.hill_climb_budget == 0 {
            return Ok(start);
        }
        let val = self.task_mixture(0x5EA7C4, self.opts.search_eval_examples);
        let durable = (self.opts.checkpoint_every > 0)
            .then(|| {
                self.workdir_file(&format!(
                    "search_hc_{}_seed{}.snap.bin",
                    self.cfg.name, self.opts.seed
                ))
            })
            .flatten()
            .map(|path| DurableOpts {
                path,
                every: self.opts.checkpoint_every,
                resume: self.opts.resume,
            });
        let r = if self.opts.eval_timeout_ms > 0 {
            let router = EvalRouter::with_opts(
                RouterOpts {
                    backend: self.rt.backend_name().to_string(),
                    artifacts_dir: self
                        .rt
                        .artifacts_dir()
                        .map(|d| d.display().to_string())
                        .unwrap_or_default(),
                    config: self.opts.config.clone(),
                    entry: "forward_eval".into(),
                    eval_timeout: Some(Duration::from_millis(self.opts.eval_timeout_ms)),
                    ..RouterOpts::default()
                },
                vec![base.clone(), adapters.clone()],
            )?;
            let mut cached = CachedEvaluator::new(|cfg: &SubAdapterConfig| {
                let mask = space.rank_mask(cfg);
                router.eval(val.clone(), Some(mask)).unwrap_or(0.0)
            });
            let r = hill_climb_durable(
                space,
                start,
                &mut cached,
                self.opts.hill_climb_budget,
                durable.as_ref(),
            )?;
            let m = router.metrics()?;
            crate::info!(
                "search evals: {} requests / {} forwards ({} retries, {} respawns, {} timeouts)",
                m.requests,
                m.forwards,
                m.retries,
                m.respawns,
                m.timeouts
            );
            r
        } else {
            let mut cached = CachedEvaluator::new(|cfg: &SubAdapterConfig| {
                let mask = space.rank_mask(cfg);
                evaluate(
                    self.rt,
                    self.cfg,
                    "forward_eval",
                    &[base, adapters],
                    Some(&mask),
                    &val,
                    &self.vocab,
                )
                .unwrap_or(0.0)
            });
            hill_climb_durable(
                space,
                start,
                &mut cached,
                self.opts.hill_climb_budget,
                durable.as_ref(),
            )?
        };
        crate::info!(
            "hill-climb: score {:.4} after {} evals",
            r.score,
            r.evals
        );
        Ok(r.config)
    }

    // ----------------------------------------------------- stage 4: eval

    pub fn eval_stage(
        &self,
        base: &ParamStore,
        adapters: &ParamStore,
        space: &SearchSpace,
        sub: &SubAdapterConfig,
    ) -> Result<Vec<(String, f64)>> {
        let mask = space.rank_mask(sub);
        let mut out = Vec::new();
        for task in &self.opts.tasks {
            let test = data::dataset(
                *task,
                &self.vocab,
                self.opts.seed ^ 0x7E57,
                self.opts.eval_examples,
                self.cfg.seq_len,
            );
            let acc = evaluate(
                self.rt,
                self.cfg,
                "forward_eval",
                &[base, adapters],
                Some(&mask),
                &test,
                &self.vocab,
            )?;
            out.push((task.name().to_string(), acc));
        }
        Ok(out)
    }

    /// Full pipeline: stages 0–4.
    pub fn run(&self) -> Result<PipelineReport> {
        let (mut base, pretrain_log) = self.pretrained_base()?;
        let total_params = base.numel();
        let (_masks, _stats) = self.prune_stage(&mut base)?;
        let measured = {
            let names: Vec<String> =
                self.cfg.prunable.iter().map(|p| p.name.clone()).collect();
            base.sparsity_of(&names)
        };
        let space = SearchSpace::from_config(self.cfg);
        let (adapters, train_log) = self.super_train(&base, &space)?;
        let sub = self.search_stage(&base, &adapters, &space)?;
        let task_accuracy = self.eval_stage(&base, &adapters, &space, &sub)?;
        let nonzero = pruning::nonzero_params(&base, Some(&adapters));
        Ok(PipelineReport {
            config: self.cfg.name.clone(),
            method: self.opts.method.name().to_string(),
            sparsity_target: self.opts.sparsity,
            sparsity_measured: measured,
            sub_adapter: sub,
            task_accuracy,
            pretrain_log,
            train_log,
            nonzero_params: nonzero,
            total_params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let o = PipelineOpts::default();
        assert_eq!(o.config, "tiny-llama");
        assert!(o.sparsity > 0.0 && o.sparsity < 1.0);
        assert!(!o.tasks.is_empty());
    }

    #[test]
    fn report_mean_and_json() {
        let r = PipelineReport {
            config: "t".into(),
            method: "wanda".into(),
            sparsity_target: 0.5,
            sparsity_measured: 0.499,
            sub_adapter: SubAdapterConfig { ranks: vec![6, 6] },
            task_accuracy: vec![("a".into(), 0.4), ("b".into(), 0.6)],
            pretrain_log: TrainLog::default(),
            train_log: TrainLog::default(),
            nonzero_params: 100,
            total_params: 200,
        };
        assert!((r.mean_accuracy() - 0.5).abs() < 1e-12);
        let j = r.to_json().to_string();
        assert!(j.contains("\"mean_accuracy\""));
        assert!(j.contains("\"sub_adapter\""));
    }
}
