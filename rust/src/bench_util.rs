//! Benchmark harness (offline substitute for criterion, DESIGN.md §3).
//!
//! Used by every `rust/benches/*.rs` (harness = false). Provides wall
//! timing with warmup, simple stats, and the markdown table printer the
//! paper-table benches emit so `cargo bench | tee bench_output.txt`
//! reproduces the tables' layout.

use std::time::Instant;

/// Timing stats over n iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub label: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured ones.
pub fn time<F: FnMut()>(label: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    Stats {
        label: label.to_string(),
        iters,
        mean_ms: mean,
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ms: samples.iter().cloned().fold(0.0, f64::max),
    }
}

impl Stats {
    pub fn print(&self) {
        println!(
            "{:<42} {:>10.3} ms/iter  (min {:.3}, max {:.3}, n={})",
            self.label, self.mean_ms, self.min_ms, self.max_ms, self.iters
        );
    }
}

/// Markdown table printer for paper-table reproduction benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n### {}\n", self.title);
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
        println!();
    }
}

/// Percent formatting helper (accuracy cells).
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_orders() {
        let s = time("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.min_ms <= s.mean_ms && s.mean_ms <= s.max_ms + 1e-9);
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.4567), "45.7");
    }
}
