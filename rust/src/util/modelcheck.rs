//! Exhaustive bounded-interleaving model checking for the crate's
//! lock-free protocols (loom-style, in-crate: the sandbox vendors
//! dependencies, so the explorer is ~150 lines of plain DFS).
//!
//! A [`Model`] is an abstract state machine: a fixed set of logical
//! threads, each advancing through atomic actions ([`Model::step`]).
//! One action corresponds to one linearization point of the real code
//! — a single atomic RMW, or one mutex critical section (sound at
//! that granularity because the real mutex serializes the region).
//! The [`Explorer`] enumerates **every** schedule (optionally up to a
//! preemption bound), checking [`Model::invariant`] after each step
//! and [`Model::at_end`] in each terminal state, and reports the
//! first violation with the thread trace that produced it. Threads
//! that may legitimately block forever (parked pool workers, detached
//! wedged threads) declare [`Model::park_ok`]; any other thread left
//! permanently blocked is a deadlock.
//!
//! Three protocol models mirror the real implementations line for
//! line (source references in each):
//!
//! * [`PoolModel`] — the kernel pool's chunk-claim / pending-counter
//!   protocol in `ops/linalg.rs` (`pool::run`, `DispatchGuard`,
//!   `worker_loop`), including the panic-unwind decrement.
//! * [`SubmitModel`] — `serve/server.rs`'s submit-vs-shutdown path:
//!   `accepting` check → depth CAS reservation → channel send →
//!   `closed` re-check with idempotent self-finish, against the
//!   runtime thread's close-then-drain shutdown.
//! * [`RouterModel`] — `coordinator/router.rs`'s generation-checked
//!   respawn with bounded-wait-then-detach on the wedged worker.
//!
//! Each model carries seeded-bug variants (the historical failure
//! modes the protocols were designed against); `tests/modelcheck.rs`
//! proves the explorer finds every one, then proves the shipped
//! protocols clean across all schedules. This replaces the earlier
//! 500-random-interleaving python spot checks with exhaustive
//! coverage.

// ------------------------------------------------------ the explorer

/// An abstract concurrent protocol: `n_threads` logical threads over
/// cloneable shared state.
pub trait Model: Clone {
    fn n_threads(&self) -> usize;
    /// Thread finished all its actions.
    fn done(&self, tid: usize) -> bool;
    /// Thread can take a step now (ignored when `done`).
    fn enabled(&self, tid: usize) -> bool;
    /// Blocked-forever is acceptable for this thread (parked worker,
    /// detached thread). Anything else stuck is a deadlock.
    fn park_ok(&self, tid: usize) -> bool {
        let _ = tid;
        false
    }
    /// Execute one atomic action of `tid`. Only called when enabled.
    fn step(&mut self, tid: usize);
    /// Checked after every step.
    fn invariant(&self) -> Result<(), String> {
        Ok(())
    }
    /// Checked in every terminal (all done/parked) state.
    fn at_end(&self) -> Result<(), String> {
        Ok(())
    }
}

/// A property violation plus the schedule (thread ids, in order) that
/// reaches it.
#[derive(Debug)]
pub struct Violation {
    pub msg: String,
    pub trace: Vec<usize>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (schedule {:?})", self.msg, self.trace)
    }
}

/// Exhaustiveness evidence: how much the DFS covered.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Complete schedules (terminal states) enumerated.
    pub schedules: u64,
    /// States visited (steps taken, counting revisits).
    pub states: u64,
}

/// Depth-first enumerator over all interleavings of a [`Model`].
pub struct Explorer {
    /// Max context switches away from a still-enabled thread
    /// (`None` = unbounded: every schedule).
    pub preemptions: Option<usize>,
    /// Abort (as a violation) past this many visited states — a
    /// runaway-model backstop, not a soundness bound.
    pub max_states: u64,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer { preemptions: None, max_states: 20_000_000 }
    }
}

impl Explorer {
    /// Enumerate every schedule; first violation wins.
    pub fn run<M: Model>(&self, model: &M) -> Result<Report, Violation> {
        let mut report = Report { schedules: 0, states: 0 };
        let mut trace = Vec::new();
        self.dfs(model, None, self.preemptions, &mut trace, &mut report)?;
        Ok(report)
    }

    fn dfs<M: Model>(
        &self,
        m: &M,
        last: Option<usize>,
        budget: Option<usize>,
        trace: &mut Vec<usize>,
        report: &mut Report,
    ) -> Result<(), Violation> {
        let n = m.n_threads();
        let runnable: Vec<usize> = (0..n).filter(|&t| !m.done(t) && m.enabled(t)).collect();
        if runnable.is_empty() {
            let stuck: Vec<usize> =
                (0..n).filter(|&t| !m.done(t) && !m.park_ok(t)).collect();
            if !stuck.is_empty() {
                return Err(Violation {
                    msg: format!("deadlock: threads {stuck:?} blocked with nothing enabled"),
                    trace: trace.clone(),
                });
            }
            report.schedules += 1;
            return m.at_end().map_err(|msg| Violation { msg, trace: trace.clone() });
        }
        for &t in &runnable {
            // running the same thread on is free; switching away from a
            // still-enabled thread spends one preemption
            let budget = match (last, budget) {
                (Some(l), Some(b)) if l != t && runnable.contains(&l) => {
                    if b == 0 {
                        continue;
                    }
                    Some(b - 1)
                }
                _ => budget,
            };
            report.states += 1;
            if report.states > self.max_states {
                return Err(Violation {
                    msg: format!("state budget exceeded ({} states)", self.max_states),
                    trace: trace.clone(),
                });
            }
            let mut next = m.clone();
            next.step(t);
            trace.push(t);
            let r = next
                .invariant()
                .map_err(|msg| Violation { msg, trace: trace.clone() })
                .and_then(|()| self.dfs(&next, Some(t), budget, trace, report));
            trace.pop();
            r?;
        }
        Ok(())
    }
}

// ---------------------------------------------------- 1. kernel pool

/// Seeded historical bugs for [`PoolModel`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum PoolBug {
    None,
    /// A chunk that panics skips its `pending` decrement — the
    /// guard's completion wait then deadlocks during the unwind.
    NoUnwindDecrement,
    /// The dispatcher clears the job without waiting for in-flight
    /// workers — a worker still holds the erased closure pointer.
    NoCompletionWait,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum DPhase {
    Publish,
    Claim,
    Run(usize),
    Decr(usize),
    Retract,
    WaitDone,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum WPhase {
    Idle,
    Run(usize),
    Decr(usize),
}

/// `ops/linalg.rs` pool protocol: dispatcher (thread 0) publishes a
/// job of `chunks` chunks then claims alongside `workers` pool
/// threads; chunk `panic_chunk` panics in whichever thread claims it.
/// The mutex-protected claim and decrement are separate actions, so
/// the dispatcher's completion wait really races in-flight workers.
#[derive(Clone)]
pub struct PoolModel {
    pub bug: PoolBug,
    chunks: usize,
    job: bool,
    next: usize,
    pending: i64,
    executed: Vec<u8>,
    retracted: Vec<bool>,
    panic_chunk: Option<usize>,
    worker_panicked: bool,
    dispatcher: DPhase,
    workers: Vec<WPhase>,
}

impl PoolModel {
    pub fn new(workers: usize, chunks: usize, panic_chunk: Option<usize>, bug: PoolBug) -> Self {
        PoolModel {
            bug,
            chunks,
            job: false,
            next: 0,
            pending: 0,
            executed: vec![0; chunks],
            retracted: vec![false; chunks],
            panic_chunk,
            worker_panicked: false,
            dispatcher: DPhase::Publish,
            workers: vec![WPhase::Idle; workers],
        }
    }

    fn decrement(&mut self, ci: usize, panicking: bool) {
        // the real code always decrements under the state lock, even on
        // the unwind path; `NoUnwindDecrement` re-introduces the bug
        if !(panicking && self.bug == PoolBug::NoUnwindDecrement) {
            self.pending -= 1;
        }
        let _ = ci;
    }
}

impl Model for PoolModel {
    fn n_threads(&self) -> usize {
        1 + self.workers.len()
    }

    fn done(&self, tid: usize) -> bool {
        tid == 0 && self.dispatcher == DPhase::Done
    }

    fn enabled(&self, tid: usize) -> bool {
        if tid == 0 {
            match self.dispatcher {
                DPhase::WaitDone => self.pending == 0,
                DPhase::Done => false,
                _ => true,
            }
        } else {
            match self.workers[tid - 1] {
                WPhase::Idle => self.job && self.next < self.chunks,
                _ => true,
            }
        }
    }

    fn park_ok(&self, tid: usize) -> bool {
        // workers park on `work_cv` between jobs, forever if none comes
        tid != 0 && self.workers[tid - 1] == WPhase::Idle
    }

    fn step(&mut self, tid: usize) {
        if tid == 0 {
            self.dispatcher = match self.dispatcher {
                DPhase::Publish => {
                    self.job = true;
                    self.next = 0;
                    self.pending = self.chunks as i64;
                    DPhase::Claim
                }
                DPhase::Claim => {
                    if self.next < self.chunks {
                        let ci = self.next;
                        self.next += 1;
                        DPhase::Run(ci)
                    } else {
                        DPhase::Retract // guard drop begins
                    }
                }
                DPhase::Run(ci) => {
                    self.executed[ci] += 1;
                    DPhase::Decr(ci)
                }
                DPhase::Decr(ci) => {
                    let panicking = self.panic_chunk == Some(ci);
                    self.decrement(ci, panicking);
                    if panicking {
                        DPhase::Retract // resume_unwind drops the guard
                    } else {
                        DPhase::Claim
                    }
                }
                DPhase::Retract => {
                    // DispatchGuard::drop — retract unclaimed chunks
                    for ci in self.next..self.chunks {
                        self.retracted[ci] = true;
                        self.pending -= 1;
                    }
                    self.next = self.chunks;
                    if self.bug == PoolBug::NoCompletionWait {
                        self.job = false;
                        DPhase::Done
                    } else {
                        DPhase::WaitDone
                    }
                }
                DPhase::WaitDone => {
                    // done_cv wait satisfied: pending == 0
                    self.job = false;
                    DPhase::Done
                }
                DPhase::Done => unreachable!(),
            };
        } else {
            let w = tid - 1;
            self.workers[w] = match self.workers[w] {
                WPhase::Idle => {
                    let ci = self.next;
                    self.next += 1;
                    WPhase::Run(ci)
                }
                WPhase::Run(ci) => {
                    if !self.job {
                        // the invariant below reports this before we get
                        // here, but keep the model total
                        self.executed[ci] = u8::MAX;
                    } else {
                        self.executed[ci] += 1;
                    }
                    WPhase::Decr(ci)
                }
                WPhase::Decr(ci) => {
                    let panicking = self.panic_chunk == Some(ci);
                    if panicking {
                        self.worker_panicked = true;
                    }
                    self.decrement(ci, panicking);
                    WPhase::Idle
                }
            };
        }
    }

    fn invariant(&self) -> Result<(), String> {
        if self.pending < 0 {
            return Err(format!("pending underflow: {}", self.pending));
        }
        if let Some(ci) = self.executed.iter().position(|&e| e > 1) {
            return Err(format!("chunk {ci} executed {} times", self.executed[ci]));
        }
        // the erased closure borrow: no worker may be running a chunk
        // after the dispatcher cleared the job
        let running = self.workers.iter().any(|w| matches!(w, WPhase::Run(_) | WPhase::Decr(_)));
        if running && !self.job && self.dispatcher == DPhase::Done {
            return Err("dispatcher returned while a worker still runs a chunk".into());
        }
        Ok(())
    }

    fn at_end(&self) -> Result<(), String> {
        for ci in 0..self.chunks {
            let e = self.executed[ci] == 1;
            let r = self.retracted[ci];
            if e == r {
                return Err(format!(
                    "chunk {ci}: executed={} retracted={r} (want exactly one)",
                    self.executed[ci]
                ));
            }
        }
        if self.pending != 0 {
            return Err(format!("terminal pending = {}", self.pending));
        }
        if self.panic_chunk.is_none() && self.retracted.iter().any(|&r| r) {
            return Err("chunks retracted without a panic".into());
        }
        Ok(())
    }
}

// ------------------------------------------- 2. submit-vs-shutdown

/// Seeded historical bugs for [`SubmitModel`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum SubmitBug {
    None,
    /// Publish `closed` *after* the final drain instead of before: a
    /// send landing between drain-end and the store is never finished
    /// by anyone — the caller hangs.
    ClosedAfterDrain,
    /// Reserve with a blind `fetch_add` + rollback instead of the CAS
    /// loop: the queue depth transiently overshoots the cap.
    BlindIncrement,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum SPhase {
    CheckAccepting,
    Reserve,
    RollbackCheck,
    Send,
    CheckClosed,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum RPhase {
    Serve(usize),
    StopAccepting,
    SetClosed,
    Drain,
    DropReceiver,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Outcome {
    Pending,
    Accepted,
    Rejected,
}

/// `serve/server.rs` submit path vs the runtime thread's shutdown
/// drain. Threads `0..submitters` each submit one request; the last
/// thread is the runtime, which serves `serve_budget` requests and
/// then shuts down (close → drain → drop receiver).
#[derive(Clone)]
pub struct SubmitModel {
    pub bug: SubmitBug,
    cap: usize,
    depth: i64,
    accepting: bool,
    closed: bool,
    queue_open: bool,
    queue: Vec<usize>,
    finished: Vec<bool>,
    outcome: Vec<Outcome>,
    sub: Vec<SPhase>,
    runtime: RPhase,
}

impl SubmitModel {
    pub fn new(submitters: usize, cap: usize, serve_budget: usize, bug: SubmitBug) -> Self {
        SubmitModel {
            bug,
            cap,
            depth: 0,
            accepting: true,
            closed: false,
            queue_open: true,
            queue: Vec::new(),
            finished: vec![false; submitters],
            outcome: vec![Outcome::Pending; submitters],
            sub: vec![SPhase::CheckAccepting; submitters],
            runtime: RPhase::Serve(serve_budget),
        }
    }

    fn finish(&mut self, id: usize) {
        // StreamShared::finish is idempotent — first caller wins
        self.finished[id] = true;
    }
}

impl Model for SubmitModel {
    fn n_threads(&self) -> usize {
        self.sub.len() + 1
    }

    fn done(&self, tid: usize) -> bool {
        if tid < self.sub.len() {
            self.sub[tid] == SPhase::Done
        } else {
            self.runtime == RPhase::Done
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        if tid < self.sub.len() {
            true
        } else {
            match self.runtime {
                // recv blocks until a request arrives; the budget
                // hitting zero — or every submitter resolving with the
                // queue empty — models the shutdown trigger arriving
                RPhase::Serve(left) => {
                    left == 0
                        || !self.queue.is_empty()
                        || self.sub.iter().all(|&s| s == SPhase::Done)
                }
                RPhase::Done => false,
                _ => true,
            }
        }
    }

    fn step(&mut self, tid: usize) {
        if tid < self.sub.len() {
            self.sub[tid] = match self.sub[tid] {
                SPhase::CheckAccepting => {
                    if self.accepting {
                        SPhase::Reserve
                    } else {
                        self.outcome[tid] = Outcome::Rejected;
                        SPhase::Done
                    }
                }
                SPhase::Reserve => match self.bug {
                    SubmitBug::BlindIncrement => {
                        self.depth += 1; // overshoot window until RollbackCheck
                        SPhase::RollbackCheck
                    }
                    _ => {
                        // the CAS loop's linearization point: reserve
                        // iff below cap, atomically
                        if (self.depth as usize) < self.cap {
                            self.depth += 1;
                            SPhase::Send
                        } else {
                            self.outcome[tid] = Outcome::Rejected;
                            SPhase::Done
                        }
                    }
                },
                SPhase::RollbackCheck => {
                    if self.depth as usize > self.cap {
                        self.depth -= 1;
                        self.outcome[tid] = Outcome::Rejected;
                        SPhase::Done
                    } else {
                        SPhase::Send
                    }
                }
                SPhase::Send => {
                    if self.queue_open {
                        self.queue.push(tid);
                        SPhase::CheckClosed
                    } else {
                        // send error: release the reservation, reject
                        self.depth -= 1;
                        self.outcome[tid] = Outcome::Rejected;
                        SPhase::Done
                    }
                }
                SPhase::CheckClosed => {
                    // SeqCst pairing with the runtime's close-then-drain:
                    // a send that completed after the final drain must
                    // observe closed == true and self-finish
                    if self.closed {
                        self.finish(tid);
                    }
                    self.outcome[tid] = Outcome::Accepted;
                    SPhase::Done
                }
                SPhase::Done => unreachable!(),
            };
        } else {
            self.runtime = match self.runtime {
                RPhase::Serve(left) => {
                    if left > 0 && !self.queue.is_empty() {
                        let id = self.queue.remove(0);
                        self.finish(id);
                        self.depth -= 1;
                        RPhase::Serve(left - 1)
                    } else {
                        RPhase::StopAccepting
                    }
                }
                RPhase::StopAccepting => {
                    self.accepting = false;
                    if self.bug == SubmitBug::ClosedAfterDrain {
                        RPhase::Drain
                    } else {
                        RPhase::SetClosed
                    }
                }
                RPhase::SetClosed => {
                    self.closed = true;
                    if self.bug == SubmitBug::ClosedAfterDrain {
                        RPhase::DropReceiver
                    } else {
                        RPhase::Drain
                    }
                }
                RPhase::Drain => {
                    if let Some(&id) = self.queue.first() {
                        self.queue.remove(0);
                        self.depth -= 1;
                        self.finish(id);
                        RPhase::Drain
                    } else if self.bug == SubmitBug::ClosedAfterDrain {
                        RPhase::SetClosed
                    } else {
                        RPhase::DropReceiver
                    }
                }
                RPhase::DropReceiver => {
                    self.queue_open = false;
                    RPhase::Done
                }
                RPhase::Done => unreachable!(),
            };
        }
    }

    fn invariant(&self) -> Result<(), String> {
        if self.depth as usize > self.cap {
            return Err(format!("queue depth {} exceeds cap {}", self.depth, self.cap));
        }
        if self.depth < 0 {
            return Err(format!("queue depth underflow: {}", self.depth));
        }
        Ok(())
    }

    fn at_end(&self) -> Result<(), String> {
        for (id, &o) in self.outcome.iter().enumerate() {
            match o {
                Outcome::Pending => return Err(format!("submitter {id} never resolved")),
                Outcome::Accepted if !self.finished[id] => {
                    return Err(format!(
                        "lost stream: submitter {id} accepted but never finished — caller hangs"
                    ));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

// ------------------------------------------------- 3. router respawn

/// Seeded historical bugs for [`RouterModel`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum RouterBug {
    None,
    /// Respawn without the generation check: two callers that both
    /// timed out against the same worker kill its replacement too.
    NoGenerationCheck,
    /// Join the wedged worker unconditionally instead of the bounded
    /// wait + detach: the caller blocks forever.
    JoinInsteadOfDetach,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum CPhase {
    Observe,
    Respawn,
    Done,
}

/// `coordinator/router.rs` respawn protocol: `callers` threads each
/// observe the worker generation (inside `try_eval`'s locked send),
/// time out, and call `respawn(observed)`. The original worker
/// (last thread) is wedged forever — `park_ok`, like the real
/// detached thread.
#[derive(Clone)]
pub struct RouterModel {
    pub bug: RouterBug,
    generation: u64,
    respawns: u64,
    observed: Vec<u64>,
    caller: Vec<CPhase>,
}

impl RouterModel {
    pub fn new(callers: usize, bug: RouterBug) -> Self {
        RouterModel {
            bug,
            generation: 0,
            respawns: 0,
            observed: vec![u64::MAX; callers],
            caller: vec![CPhase::Observe; callers],
        }
    }
}

impl Model for RouterModel {
    fn n_threads(&self) -> usize {
        self.caller.len() + 1 // + the wedged worker
    }

    fn done(&self, tid: usize) -> bool {
        tid < self.caller.len() && self.caller[tid] == CPhase::Done
    }

    fn enabled(&self, tid: usize) -> bool {
        if tid >= self.caller.len() {
            return false; // wedged mid-forward, never progresses
        }
        match self.caller[tid] {
            // JoinInsteadOfDetach: respawn blocks on the wedged
            // worker's exit, which never comes
            CPhase::Respawn if self.bug == RouterBug::JoinInsteadOfDetach => false,
            CPhase::Done => false,
            _ => true,
        }
    }

    fn park_ok(&self, tid: usize) -> bool {
        tid >= self.caller.len()
    }

    fn step(&mut self, tid: usize) {
        self.caller[tid] = match self.caller[tid] {
            CPhase::Observe => {
                // try_eval: generation read under the worker mutex
                self.observed[tid] = self.generation;
                CPhase::Respawn
            }
            CPhase::Respawn => {
                // respawn(): one mutex critical section — generation
                // check, bounded wait (terminates by construction),
                // detach, spawn replacement
                let stale = self.generation != self.observed[tid];
                if self.bug == RouterBug::NoGenerationCheck || !stale {
                    self.generation += 1;
                    self.respawns += 1;
                }
                CPhase::Done
            }
            CPhase::Done => unreachable!(),
        };
    }

    fn invariant(&self) -> Result<(), String> {
        if self.generation != self.respawns {
            return Err(format!(
                "generation {} out of sync with respawns {}",
                self.generation, self.respawns
            ));
        }
        Ok(())
    }

    fn at_end(&self) -> Result<(), String> {
        // one respawn per *distinct* observed generation: callers that
        // observed the same wedged worker must coalesce
        let mut distinct: Vec<u64> = self.observed.clone();
        distinct.sort_unstable();
        distinct.dedup();
        if self.respawns != distinct.len() as u64 {
            return Err(format!(
                "{} respawns for {} distinct observed generations {:?} — a fresh \
                 worker was killed for its predecessor's wedge",
                self.respawns,
                distinct.len(),
                self.observed
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads, two actions each: load then store of a shared
    /// counter. With `atomic` the increment is one action; without,
    /// the classic lost update exists and the explorer must find it.
    #[derive(Clone)]
    struct Counter {
        atomic: bool,
        value: u64,
        loaded: Vec<Option<u64>>,
        pc: Vec<usize>,
    }

    impl Counter {
        fn new(threads: usize, atomic: bool) -> Self {
            Counter { atomic, value: 0, loaded: vec![None; threads], pc: vec![0; threads] }
        }
    }

    impl Model for Counter {
        fn n_threads(&self) -> usize {
            self.pc.len()
        }
        fn done(&self, t: usize) -> bool {
            self.pc[t] == if self.atomic { 1 } else { 2 }
        }
        fn enabled(&self, _t: usize) -> bool {
            true
        }
        fn step(&mut self, t: usize) {
            if self.atomic {
                self.value += 1;
            } else if self.pc[t] == 0 {
                self.loaded[t] = Some(self.value);
            } else {
                self.value = self.loaded[t].unwrap() + 1;
            }
            self.pc[t] += 1;
        }
        fn at_end(&self) -> Result<(), String> {
            if self.value == self.pc.len() as u64 {
                Ok(())
            } else {
                Err(format!("lost update: {} != {}", self.value, self.pc.len()))
            }
        }
    }

    #[test]
    fn explorer_counts_all_schedules() {
        // 2 threads x 1 atomic action: exactly 2 interleavings
        let r = Explorer::default().run(&Counter::new(2, true)).unwrap();
        assert_eq!(r.schedules, 2);
        // 2 threads x 2 actions: C(4,2) = 6 interleavings
        let v = Explorer::default().run(&Counter::new(2, false)).unwrap_err();
        assert!(v.msg.contains("lost update"), "{v}");
    }

    #[test]
    fn preemption_bound_prunes_but_keeps_serial_schedules() {
        // bound 0: only the two serial schedules of the atomic model
        let e = Explorer { preemptions: Some(0), ..Explorer::default() };
        let r = e.run(&Counter::new(2, true)).unwrap();
        assert_eq!(r.schedules, 2);
        // the non-atomic lost update needs one preemption; bound 0
        // misses it, bound 1 finds it
        assert!(e.run(&Counter::new(2, false)).is_ok());
        let e1 = Explorer { preemptions: Some(1), ..Explorer::default() };
        assert!(e1.run(&Counter::new(2, false)).is_err());
    }

    /// Two threads blocked on each other: must be reported, not spun.
    #[derive(Clone)]
    struct Deadlock {
        stepped: bool,
    }

    impl Model for Deadlock {
        fn n_threads(&self) -> usize {
            2
        }
        fn done(&self, _t: usize) -> bool {
            false
        }
        fn enabled(&self, t: usize) -> bool {
            t == 0 && !self.stepped
        }
        fn step(&mut self, _t: usize) {
            self.stepped = true;
        }
    }

    #[test]
    fn deadlock_detected_with_trace() {
        let v = Explorer::default().run(&Deadlock { stepped: false }).unwrap_err();
        assert!(v.msg.contains("deadlock"), "{v}");
        assert_eq!(v.trace, vec![0]);
    }

    #[test]
    fn state_budget_is_a_backstop() {
        let e = Explorer { max_states: 3, ..Explorer::default() };
        let v = e.run(&Counter::new(3, false)).unwrap_err();
        assert!(v.msg.contains("state budget"), "{v}");
    }
}
