//! Property-testing helper (offline substitute for `proptest`, DESIGN.md §3).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! retries with progressively "smaller" generator budgets (shrink-lite)
//! and reports the seed so the case replays deterministically:
//!
//! ```text
//! use shears::util::prop::{check, Gen};
//! check("sort is idempotent", 200, |g| {
//!     let mut v = g.vec_i64(0..20, -100..100);
//!     v.sort(); let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```
//! (text block: doctest binaries don't inherit the xla rpath link flags)

use super::rng::Rng;
use std::ops::Range;

/// Random-input generator handed to properties. `size` scales collection
/// budgets during shrinking.
pub struct Gen {
    pub rng: Rng,
    pub size: f64,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        self.rng.range(r.start, r.end)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Length drawn from `len`, scaled down while shrinking.
    fn scaled_len(&mut self, len: Range<usize>) -> usize {
        let raw = self.usize_in(len.clone());
        let scaled = ((raw as f64) * self.size).round() as usize;
        scaled.max(len.start)
    }

    pub fn vec_i64(&mut self, len: Range<usize>, each: Range<i64>) -> Vec<i64> {
        let n = self.scaled_len(len);
        (0..n).map(|_| self.i64_in(each.clone())).collect()
    }

    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.scaled_len(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }
}

/// Run `prop` over `cases` seeded inputs; panics (with the failing seed)
/// if any case fails. Set `SHEARS_PROP_SEED` to replay one case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    if let Ok(seed) = std::env::var("SHEARS_PROP_SEED") {
        let seed: u64 = seed.parse().expect("SHEARS_PROP_SEED must be u64");
        let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let outcome = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
            prop(&mut g);
        });
        if outcome.is_err() {
            // shrink-lite: replay the same seed at smaller collection sizes
            // to find a smaller budget that still fails.
            let mut min_fail = 1.0;
            for step in 1..=4 {
                let size = 1.0 / f64::powi(2.0, step);
                let smaller = std::panic::catch_unwind(|| {
                    let mut g = Gen { rng: Rng::new(seed), size };
                    prop(&mut g);
                });
                if smaller.is_err() {
                    min_fail = size;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, min failing size {min_fail}); \
                 replay with SHEARS_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 100, |g| {
            let x = g.i64_in(-1000..1000);
            assert!(x.abs() >= 0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |g| {
            let v = g.vec_i64(1..50, 0..10);
            assert!(v.is_empty(), "non-empty");
        });
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let u = g.usize_in(3..9);
            assert!((3..9).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let v = g.vec_f32(0..5, 0.0, 1.0);
            assert!(v.len() < 5);
        });
    }
}
