//! Leveled stderr logger + wall-clock scope timers.
//!
//! Level comes from `SHEARS_LOG` (error|warn|info|debug, default info).
//! Timers back the §Perf measurements in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

// ORDERING(LEVEL): config — verbosity latch; a racing reader logging
// one line at the old level is harmless.
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = match std::env::var("SHEARS_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, msg: &str) {
    if (l as u8) <= level() {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[shears {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) };
}

/// RAII wall-clock timer; logs at debug on drop, exposes elapsed secs.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Self {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn stop(self) -> f64 {
        let secs = self.elapsed_secs();
        log(Level::Debug, &format!("{}: {:.3}s", self.label, secs));
        std::mem::forget(self);
        secs
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        log(
            Level::Debug,
            &format!("{}: {:.3}s", self.label, self.elapsed_secs()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.stop() >= 0.004);
    }
}
