//! Minimal JSON: recursive-descent parser + emitter.
//!
//! Exists because the offline registry has no serde (DESIGN.md §3). Scope
//! is exactly what the crate needs: parse `artifacts/manifest.json` and
//! checkpoint metadata, emit experiment-result JSON. Supports the full
//! JSON grammar except unicode escapes beyond BMP `\uXXXX` pairs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors (panic-free; used all over manifest loading) ---

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chain that returns Null for missing keys.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key).unwrap_or(&Json::Null)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ------------------------------------------------------------ emit

    // inherent by design: this is the compact-emit primitive, not Display
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for emitting results.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.b[start..];
                    let len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..len)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\n", "c": true, "d": null, "e": {}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.at("b").as_str(), Some("hi\n"));
        assert_eq!(v.at("c").as_bool(), Some(true));
        assert_eq!(*v.at("d"), Json::Null);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested_and_pretty_roundtrips() {
        let v = obj(vec![
            ("shape", arr(vec![num(2.0), num(3.0)])),
            ("name", s("lora_a.layers.0.attn.q")),
        ]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        assert_eq!(re.at("shape").as_shape(), Some(vec![2, 3]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let v = s("a\"b\\c\nd\u{1}");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo – ünïcode""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo – ünïcode"));
    }

    #[test]
    fn numbers_int_and_float() {
        let v = Json::parse("[0, -1, 3.25, 1e3, 2E-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(0.0));
        assert_eq!(a[1].as_f64(), Some(-1.0));
        assert_eq!(a[2].as_f64(), Some(3.25));
        assert_eq!(a[3].as_f64(), Some(1000.0));
        assert_eq!(a[4].as_f64(), Some(0.02));
    }
}
