//! Infrastructure substrates built in-repo (the offline crate registry
//! only carries the `xla` closure — see DESIGN.md §3).

pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
