//! Infrastructure substrates built in-repo (the offline crate registry
//! only carries the `xla` closure — see DESIGN.md §3).

pub mod durable;
pub mod json;
pub mod log;
pub mod modelcheck;
pub mod prop;
pub mod rng;

/// Sort a latency sample ascending for [`percentile`]. NaNs (which a
/// healthy metrics path never produces) sort as equal so the sort stays
/// total instead of panicking.
pub fn sort_for_percentiles(values: &mut [f64]) {
    values.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
}

/// Nearest-rank percentile over an **ascending-sorted** sample: the
/// smallest element with at least ⌈p·n⌉ values ≤ it. Unlike floor
/// indexing (`sorted[((n-1) as f64 * p) as usize]`), this reports the
/// true tail for small samples — at n=20, p=0.99 yields the maximum,
/// not element 18. Shared by `serve` and the eval router so the two
/// metric paths cannot drift. Empty input → 0.0.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        // ⌈0.99·20⌉ = 20 → the maximum (floor indexing reported 19.0)
        assert_eq!(percentile(&v, 0.99), 20.0);
        assert_eq!(percentile(&v, 0.5), 10.0);
        assert_eq!(percentile(&v, 1.0), 20.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // n=4, p50: ⌈2⌉ = rank 2
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.0);
    }

    #[test]
    fn sort_for_percentiles_orders_ascending() {
        let mut v = vec![3.0, 1.0, 2.0];
        sort_for_percentiles(&mut v);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert_eq!(percentile(&v, 0.99), 3.0);
    }
}
