//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, with
//! uniform/normal/choice helpers. Every stochastic component in the crate
//! (param init, NLS sampling, data generation, search) threads one of
//! these so experiments are bit-reproducible from a single seed.

/// xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for parallel components).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Export the full generator state (core words + the cached
    /// Box-Muller variate) for durable snapshots. Restoring via
    /// [`Rng::from_state`] makes the remaining stream bit-identical —
    /// the resume-determinism pins in `tests/pipeline_faults.rs` hang
    /// off exactly this round-trip.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from [`Rng::state`].
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free for our scales (n << 2^64)
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a buffer with N(mean, std) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], mean: f32, std: f32) {
        for x in buf.iter_mut() {
            *x = self.normal_f32(mean, std);
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(13);
            assert!(n < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_stream() {
        let mut r = Rng::new(9);
        // burn an odd number of normals so a Box-Muller spare is cached
        let _ = (r.normal(), r.next_u64(), r.normal(), r.normal());
        let (s, spare) = r.state();
        assert!(spare.is_some(), "fixture must exercise the cached variate");
        let mut resumed = Rng::from_state(s, spare);
        for _ in 0..64 {
            assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
