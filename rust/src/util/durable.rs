//! Atomic, checksummed file persistence — the durability substrate
//! shared by model checkpoints ([`crate::model::ParamStore::save`]),
//! search snapshots (`search::*_durable`), and training checkpoints
//! (`train::train_loop`).
//!
//! Layout: the caller's serialized payload, closed by a 20-byte
//! integrity footer `[payload_len u64 le][fnv1a64 u64 le][b"SHF1"]`.
//! Writes are **atomic**: payload + footer go to a temp file in the
//! same directory (cross-device renames are not atomic), the file is
//! fsynced, then renamed over the destination, then the directory is
//! fsynced best-effort. A crash mid-save leaves the previous file
//! intact — readers never observe a half-written state.
//!
//! Reads verify the footer and fail with a clean
//! `corrupt {what}: …` error on any mismatch (`what` is the caller's
//! noun — "checkpoint", "snapshot" — so error strings stay stable per
//! artifact kind). Files without a footer (written before it existed)
//! pass through as legacy payloads.

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Trailer magic closing the integrity footer.
pub const FOOTER_MAGIC: &[u8; 4] = b"SHF1";
/// `[payload_len u64][checksum u64][magic 4]`.
pub const FOOTER_LEN: usize = 8 + 8 + 4;

/// FNV-1a 64-bit: tiny, dependency-free, and plenty to catch torn or
/// bit-flipped files (this is corruption detection, not crypto).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write `payload` + integrity footer to `path` atomically (same-dir
/// temp file, fsync, rename, best-effort dir fsync).
pub fn write_atomic(path: impl AsRef<Path>, payload: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let checksum = fnv1a64(payload);
    let mut tmp_name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("durable"));
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let mut f =
        std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
    f.write_all(payload)?;
    f.write_all(&(payload.len() as u64).to_le_bytes())?;
    f.write_all(&checksum.to_le_bytes())?;
    f.write_all(FOOTER_MAGIC)?;
    f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    // best-effort directory fsync so the rename itself is durable;
    // some platforms refuse to open directories — not fatal
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// `Ok(Some(payload_len))` when `buf` ends in a verified integrity
/// footer, `Ok(None)` for legacy footer-less files, `Err` when a
/// footer is present but its claims don't hold. `what` names the
/// artifact in error strings ("checkpoint", "snapshot").
pub fn verify_footer(buf: &[u8], what: &str) -> Result<Option<usize>> {
    if buf.len() < FOOTER_LEN || &buf[buf.len() - 4..] != FOOTER_MAGIC {
        return Ok(None);
    }
    let fstart = buf.len() - FOOTER_LEN;
    let payload_len = u64::from_le_bytes(buf[fstart..fstart + 8].try_into().unwrap()) as usize;
    let stored = u64::from_le_bytes(buf[fstart + 8..fstart + 16].try_into().unwrap());
    if payload_len != fstart {
        bail!("corrupt {what}: footer claims {payload_len} payload bytes, file has {fstart}");
    }
    let actual = fnv1a64(&buf[..payload_len]);
    if actual != stored {
        bail!(
            "corrupt {what}: checksum mismatch (stored {stored:#018x}, computed {actual:#018x})"
        );
    }
    Ok(Some(payload_len))
}

/// Read `path` and strip a verified footer. Legacy footer-less files
/// return the whole buffer as payload.
pub fn read_verified(path: impl AsRef<Path>, what: &str) -> Result<Vec<u8>> {
    let path = path.as_ref();
    let mut buf = std::fs::read(path).with_context(|| format!("open {}", path.display()))?;
    if let Some(len) = verify_footer(&buf, what)? {
        buf.truncate(len);
    }
    Ok(buf)
}

/// Read `path` and strip a verified footer, treating a *missing*
/// footer as corruption too. For artifacts introduced after the footer
/// existed (search snapshots, training checkpoints) there is no legacy
/// fleet to tolerate — a torn tail that happens to shear the footer
/// off must not parse as "legacy".
pub fn read_verified_strict(path: impl AsRef<Path>, what: &str) -> Result<Vec<u8>> {
    let path = path.as_ref();
    let mut buf = std::fs::read(path).with_context(|| format!("open {}", path.display()))?;
    match verify_footer(&buf, what)? {
        Some(len) => {
            buf.truncate(len);
            Ok(buf)
        }
        None => bail!("corrupt {what}: missing integrity footer"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("shears_test_durable");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_no_temp_residue() {
        let p = tmp_path("rt.bin");
        write_atomic(&p, b"hello payload").unwrap();
        assert_eq!(read_verified(&p, "snapshot").unwrap(), b"hello payload");
        assert_eq!(read_verified_strict(&p, "snapshot").unwrap(), b"hello payload");
        assert!(!p.with_file_name("rt.bin.tmp").exists());
        // overwrite-in-place keeps working
        write_atomic(&p, b"second").unwrap();
        assert_eq!(read_verified_strict(&p, "snapshot").unwrap(), b"second");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn footer_claims_and_checksum_are_enforced() {
        let p = tmp_path("bad.bin");
        write_atomic(&p, b"payload bytes here").unwrap();
        let good = std::fs::read(&p).unwrap();

        // flip a payload byte -> checksum mismatch
        let mut flipped = good.clone();
        flipped[2] ^= 0xff;
        std::fs::write(&p, &flipped).unwrap();
        let e = read_verified(&p, "snapshot").unwrap_err().to_string();
        assert!(e.contains("corrupt snapshot") && e.contains("checksum mismatch"), "{e}");

        // drop a payload byte -> footer length claim fails
        let mut torn = good.clone();
        torn.remove(0);
        std::fs::write(&p, &torn).unwrap();
        let e = read_verified(&p, "snapshot").unwrap_err().to_string();
        assert!(e.contains("footer claims"), "{e}");

        // shear the footer off -> legacy for tolerant reads, corrupt
        // for strict ones
        let headless = &good[..good.len() - FOOTER_LEN];
        std::fs::write(&p, headless).unwrap();
        assert_eq!(read_verified(&p, "snapshot").unwrap(), headless);
        let e = read_verified_strict(&p, "snapshot").unwrap_err().to_string();
        assert!(e.contains("missing integrity footer"), "{e}");
        let _ = std::fs::remove_file(&p);
    }
}
