//! Deterministic fault injection for the serving stack, plus the
//! attributable-fault taxonomy every recovery path reports through.
//!
//! A [`FaultPlan`] schedules injected failures against the engine's
//! cumulative **step-attempt counter** (every call to
//! [`super::StepEngine::step`] with at least one active slot consumes
//! one attempt, whether or not it completes), so a given plan replays
//! the exact same failure at the exact same point in every run — the
//! recovery paths in `serve/server.rs` are pinned by tests, not by
//! hoping a real fault shows up. The counter lives on the plan itself
//! and the supervisor moves the plan from a dead engine to its
//! replacement, so injections keep their global indices across a
//! supervised restart (a `panic@N+1` plan exhausts the restart budget
//! deterministically).
//!
//! Plans come from the API ([`super::ServerOpts`]`::fault`,
//! [`super::StepEngine::set_fault_plan`]) or — when the API plan is
//! empty — from the `SHEARS_FAULT` environment variable, so operators
//! can run recovery drills against a live binary. Grammar:
//! comma-separated `kind@start[+period][:arg]`, attempts 0-based:
//!
//! ```text
//!   panic@3       panic inside step attempt 3 (exercises the supervisor)
//!   error@5       step attempt 5 fails; every slot recovers via re-prefill
//!   error@5:1     …and slot 1's recovery prefill fails too (quarantine)
//!   nan@4:2       poison slot 2's logits row with NaN on attempt 4
//!   delay@2:8     sleep 8 ms before attempt 2 (deadline-overrun tests)
//!   rankdelay@0+1:50  every attempt, sleep 50 µs × the sum of active
//!                     slots' adapter ranks — emulates compute that
//!                     scales with LoRA rank, so brownout degradation
//!                     (rank truncation) measurably buys back latency
//!   panic@6+10    periodic: fires on attempts 6, 16, 26, …
//! ```
//!
//! An **empty plan is a single branch** on the hot path
//! ([`FaultPlan::is_empty`]) — no counter bookkeeping, no scan — so
//! the fault layer rides in production builds without costing the
//! zero-alloc warm step anything (`rust/tests/alloc_count.rs`).

use anyhow::{bail, Context, Result};
use std::fmt;

/// Why a request ended without a normal completion — shared by
/// injected and organic failures so stream errors and
/// [`super::GenResponse`]`::fault` stay attributable either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// the engine step panicked (caught by the server's supervisor;
    /// every in-flight request fails and the engine is rebuilt)
    StepPanic,
    /// the batched decode step errored and this slot's own recovery
    /// re-prefill failed too
    StepError,
    /// the slot's logits row contained NaN/±inf — its KV column is no
    /// longer trusted
    NanLogits,
    /// past `GenRequest::deadline` with `ServerOpts::enforce_deadlines`
    DeadlineExceeded,
    /// past the hard per-request `GenRequest::max_wall` budget
    WallClockExceeded,
    /// cancelled by the caller (`StreamHandle::cancel`)
    Cancelled,
    /// the caller dropped its `StreamHandle` before the stream ended
    Abandoned,
    /// the server is going away (restart budget exhausted / drain)
    Shutdown,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::StepPanic => "step-panic",
            FaultKind::StepError => "step-error",
            FaultKind::NanLogits => "nan-logits",
            FaultKind::DeadlineExceeded => "deadline-exceeded",
            FaultKind::WallClockExceeded => "wall-clock-exceeded",
            FaultKind::Cancelled => "cancelled",
            FaultKind::Abandoned => "abandoned",
            FaultKind::Shutdown => "shutdown",
        }
    }

    /// Cancellations are the caller's (or the clock's) doing; faults
    /// are the engine's. The two feed different metrics counters.
    pub fn is_cancellation(self) -> bool {
        matches!(
            self,
            FaultKind::DeadlineExceeded
                | FaultKind::WallClockExceeded
                | FaultKind::Cancelled
                | FaultKind::Abandoned
        )
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One failed or cancelled request's attribution record: request id,
/// the KV slot it occupied (`None` = it never left the queue), what
/// kind of fault, and the underlying detail. Carried on
/// [`super::GenResponse`]`::fault` and formatted into stream errors so
/// a multi-tenant operator can tell whose request died, where, and why.
#[derive(Clone, Debug)]
pub struct ServeFault {
    pub request: u64,
    pub slot: Option<usize>,
    pub kind: FaultKind,
    pub detail: String,
}

impl fmt::Display for ServeFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.slot {
            Some(s) => {
                write!(f, "request {} (slot {s}) fault {}: {}", self.request, self.kind, self.detail)
            }
            None => {
                write!(f, "request {} (queued) fault {}: {}", self.request, self.kind, self.detail)
            }
        }
    }
}

/// What to inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectKind {
    /// panic inside the engine step — exercises `catch_unwind`
    /// supervision and the restart budget
    Panic,
    /// the batched step returns an error before touching the model;
    /// `slot` (if set) also fails its recovery re-prefill, so exactly
    /// that request retires with a [`FaultKind::StepError`] fault
    Error { slot: Option<usize> },
    /// overwrite `slot`'s logits row with NaN after the model step —
    /// exercises the non-finite quarantine
    NanLogits { slot: usize },
    /// sleep `ms` before the step — deadline/wall-clock overrun tests
    Delay { ms: u64 },
    /// sleep `us` microseconds **per active adapter rank** before the
    /// step (the engine multiplies by the sum of active slots'
    /// [`crate::ops::model::AdapterBinding::active_rank`]) — a
    /// deterministic stand-in for rank-proportional compute, the load
    /// model the brownout overload drills are pinned against
    RankDelay { us: u64 },
}

/// An [`InjectKind`] scheduled against the step-attempt counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Injection {
    /// first attempt (0-based) this fires on
    pub at: u64,
    /// re-fire every `period` attempts after `at`; `0` = fire once
    pub period: u64,
    pub kind: InjectKind,
}

impl Injection {
    fn fires(&self, attempt: u64) -> bool {
        if attempt < self.at {
            return false;
        }
        if self.period == 0 {
            attempt == self.at
        } else {
            (attempt - self.at) % self.period == 0
        }
    }
}

/// Everything firing on one step attempt — plain copyable data, built
/// without allocating, so consulting the plan keeps warm steps
/// alloc-free even with injections armed (just not firing).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fire {
    /// the attempt index this record describes (for error messages)
    pub attempt: u64,
    pub delay_ms: u64,
    /// microseconds to sleep per unit of active adapter rank in the
    /// batch (the engine supplies the rank sum)
    pub rank_delay_us: u64,
    pub panic: bool,
    pub error: bool,
    /// slot whose recovery prefill the injected error also poisons
    pub error_slot: Option<usize>,
    /// slot whose logits row gets poisoned with NaN
    pub nan_slot: Option<usize>,
}

impl Fire {
    pub fn is_clean(&self) -> bool {
        self.delay_ms == 0
            && self.rank_delay_us == 0
            && !self.panic
            && !self.error
            && self.nan_slot.is_none()
    }
}

/// A deterministic fault schedule (see the module docs for the
/// grammar and counter semantics).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    injections: Vec<Injection>,
    attempts: u64,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// An empty plan is the production state: the engine's only cost
    /// is this check.
    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// Step attempts consumed so far (survives engine rebuilds — the
    /// supervisor moves the plan, counter and all).
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    pub fn push(&mut self, inj: Injection) {
        self.injections.push(inj);
    }

    pub fn panic_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Panic });
        self
    }

    pub fn panic_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::Panic });
        self
    }

    pub fn error_at(mut self, at: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Error { slot: None } });
        self
    }

    pub fn error_at_slot(mut self, at: u64, slot: usize) -> FaultPlan {
        self.injections
            .push(Injection { at, period: 0, kind: InjectKind::Error { slot: Some(slot) } });
        self
    }

    pub fn error_every(mut self, at: u64, period: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::Error { slot: None } });
        self
    }

    pub fn nan_at(mut self, at: u64, slot: usize) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::NanLogits { slot } });
        self
    }

    pub fn delay_at(mut self, at: u64, ms: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::Delay { ms } });
        self
    }

    pub fn rank_delay_at(mut self, at: u64, us: u64) -> FaultPlan {
        self.injections.push(Injection { at, period: 0, kind: InjectKind::RankDelay { us } });
        self
    }

    pub fn rank_delay_every(mut self, at: u64, period: u64, us: u64) -> FaultPlan {
        self.injections.push(Injection { at, period, kind: InjectKind::RankDelay { us } });
        self
    }

    /// Consume one step attempt and collect what fires on it. Called
    /// by the engine once per step with a non-empty plan; never
    /// allocates.
    pub fn fire(&mut self) -> Fire {
        let attempt = self.attempts;
        self.attempts += 1;
        let mut f = Fire { attempt, ..Fire::default() };
        for inj in &self.injections {
            if !inj.fires(attempt) {
                continue;
            }
            match inj.kind {
                InjectKind::Panic => f.panic = true,
                InjectKind::Error { slot } => {
                    f.error = true;
                    if slot.is_some() {
                        f.error_slot = slot;
                    }
                }
                InjectKind::NanLogits { slot } => {
                    // first match wins — one quarantine target per step
                    if f.nan_slot.is_none() {
                        f.nan_slot = Some(slot);
                    }
                }
                InjectKind::Delay { ms } => f.delay_ms += ms,
                InjectKind::RankDelay { us } => f.rank_delay_us += us,
            }
        }
        f
    }

    /// Parse the `SHEARS_FAULT` grammar (module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, sched) = part
                .split_once('@')
                .with_context(|| format!("fault '{part}': expected kind@start[+period][:arg]"))?;
            let (sched, arg) = match sched.split_once(':') {
                Some((s, a)) => (s, Some(a)),
                None => (sched, None),
            };
            let (at, period) = match sched.split_once('+') {
                Some((a, p)) => (
                    a.parse::<u64>().with_context(|| format!("fault '{part}': bad start '{a}'"))?,
                    p.parse::<u64>()
                        .with_context(|| format!("fault '{part}': bad period '{p}'"))?,
                ),
                None => (
                    sched
                        .parse::<u64>()
                        .with_context(|| format!("fault '{part}': bad start '{sched}'"))?,
                    0,
                ),
            };
            let parse_arg = |what: &str| -> Result<u64> {
                arg.with_context(|| format!("fault '{part}': '{kind}' needs :{what}"))?
                    .parse::<u64>()
                    .with_context(|| format!("fault '{part}': bad {what}"))
            };
            let kind = match kind {
                "panic" => {
                    ensure_no_arg(part, arg)?;
                    InjectKind::Panic
                }
                "error" => InjectKind::Error {
                    slot: match arg {
                        Some(_) => Some(parse_arg("slot")? as usize),
                        None => None,
                    },
                },
                "nan" => InjectKind::NanLogits { slot: parse_arg("slot")? as usize },
                "delay" => InjectKind::Delay { ms: parse_arg("ms")? },
                "rankdelay" => InjectKind::RankDelay { us: parse_arg("us")? },
                other => {
                    bail!("fault '{part}': unknown kind '{other}' (panic|error|nan|delay|rankdelay)")
                }
            };
            plan.injections.push(Injection { at, period, kind });
        }
        Ok(plan)
    }

    /// The `SHEARS_FAULT` plan, `None` when unset or blank. A parse
    /// error is a real error — a typoed drill must fail loudly, not
    /// silently run fault-free.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("SHEARS_FAULT") {
            Ok(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(&s)?)),
            _ => Ok(None),
        }
    }
}

fn ensure_no_arg(part: &str, arg: Option<&str>) -> Result<()> {
    if arg.is_some() {
        bail!("fault '{part}': 'panic' takes no :arg");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_kind_and_schedule() {
        let p = FaultPlan::parse("panic@3, error@5:1 ,nan@4:2,delay@2:8,error@7+100,rankdelay@0+1:50")
            .unwrap();
        assert_eq!(p.injections.len(), 6);
        assert_eq!(p.injections[0], Injection { at: 3, period: 0, kind: InjectKind::Panic });
        assert_eq!(
            p.injections[1],
            Injection { at: 5, period: 0, kind: InjectKind::Error { slot: Some(1) } }
        );
        assert_eq!(
            p.injections[2],
            Injection { at: 4, period: 0, kind: InjectKind::NanLogits { slot: 2 } }
        );
        assert_eq!(p.injections[3], Injection { at: 2, period: 0, kind: InjectKind::Delay { ms: 8 } });
        assert_eq!(
            p.injections[4],
            Injection { at: 7, period: 100, kind: InjectKind::Error { slot: None } }
        );
        assert_eq!(
            p.injections[5],
            Injection { at: 0, period: 1, kind: InjectKind::RankDelay { us: 50 } }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("panic").is_err(), "missing @start");
        assert!(FaultPlan::parse("panic@x").is_err(), "bad start");
        assert!(FaultPlan::parse("nan@3").is_err(), "nan needs a slot");
        assert!(FaultPlan::parse("delay@3").is_err(), "delay needs ms");
        assert!(FaultPlan::parse("rankdelay@3").is_err(), "rankdelay needs us");
        assert!(FaultPlan::parse("panic@3:1").is_err(), "panic takes no arg");
        assert!(FaultPlan::parse("explode@1").is_err(), "unknown kind");
        assert!(FaultPlan::parse("error@1+z").is_err(), "bad period");
        let p = FaultPlan::parse(" ").unwrap();
        assert!(p.is_empty(), "blank spec is the empty plan");
    }

    #[test]
    fn one_shot_fires_exactly_once_periodic_repeats() {
        let one = Injection { at: 3, period: 0, kind: InjectKind::Panic };
        assert!(!one.fires(2));
        assert!(one.fires(3));
        assert!(!one.fires(4));
        let rep = Injection { at: 6, period: 10, kind: InjectKind::Panic };
        assert!(!rep.fires(5));
        assert!(rep.fires(6));
        assert!(!rep.fires(7));
        assert!(rep.fires(16));
        assert!(rep.fires(26));
    }

    #[test]
    fn fire_advances_the_attempt_counter_and_aggregates() {
        let mut p =
            FaultPlan::none().delay_at(1, 4).nan_at(1, 2).error_at_slot(1, 0).rank_delay_at(1, 9);
        let f0 = p.fire();
        assert_eq!(f0.attempt, 0);
        assert!(f0.is_clean());
        let f1 = p.fire();
        assert_eq!(f1.attempt, 1);
        assert!(!f1.is_clean());
        assert_eq!(f1.delay_ms, 4);
        assert_eq!(f1.rank_delay_us, 9);
        assert_eq!(f1.nan_slot, Some(2));
        assert!(f1.error);
        assert_eq!(f1.error_slot, Some(0));
        assert!(!f1.panic);
        assert!(p.fire().is_clean());
        assert_eq!(p.attempts(), 3);
    }

    #[test]
    fn fault_display_is_attributable() {
        let f = ServeFault {
            request: 7,
            slot: Some(2),
            kind: FaultKind::NanLogits,
            detail: "non-finite logits row".into(),
        };
        let s = f.to_string();
        assert!(s.contains("request 7"), "{s}");
        assert!(s.contains("slot 2"), "{s}");
        assert!(s.contains("nan-logits"), "{s}");
        let q = ServeFault {
            request: 9,
            slot: None,
            kind: FaultKind::Shutdown,
            detail: "restart budget exhausted".into(),
        };
        assert!(q.to_string().contains("(queued)"));
    }

    #[test]
    fn cancellation_kinds_partition_the_taxonomy() {
        for k in [
            FaultKind::DeadlineExceeded,
            FaultKind::WallClockExceeded,
            FaultKind::Cancelled,
            FaultKind::Abandoned,
        ] {
            assert!(k.is_cancellation(), "{k}");
        }
        for k in [FaultKind::StepPanic, FaultKind::StepError, FaultKind::NanLogits, FaultKind::Shutdown]
        {
            assert!(!k.is_cancellation(), "{k}");
        }
    }
}
