//! Overload brownout controller for the serving runtime.
//!
//! Tracks three load signals — EWMA decode-step latency, submit-queue
//! depth, and the deadline-miss rate over a ring of recent completions
//! — and drives a hysteresis state machine:
//!
//! ```text
//!   Normal  --hot(degrade)×dwell_up-->  Degraded  --hot(shed)×dwell_up-->  Shedding
//!   Normal  <-cool(degrade)×dwell_down- Degraded  <-cool(shed)×dwell_down- Shedding
//! ```
//!
//! In `Degraded`, newly admitted requests that opt in are bound to a
//! cheaper prefix sub-adapter (`AdapterBinding::prefix`) instead of
//! missing deadlines. In `Shedding`, submissions past the admissible
//! horizon are rejected with `RejectReason::Overloaded` — never
//! silently dropped. A state moves at most one rung per evaluation,
//! and only after `dwell_up`/`dwell_down` consecutive agreeing
//! evaluations, so the controller cannot flap on a noisy signal.
//!
//! The controller is pure bookkeeping: no clocks of its own (the
//! server passes `Instant`s in), no allocation after construction (the
//! miss ring is preallocated), and with `enabled: false` every hook is
//! an observed no-op — the server's output is bit-identical to a build
//! without the controller. Determinism in tests comes from driving the
//! signals with `FaultPlan` latency injection.

use std::time::{Duration, Instant};

/// Brownout rung. Encoded in metrics as a gauge via [`BrownoutState::gauge`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BrownoutState {
    /// Below thresholds: admission untouched, controller observe-only.
    Normal,
    /// Opted-in admissions are bound to a prefix sub-adapter.
    Degraded,
    /// Degraded, plus submissions past the admissible horizon are
    /// rejected `Overloaded`.
    Shedding,
}

impl BrownoutState {
    /// Metrics encoding: 0 = Normal, 1 = Degraded, 2 = Shedding.
    pub fn gauge(self) -> u64 {
        match self {
            BrownoutState::Normal => 0,
            BrownoutState::Degraded => 1,
            BrownoutState::Shedding => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BrownoutState::Normal => "normal",
            BrownoutState::Degraded => "degraded",
            BrownoutState::Shedding => "shedding",
        }
    }
}

/// Trip/clear thresholds for one rung of the ladder. The rung trips
/// ("hot") when ANY signal reaches its `_hi`, and clears ("cool") only
/// when ALL signals are at or below their `_lo` — the gap between the
/// two is the hysteresis dead zone where the rung holds.
#[derive(Clone, Copy, Debug)]
pub struct BrownoutThresholds {
    /// EWMA decode-step latency, milliseconds.
    pub step_ms_hi: f64,
    pub step_ms_lo: f64,
    /// Submit-queue depth (queued, not yet admitted).
    pub queue_hi: usize,
    pub queue_lo: usize,
    /// Deadline-miss fraction over the recent-completions ring, 0..=1.
    pub miss_hi: f64,
    pub miss_lo: f64,
}

impl BrownoutThresholds {
    /// Thresholds no real load can reach: the rung never trips, and
    /// (vacuously) always reads cool. The armed-but-unreachable
    /// configuration used by the bit-identity drills.
    pub const UNREACHABLE: BrownoutThresholds = BrownoutThresholds {
        step_ms_hi: f64::INFINITY,
        step_ms_lo: f64::INFINITY,
        queue_hi: usize::MAX,
        queue_lo: usize::MAX,
        miss_hi: f64::INFINITY,
        miss_lo: f64::INFINITY,
    };
}

/// Controller configuration, carried in `ServerOpts::brownout`.
#[derive(Clone, Debug)]
pub struct BrownoutOpts {
    /// Master switch. Off (the default) means the server never
    /// constructs load signals and admission is byte-for-byte the
    /// pre-brownout path.
    pub enabled: bool,
    /// Rank fraction served to degraded admissions (per site:
    /// `ceil(fraction × active_rank)` prefix rows, min 1).
    pub fraction: f32,
    /// Policy for requests that leave `GenRequest::allow_degraded`
    /// unset.
    pub default_allow_degraded: bool,
    /// EWMA smoothing factor for step latency and steps-per-request,
    /// in (0, 1]; 1.0 tracks only the most recent observation.
    pub alpha: f64,
    /// Normal ⇄ Degraded thresholds.
    pub degrade: BrownoutThresholds,
    /// Degraded ⇄ Shedding thresholds.
    pub shed: BrownoutThresholds,
    /// Consecutive hot evaluations before escalating one rung.
    pub dwell_up: u32,
    /// Consecutive cool evaluations before de-escalating one rung.
    pub dwell_down: u32,
    /// While shedding: the backlog the server is still willing to
    /// accept, expressed as milliseconds of estimated work
    /// (`admissible depth = horizon / (step_ms × steps_per_request)`).
    /// 0 rejects every submission while shedding.
    pub shed_horizon_ms: f64,
    /// Length of the deadline-miss ring (recent clean completions).
    pub miss_window: usize,
}

impl Default for BrownoutOpts {
    fn default() -> Self {
        BrownoutOpts {
            enabled: false,
            fraction: 0.5,
            default_allow_degraded: false,
            alpha: 0.2,
            degrade: BrownoutThresholds::UNREACHABLE,
            shed: BrownoutThresholds::UNREACHABLE,
            dwell_up: 3,
            dwell_down: 5,
            shed_horizon_ms: 1_000.0,
            miss_window: 64,
        }
    }
}

/// The hysteresis state machine plus its load signals. Lives in the
/// server loop's `LoopState`, so it survives supervised engine
/// restarts — an overload does not reset because the engine was
/// rebuilt.
#[derive(Debug)]
pub struct BrownoutController {
    opts: BrownoutOpts,
    state: BrownoutState,
    /// EWMA decode-step latency, ms (`None` until the first step).
    step_ms: Option<f64>,
    /// EWMA decode steps per completed request — the per-request cost
    /// model behind the admissible horizon.
    steps_per_req: Option<f64>,
    /// Ring of recent clean completions: `true` = missed its advisory
    /// deadline. Preallocated; `miss_len` counts the valid entries.
    miss_ring: Vec<bool>,
    miss_next: usize,
    miss_len: usize,
    hot_streak: u32,
    cool_streak: u32,
    transitions: u64,
    /// Time-in-state accounting, accrued at each evaluation.
    last_eval: Option<Instant>,
    degraded_secs: f64,
    shedding_secs: f64,
}

impl BrownoutController {
    pub fn new(opts: BrownoutOpts) -> Self {
        let window = opts.miss_window.max(1);
        BrownoutController {
            opts,
            state: BrownoutState::Normal,
            step_ms: None,
            steps_per_req: None,
            miss_ring: vec![false; window],
            miss_next: 0,
            miss_len: 0,
            hot_streak: 0,
            cool_streak: 0,
            transitions: 0,
            last_eval: None,
            degraded_secs: 0.0,
            shedding_secs: 0.0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.opts.enabled
    }

    pub fn state(&self) -> BrownoutState {
        self.state
    }

    /// Whether admissions should bind prefix sub-adapters right now
    /// (both brownout rungs degrade; `Shedding` additionally rejects).
    pub fn degrading(&self) -> bool {
        self.opts.enabled && self.state != BrownoutState::Normal
    }

    pub fn fraction(&self) -> f32 {
        self.opts.fraction
    }

    pub fn default_allow_degraded(&self) -> bool {
        self.opts.default_allow_degraded
    }

    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    pub fn degraded_secs(&self) -> f64 {
        self.degraded_secs
    }

    pub fn shedding_secs(&self) -> f64 {
        self.shedding_secs
    }

    /// Current EWMA step latency in ms (0 before any step).
    pub fn ewma_step_ms(&self) -> f64 {
        self.step_ms.unwrap_or(0.0)
    }

    /// Deadline-miss fraction over the ring (0 while empty).
    pub fn miss_rate(&self) -> f64 {
        if self.miss_len == 0 {
            return 0.0;
        }
        let missed = self.miss_ring[..self.miss_len].iter().filter(|&&m| m).count();
        missed as f64 / self.miss_len as f64
    }

    fn ewma(prev: Option<f64>, x: f64, alpha: f64) -> f64 {
        match prev {
            None => x,
            Some(p) => p + alpha * (x - p),
        }
    }

    /// Feed one successful engine step's wall time. No-op when
    /// disabled; never allocates.
    pub fn observe_step(&mut self, dur: Duration) {
        if !self.opts.enabled {
            return;
        }
        let ms = dur.as_secs_f64() * 1e3;
        self.step_ms = Some(Self::ewma(self.step_ms, ms, self.opts.alpha));
    }

    /// Feed one clean completion: how many tokens it decoded and
    /// whether it missed its advisory deadline. No-op when disabled;
    /// never allocates (the ring is preallocated).
    pub fn observe_completion(&mut self, new_tokens: usize, deadline_missed: bool) {
        if !self.opts.enabled {
            return;
        }
        self.miss_ring[self.miss_next] = deadline_missed;
        self.miss_next = (self.miss_next + 1) % self.miss_ring.len();
        self.miss_len = (self.miss_len + 1).min(self.miss_ring.len());
        // one decode step per generated token while resident
        self.steps_per_req =
            Some(Self::ewma(self.steps_per_req, new_tokens.max(1) as f64, self.opts.alpha));
    }

    fn hot(&self, th: &BrownoutThresholds, queue_depth: usize) -> bool {
        self.ewma_step_ms() >= th.step_ms_hi
            || queue_depth >= th.queue_hi
            || self.miss_rate() >= th.miss_hi
    }

    fn cool(&self, th: &BrownoutThresholds, queue_depth: usize) -> bool {
        self.ewma_step_ms() <= th.step_ms_lo
            && queue_depth <= th.queue_lo
            && self.miss_rate() <= th.miss_lo
    }

    fn transition(&mut self, next: BrownoutState) {
        self.state = next;
        self.transitions += 1;
        self.hot_streak = 0;
        self.cool_streak = 0;
    }

    /// One control-loop evaluation: accrue time-in-state, update the
    /// dwell streaks against the current rung's thresholds, and move
    /// at most one rung. Returns the (possibly new) state. No-op in
    /// `Normal` unless a signal trips — which is what keeps a run with
    /// the controller armed below thresholds bit-identical to one with
    /// it off.
    pub fn evaluate(&mut self, now: Instant, queue_depth: usize) -> BrownoutState {
        if !self.opts.enabled {
            return self.state;
        }
        if let Some(prev) = self.last_eval {
            let dt = now.saturating_duration_since(prev).as_secs_f64();
            match self.state {
                BrownoutState::Normal => {}
                BrownoutState::Degraded => self.degraded_secs += dt,
                BrownoutState::Shedding => self.shedding_secs += dt,
            }
        }
        self.last_eval = Some(now);

        // this rung's escalate/clear signals
        let (hot, cool) = match self.state {
            BrownoutState::Normal => (self.hot(&self.opts.degrade, queue_depth), false),
            BrownoutState::Degraded => (
                self.hot(&self.opts.shed, queue_depth),
                self.cool(&self.opts.degrade, queue_depth),
            ),
            BrownoutState::Shedding => (false, self.cool(&self.opts.shed, queue_depth)),
        };
        if hot {
            self.hot_streak += 1;
            self.cool_streak = 0;
        } else if cool {
            self.cool_streak += 1;
            self.hot_streak = 0;
        } else {
            // dead zone: hold the rung, reset both streaks
            self.hot_streak = 0;
            self.cool_streak = 0;
        }

        if self.hot_streak >= self.opts.dwell_up.max(1) {
            match self.state {
                BrownoutState::Normal => self.transition(BrownoutState::Degraded),
                BrownoutState::Degraded => self.transition(BrownoutState::Shedding),
                BrownoutState::Shedding => {}
            }
        } else if self.cool_streak >= self.opts.dwell_down.max(1) {
            match self.state {
                BrownoutState::Normal => {}
                BrownoutState::Degraded => self.transition(BrownoutState::Normal),
                BrownoutState::Shedding => self.transition(BrownoutState::Degraded),
            }
        }
        self.state
    }

    /// While `Shedding`: how deep the submit queue may grow before new
    /// submissions bounce `Overloaded` — the shed horizon divided by
    /// the estimated per-request cost. `usize::MAX` in every other
    /// state (no shedding).
    pub fn admissible_depth(&self, queue_cap: usize) -> usize {
        if self.state != BrownoutState::Shedding {
            return usize::MAX;
        }
        let per_req_ms = self.ewma_step_ms() * self.steps_per_req.unwrap_or(1.0);
        if per_req_ms <= f64::EPSILON {
            // no cost model yet: shed everything past the horizon flag
            return if self.opts.shed_horizon_ms > 0.0 { queue_cap } else { 0 };
        }
        ((self.opts.shed_horizon_ms / per_req_ms).floor() as usize).min(queue_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reachable() -> BrownoutOpts {
        BrownoutOpts {
            enabled: true,
            alpha: 1.0,
            degrade: BrownoutThresholds {
                step_ms_hi: 10.0,
                step_ms_lo: 2.0,
                queue_hi: usize::MAX,
                queue_lo: usize::MAX,
                miss_hi: f64::INFINITY,
                miss_lo: f64::INFINITY,
            },
            shed: BrownoutThresholds {
                step_ms_hi: 50.0,
                step_ms_lo: 8.0,
                queue_hi: usize::MAX,
                queue_lo: usize::MAX,
                miss_hi: f64::INFINITY,
                miss_lo: f64::INFINITY,
            },
            dwell_up: 2,
            dwell_down: 2,
            ..BrownoutOpts::default()
        }
    }

    fn eval_n(c: &mut BrownoutController, t0: Instant, from: u32, n: u32, ms: f64) -> BrownoutState {
        let mut st = c.state();
        for i in from..from + n {
            c.observe_step(Duration::from_secs_f64(ms * 1e-3));
            st = c.evaluate(t0 + Duration::from_millis(u64::from(i)), 0);
        }
        st
    }

    #[test]
    fn escalates_only_after_dwell_up_consecutive_hot_evals() {
        let mut c = BrownoutController::new(reachable());
        let t0 = Instant::now();
        assert_eq!(eval_n(&mut c, t0, 0, 1, 20.0), BrownoutState::Normal, "one hot eval holds");
        assert_eq!(eval_n(&mut c, t0, 1, 1, 20.0), BrownoutState::Degraded, "dwell_up = 2 trips");
        assert_eq!(c.transitions(), 1);
        // two shed-hot evals escalate the next rung
        assert_eq!(eval_n(&mut c, t0, 2, 2, 60.0), BrownoutState::Shedding);
        assert_eq!(c.transitions(), 2);
    }

    #[test]
    fn alternating_hot_and_dead_zone_never_escalates() {
        let mut c = BrownoutController::new(reachable());
        let t0 = Instant::now();
        for i in 0..10u32 {
            // hot (20ms) alternating with dead-zone (5ms: above lo=2, below hi=10)
            let ms = if i % 2 == 0 { 20.0 } else { 5.0 };
            assert_eq!(eval_n(&mut c, t0, i, 1, ms), BrownoutState::Normal, "flap guard at {i}");
        }
        assert_eq!(c.transitions(), 0);
    }

    #[test]
    fn recovers_one_rung_at_a_time_with_dwell_down() {
        let mut c = BrownoutController::new(reachable());
        let t0 = Instant::now();
        eval_n(&mut c, t0, 0, 2, 20.0); // -> Degraded
        eval_n(&mut c, t0, 2, 2, 60.0); // -> Shedding
        assert_eq!(c.state(), BrownoutState::Shedding);
        // fast steps: cool for both rungs, but only one rung per dwell
        assert_eq!(eval_n(&mut c, t0, 4, 1, 1.0), BrownoutState::Shedding);
        assert_eq!(eval_n(&mut c, t0, 5, 1, 1.0), BrownoutState::Degraded);
        assert_eq!(eval_n(&mut c, t0, 6, 2, 1.0), BrownoutState::Normal);
        assert_eq!(c.transitions(), 4);
    }

    #[test]
    fn unreachable_thresholds_stay_normal_under_any_load() {
        let mut c = BrownoutController::new(BrownoutOpts { enabled: true, ..Default::default() });
        let t0 = Instant::now();
        for i in 0..50u32 {
            c.observe_step(Duration::from_millis(500));
            c.observe_completion(4, true);
            assert_eq!(
                c.evaluate(t0 + Duration::from_millis(u64::from(i)), 1_000_000),
                BrownoutState::Normal
            );
        }
        assert_eq!(c.transitions(), 0);
    }

    #[test]
    fn disabled_controller_is_inert() {
        let mut c = BrownoutController::new(BrownoutOpts {
            enabled: false,
            dwell_up: 1,
            degrade: BrownoutThresholds {
                step_ms_hi: 0.0,
                step_ms_lo: 0.0,
                queue_hi: 0,
                queue_lo: 0,
                miss_hi: 0.0,
                miss_lo: 0.0,
            },
            ..Default::default()
        });
        let t0 = Instant::now();
        c.observe_step(Duration::from_secs(1));
        c.observe_completion(8, true);
        assert_eq!(c.evaluate(t0, 100), BrownoutState::Normal);
        assert!(!c.degrading());
        assert_eq!(c.transitions(), 0);
        assert_eq!(c.ewma_step_ms(), 0.0, "disabled controller records nothing");
    }

    #[test]
    fn queue_depth_alone_can_trip_and_drive_shedding() {
        let mut c = BrownoutController::new(BrownoutOpts {
            enabled: true,
            dwell_up: 1,
            dwell_down: 1_000_000,
            degrade: BrownoutThresholds {
                queue_hi: 2,
                queue_lo: 0,
                ..BrownoutThresholds::UNREACHABLE
            },
            shed: BrownoutThresholds {
                queue_hi: 2,
                queue_lo: 0,
                ..BrownoutThresholds::UNREACHABLE
            },
            shed_horizon_ms: 0.0,
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(c.evaluate(t0, 2), BrownoutState::Degraded, "one rung per evaluation");
        assert_eq!(c.evaluate(t0 + Duration::from_millis(1), 2), BrownoutState::Shedding);
        assert_eq!(c.admissible_depth(64), 0, "zero horizon sheds everything");
        // huge dwell_down: empty queue does not de-escalate within the test
        assert_eq!(c.evaluate(t0 + Duration::from_millis(2), 0), BrownoutState::Shedding);
        assert_eq!(c.admissible_depth(64), 0);
    }

    #[test]
    fn admissible_depth_is_horizon_over_estimated_request_cost() {
        let mut c = BrownoutController::new(BrownoutOpts {
            enabled: true,
            alpha: 1.0,
            dwell_up: 1,
            degrade: BrownoutThresholds { queue_hi: 1, ..BrownoutThresholds::UNREACHABLE },
            shed: BrownoutThresholds { queue_hi: 1, ..BrownoutThresholds::UNREACHABLE },
            shed_horizon_ms: 100.0,
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(c.admissible_depth(64), usize::MAX, "not shedding yet");
        c.observe_step(Duration::from_millis(5));
        c.observe_completion(4, false); // 5ms × 4 steps = 20ms per request
        c.evaluate(t0, 1);
        c.evaluate(t0 + Duration::from_millis(1), 1);
        assert_eq!(c.state(), BrownoutState::Shedding);
        assert_eq!(c.admissible_depth(64), 5, "100ms horizon / 20ms per request");
        assert_eq!(c.admissible_depth(3), 3, "clamped to the queue cap");
    }

    #[test]
    fn time_in_state_accrues_per_rung() {
        let mut c = BrownoutController::new(BrownoutOpts {
            enabled: true,
            alpha: 1.0,
            dwell_up: 1,
            dwell_down: 1,
            degrade: BrownoutThresholds {
                step_ms_hi: 10.0,
                step_ms_lo: 2.0,
                ..BrownoutThresholds::UNREACHABLE
            },
            ..Default::default()
        });
        let t0 = Instant::now();
        c.observe_step(Duration::from_millis(20));
        c.evaluate(t0, 0); // -> Degraded at t0
        c.evaluate(t0 + Duration::from_millis(250), 0); // 250ms degraded (dead zone holds)
        assert!(c.degraded_secs() >= 0.25 - 1e-9, "degraded_secs = {}", c.degraded_secs());
        assert_eq!(c.state(), BrownoutState::Degraded, "20ms EWMA sits in the dead zone");
        assert_eq!(c.shedding_secs(), 0.0);
    }

    #[test]
    fn miss_ring_wraps_and_rates_recent_completions() {
        let mut c = BrownoutController::new(BrownoutOpts {
            enabled: true,
            miss_window: 4,
            ..Default::default()
        });
        assert_eq!(c.miss_rate(), 0.0);
        for _ in 0..4 {
            c.observe_completion(3, true);
        }
        assert_eq!(c.miss_rate(), 1.0);
        for _ in 0..3 {
            c.observe_completion(3, false);
        }
        assert_eq!(c.miss_rate(), 0.25, "ring of 4 holds one stale miss");
    }
}
