//! Multi-tenant adapter registry: `AdapterId -> AdapterBinding`.
//!
//! The production shape of Shears serving is one shared frozen sparse
//! base and many KB-scale tenant sub-adapters (NLS makes every
//! sub-adapter a rank-mask prefix over the same super-network LoRA
//! weights). The registry keeps resident bindings under a configurable
//! byte budget with LRU eviction, and pins an optional default applied
//! to requests that name no tenant.
//!
//! In-flight tracking is structural: the registry holds one `Arc` per
//! binding, and every queued request or occupied decode slot holds a
//! clone, so `Arc::strong_count == 1` means idle. Evicting (or
//! deregistering) a binding that is still referenced is an **error**,
//! never a stall — the caller decides whether to retry, grow the
//! budget, or shed load.

use crate::model::{ModelConfig, ParamStore};
use crate::ops::model::{AdapterBinding, NamedTensors};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Weak};

/// Tenant adapter identifier (request-visible).
pub type AdapterId = String;

struct Entry {
    binding: Arc<AdapterBinding>,
    bytes: usize,
    /// logical LRU clock value at last touch (register/resolve)
    last_used: u64,
}

/// Resident tenant bindings under a byte budget, plus the pinned
/// server default. Deterministic: LRU order is a logical clock bumped
/// on register/resolve, no wall time.
pub struct AdapterRegistry {
    entries: HashMap<AdapterId, Entry>,
    default_: Option<Arc<AdapterBinding>>,
    /// resident-bytes ceiling; `0` = unlimited
    budget: usize,
    clock: u64,
    /// Brownout prefix sub-bindings, keyed by the parent binding's
    /// address plus the kept fraction in permille. Values pair a
    /// `Weak` on the parent (validated by `Arc::ptr_eq` on hit, so a
    /// reused allocation can never serve another binding's prefix)
    /// with the derived sub-binding. Hits are a map lookup plus an
    /// `Arc` clone — no allocation, which keeps degraded warm
    /// admission inside the zero-alloc envelope.
    prefixes: HashMap<(usize, u32), (Weak<AdapterBinding>, Arc<AdapterBinding>)>,
}

impl AdapterRegistry {
    /// An empty registry. `budget_bytes == 0` means unlimited.
    pub fn new(budget_bytes: usize) -> AdapterRegistry {
        AdapterRegistry {
            entries: HashMap::new(),
            default_: None,
            budget: budget_bytes,
            clock: 0,
            prefixes: HashMap::new(),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Total bytes of registered resident bindings (the pinned default
    /// is counted only while it is also a registered entry).
    pub fn resident_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes).sum()
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no adapters are registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered ids, sorted (deterministic listing).
    pub fn ids(&self) -> Vec<AdapterId> {
        let mut v: Vec<AdapterId> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Whether `id` is registered.
    pub fn contains(&self, id: &str) -> bool {
        self.entries.contains_key(id)
    }

    /// The configured budget in bytes (`0` = unlimited).
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Plan the LRU evictions needed to keep `extra` more bytes
    /// resident, ignoring any existing entry named `replace` (it is
    /// about to be swapped out). Only idle entries — registry holds
    /// the sole `Arc` — are evictable; if the budget still cannot be
    /// met, this errors without touching the registry.
    fn plan_evictions(&self, extra: usize, replace: Option<&str>) -> Result<Vec<AdapterId>> {
        if self.budget == 0 {
            return Ok(Vec::new());
        }
        let counted = |id: &str| replace != Some(id);
        let mut resident: usize = self
            .entries
            .iter()
            .filter(|(id, _)| counted(id))
            .map(|(_, e)| e.bytes)
            .sum();
        let mut victims = Vec::new();
        if resident + extra <= self.budget {
            return Ok(victims);
        }
        let mut idle: Vec<(&AdapterId, &Entry)> = self
            .entries
            .iter()
            .filter(|(id, e)| counted(id) && Arc::strong_count(&e.binding) == 1)
            .collect();
        idle.sort_by_key(|(_, e)| e.last_used);
        for (id, e) in idle {
            if resident + extra <= self.budget {
                break;
            }
            resident -= e.bytes;
            victims.push(id.clone());
        }
        ensure!(
            resident + extra <= self.budget,
            "fitting {extra} more bytes under the {}-byte adapter budget would require \
             evicting in-flight adapters ({resident} bytes pinned by active slots or requests)",
            self.budget
        );
        Ok(victims)
    }

    /// Register (or hot-swap) `id`. Evicts least-recently-used *idle*
    /// adapters as needed to fit the byte budget; fails — mutating
    /// nothing — if the binding alone exceeds the budget or fitting it
    /// would evict an adapter with in-flight slots. Hot-swapping an
    /// in-flight id is allowed: slots already decoding keep their old
    /// binding (their `Arc` clones) until they retire, while new
    /// admissions resolve the replacement.
    pub fn register(&mut self, id: &str, binding: AdapterBinding) -> Result<()> {
        let bytes = binding.bytes();
        if self.budget > 0 {
            ensure!(
                bytes <= self.budget,
                "adapter '{id}' needs {bytes} bytes, over the {}-byte registry budget",
                self.budget
            );
        }
        let victims = self
            .plan_evictions(bytes, Some(id))
            .with_context(|| format!("registering adapter '{id}'"))?;
        for v in &victims {
            self.entries.remove(v);
        }
        let last_used = self.tick();
        self.entries.insert(
            id.to_string(),
            Entry { binding: Arc::new(binding), bytes, last_used },
        );
        self.prune_prefixes();
        Ok(())
    }

    /// Remove `id`. Errors if unknown, or if the binding is still
    /// referenced (active slots, queued requests, or the pinned
    /// default).
    pub fn deregister(&mut self, id: &str) -> Result<()> {
        let e = self
            .entries
            .get(id)
            .with_context(|| format!("unknown adapter '{id}'"))?;
        ensure!(
            Arc::strong_count(&e.binding) == 1,
            "adapter '{id}' is in flight (active slots, queued requests, or pinned \
             as default) — cannot deregister"
        );
        self.entries.remove(id);
        self.prune_prefixes();
        Ok(())
    }

    /// Resolve a request's adapter choice: `Some(id)` must be
    /// registered (unknown ids are an error — submit-time rejection),
    /// `None` falls back to the pinned default (which may itself be
    /// `None` = the session/base default). Touches the LRU clock.
    pub fn resolve(&mut self, id: Option<&str>) -> Result<Option<Arc<AdapterBinding>>> {
        match id {
            None => Ok(self.default_.clone()),
            Some(id) => {
                let t = self.tick();
                let e = self
                    .entries
                    .get_mut(id)
                    .with_context(|| format!("unknown adapter '{id}'"))?;
                e.last_used = t;
                Ok(Some(e.binding.clone()))
            }
        }
    }

    /// Pin a registered adapter as the default for requests that name
    /// no tenant (`None` clears the pin). The pin holds an `Arc`
    /// clone, so a pinned adapter is never evicted.
    pub fn pin_default(&mut self, id: Option<&str>) -> Result<()> {
        match id {
            None => {
                self.default_ = None;
                Ok(())
            }
            Some(id) => {
                let e = self
                    .entries
                    .get(id)
                    .with_context(|| format!("unknown adapter '{id}'"))?;
                self.default_ = Some(e.binding.clone());
                Ok(())
            }
        }
    }

    /// Pin an out-of-registry default binding (the decoder's own
    /// construction-time adapter); budget accounting ignores it.
    pub fn set_default_binding(&mut self, b: Option<Arc<AdapterBinding>>) {
        self.default_ = b;
    }

    /// The pinned default, if any.
    pub fn default_binding(&self) -> Option<&Arc<AdapterBinding>> {
        self.default_.as_ref()
    }

    /// The cached prefix sub-binding of `parent` at `fraction`
    /// (see [`AdapterBinding::prefix`]) — derived once per
    /// `(parent, fraction)` pair, so warm degraded admission costs a
    /// map hit plus an `Arc` clone. Fractions are bucketed to
    /// permille; a parent that was dropped (evicted, hot-swapped) and
    /// whose allocation got reused fails the `ptr_eq` check and is
    /// re-derived rather than served stale.
    pub fn prefix_of(&mut self, parent: &Arc<AdapterBinding>, fraction: f32) -> Arc<AdapterBinding> {
        let f = if fraction.is_finite() { fraction.clamp(0.0, 1.0) } else { 1.0 };
        let key = (Arc::as_ptr(parent) as usize, (f * 1000.0).round() as u32);
        if let Some((w, sub)) = self.prefixes.get(&key) {
            if let Some(live) = w.upgrade() {
                if Arc::ptr_eq(&live, parent) {
                    return sub.clone();
                }
            }
        }
        let sub = Arc::new(parent.prefix(f));
        self.prefixes.insert(key, (Arc::downgrade(parent), sub.clone()));
        sub
    }

    /// Drop prefix-cache entries whose parent binding is gone — called
    /// on the cold registry paths (register/deregister) so the cache
    /// tracks the resident set instead of growing monotonically.
    fn prune_prefixes(&mut self) {
        self.prefixes.retain(|_, (w, _)| w.upgrade().is_some());
    }

    /// Resident prefix-cache entries (tests/metrics).
    pub fn prefix_cache_len(&self) -> usize {
        self.prefixes.len()
    }

    /// Change the byte budget (`0` = unlimited), evicting idle LRU
    /// entries if shrinking requires it; errors — leaving budget and
    /// entries untouched — when only in-flight adapters remain over
    /// the new ceiling.
    pub fn set_budget(&mut self, budget_bytes: usize) -> Result<()> {
        let old = std::mem::replace(&mut self.budget, budget_bytes);
        match self.plan_evictions(0, None) {
            Ok(victims) => {
                for v in &victims {
                    self.entries.remove(v);
                }
                Ok(())
            }
            Err(e) => {
                self.budget = old;
                Err(e.context("shrinking adapter budget"))
            }
        }
    }
}

/// Resolve one tenant's binding from a standalone adapter
/// [`ParamStore`] (checkpoint loads, CLI registration) rather than a
/// live `ForwardSession`. `rank_mask` is the tenant's
/// `[n_modules * max_rank]` mask values (see
/// `nls::SearchSpace::rank_mask`).
pub fn binding_from_store(
    cfg: &ModelConfig,
    store: &ParamStore,
    rank_mask: &[f32],
) -> Result<AdapterBinding> {
    let mut names = Vec::with_capacity(cfg.adapter_modules.len() * 2);
    for m in &cfg.adapter_modules {
        names.push(format!("lora_a.{m}"));
        names.push(format!("lora_b.{m}"));
    }
    let mut named = NamedTensors::new();
    for n in &names {
        named.insert(n, store.get(n)?);
    }
    AdapterBinding::from_named(cfg, &named, rank_mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg_with(budget: usize, items: &[(&str, usize)]) -> AdapterRegistry {
        let mut r = AdapterRegistry::new(budget);
        for (id, bytes) in items {
            r.register(id, AdapterBinding::synthetic(*bytes)).unwrap();
        }
        r
    }

    #[test]
    fn register_resolve_round_trip() {
        let mut r = reg_with(0, &[("a", 100), ("b", 200)]);
        assert_eq!(r.len(), 2);
        assert_eq!(r.resident_bytes(), 300);
        assert!(r.resolve(Some("a")).unwrap().is_some());
        assert!(r.resolve(None).unwrap().is_none());
        let err = r.resolve(Some("nope")).unwrap_err();
        assert!(err.to_string().contains("unknown adapter"), "{err:#}");
        assert_eq!(r.ids(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn lru_eviction_stays_under_budget() {
        let mut r = reg_with(250, &[("a", 100), ("b", 100)]);
        // touch "a" so "b" is the LRU victim
        r.resolve(Some("a")).unwrap();
        r.register("c", AdapterBinding::synthetic(100)).unwrap();
        assert!(r.resident_bytes() <= 250);
        assert!(r.contains("a") && r.contains("c") && !r.contains("b"));
        // evicted id can re-register (round trip)
        r.resolve(Some("c")).unwrap();
        r.register("b", AdapterBinding::synthetic(100)).unwrap();
        assert!(r.contains("b") && !r.contains("a"));
        assert!(r.resident_bytes() <= 250);
    }

    #[test]
    fn single_adapter_over_budget_rejected() {
        let mut r = AdapterRegistry::new(50);
        let err = r.register("big", AdapterBinding::synthetic(51)).unwrap_err();
        assert!(err.to_string().contains("over the 50-byte"), "{err:#}");
        assert!(r.is_empty());
    }

    #[test]
    fn in_flight_adapters_are_not_evicted() {
        let mut r = reg_with(250, &[("a", 100), ("b", 100)]);
        // hold both bindings as an active slot would
        let ha = r.resolve(Some("a")).unwrap();
        let hb = r.resolve(Some("b")).unwrap();
        let err = r.register("c", AdapterBinding::synthetic(100)).unwrap_err();
        assert!(err.to_string().contains("in-flight"), "{err:#}");
        // failed registration mutates nothing
        assert_eq!(r.len(), 2);
        assert_eq!(r.resident_bytes(), 200);
        drop(ha);
        drop(hb);
        r.register("c", AdapterBinding::synthetic(100)).unwrap();
        assert!(r.resident_bytes() <= 250);
    }

    #[test]
    fn hot_swap_keeps_old_binding_for_active_slots() {
        let mut r = reg_with(0, &[("a", 100)]);
        let old = r.resolve(Some("a")).unwrap().unwrap();
        // swap while in flight: allowed; the old Arc stays alive
        r.register("a", AdapterBinding::synthetic(150)).unwrap();
        let new = r.resolve(Some("a")).unwrap().unwrap();
        assert!(!Arc::ptr_eq(&old, &new));
        assert_eq!(old.bytes(), 100);
        assert_eq!(new.bytes(), 150);
        assert_eq!(r.resident_bytes(), 150);
    }

    #[test]
    fn deregister_in_flight_is_an_error() {
        let mut r = reg_with(0, &[("a", 100)]);
        let hold = r.resolve(Some("a")).unwrap();
        assert!(r.deregister("a").is_err());
        drop(hold);
        r.deregister("a").unwrap();
        assert!(r.deregister("a").is_err());
    }

    #[test]
    fn pinned_default_resists_eviction() {
        let mut r = reg_with(250, &[("a", 100), ("b", 100)]);
        r.pin_default(Some("a")).unwrap();
        // "a" is older than "b" but pinned, so "b" is evicted instead
        let err = r.register("c", AdapterBinding::synthetic(200)).unwrap_err();
        assert!(err.to_string().contains("in-flight"), "{err:#}");
        r.register("c", AdapterBinding::synthetic(100)).unwrap();
        assert!(r.contains("a") && r.contains("c") && !r.contains("b"));
        assert!(r.resolve(None).unwrap().is_some());
        r.pin_default(None).unwrap();
        assert!(r.resolve(None).unwrap().is_none());
    }

    #[test]
    fn prefix_cache_hits_return_the_same_arc() {
        let mut r = reg_with(0, &[("a", 100)]);
        let parent = r.resolve(Some("a")).unwrap().unwrap();
        let s1 = r.prefix_of(&parent, 0.25);
        let s2 = r.prefix_of(&parent, 0.25);
        assert!(Arc::ptr_eq(&s1, &s2), "second lookup must be a cache hit");
        assert_eq!(r.prefix_cache_len(), 1);
        // a different fraction is a different rung
        let s3 = r.prefix_of(&parent, 0.5);
        assert!(!Arc::ptr_eq(&s1, &s3));
        assert_eq!(r.prefix_cache_len(), 2);
    }

    #[test]
    fn prefix_cache_is_pruned_with_its_parent() {
        let mut r = reg_with(0, &[("a", 100)]);
        {
            let parent = r.resolve(Some("a")).unwrap().unwrap();
            r.prefix_of(&parent, 0.25);
            assert_eq!(r.prefix_cache_len(), 1);
        }
        // hot-swap drops the old parent; registry ops prune its prefixes
        r.register("a", AdapterBinding::synthetic(120)).unwrap();
        assert_eq!(r.prefix_cache_len(), 0);
        let parent = r.resolve(Some("a")).unwrap().unwrap();
        let sub = r.prefix_of(&parent, 0.25);
        let again = r.prefix_of(&parent, 0.25);
        assert!(Arc::ptr_eq(&sub, &again));
    }

    #[test]
    fn shrinking_budget_evicts_idle_only() {
        let mut r = reg_with(0, &[("a", 100), ("b", 100)]);
        let hold = r.resolve(Some("b")).unwrap();
        // "a" is idle and can go; "b" is pinned by the hold
        r.set_budget(100).unwrap();
        assert!(!r.contains("a") && r.contains("b"));
        let err = r.set_budget(50).unwrap_err();
        assert!(err.to_string().contains("in-flight"), "{err:#}");
        assert_eq!(r.budget_bytes(), 100);
        drop(hold);
        r.set_budget(50).unwrap();
        assert!(r.is_empty());
    }
}
