//! Asynchronous serving frontend: submit from any thread, stream
//! tokens back, deadline-ordered admission.
//!
//! Mirrors the `coordinator::router::EvalRouter` thread-owns-backend
//! pattern: PJRT handles and the native exe cache are not `Send`, so a
//! dedicated runtime thread builds its own [`Runtime`] + [`Decoder`]
//! from an explicit spec and drives a [`StepEngine`] in a continuous
//! admission loop —
//!
//! ```text
//!   ingest (drain the channel; block only when fully idle)
//!   admit  (free KV slots fill from the pending queue: earliest
//!           deadline first, priority then FIFO as tie-breaks)
//!   step   (one batched decode step; stream each token out)
//! ```
//!
//! — so queue polls interleave between decode steps without ever
//! re-binding the decode session. Backpressure is a bounded pending
//! queue: [`SubmitHandle::submit`] returns [`Submit::Rejected`] past
//! `queue_cap` undrained requests instead of buffering unboundedly (or
//! hanging the caller). Submitters get a [`StreamHandle`] delivering
//! per-token progress and the final [`GenResponse`]; delivery into a
//! stream's preallocated buffer keeps warm decode steps allocation-free
//! on the runtime thread.
//!
//! The loop is **supervised**: admit and step run under `catch_unwind`,
//! so a panic fails only the in-flight streams (each with an
//! attributable [`ServeFault`] error) and the engine is rebuilt from
//! the decoder's resident base weights — fresh K/V planes, same
//! prepared sparse weights — under a bounded restart budget with
//! exponential backoff. Budget exhausted, the server stops accepting,
//! drains its queue as rejected, and goes down cleanly (no hung
//! handles). Between steps a reap sweep enforces hard per-request
//! wall-clock budgets (`GenRequest::max_wall`), deadlines when
//! [`ServerOpts::enforce_deadlines`] is set, explicit
//! [`StreamHandle::cancel`] calls, and abandoned handles (dropped
//! before the stream ended) — each frees its KV slot immediately.
//! Fault drills arm [`ServerOpts::fault`] or `SHEARS_FAULT`
//! (`serve::fault` has the grammar).
//!
//! The loop is also **overload-adaptive** when
//! [`ServerOpts::brownout`] is enabled: a [`BrownoutController`] in
//! the loop state (it survives supervised restarts — overload does not
//! reset because the engine was rebuilt) is fed every successful
//! step's wall time and every clean completion, evaluated once per
//! iteration, and its verdicts published into submit-side atomics.
//! Past `Normal`, opted-in admissions are bound to a cached **prefix
//! sub-adapter** (`AdapterRegistry::prefix_of`); in `Shedding`,
//! [`SubmitHandle::submit`] bounces submissions past the admissible
//! horizon with [`RejectReason::Overloaded`], counted in
//! [`ServeMetrics::shed`] so accepted + rejected + shed always
//! reconciles with submissions.

use super::{
    AdapterId, AdapterRegistry, Admission, BrownoutController, BrownoutOpts, Decoder, FaultKind,
    FaultPlan, GenRequest, GenResponse, ServeFault, ServeMetrics, StepEngine,
};
use crate::model::ParamStore;
use crate::ops::model::AdapterBinding;
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use anyhow::{Context, Result};
use std::cell::Cell;
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as AOrd};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};
use std::time::{Duration, Instant};

/// Server construction spec. Like the eval router, the backend is an
/// explicit choice (`native|pjrt|auto`, the `--backend` grammar) so a
/// spawner's selection is never overridden by env auto-detection.
#[derive(Clone, Debug)]
pub struct ServerOpts {
    pub backend: String,
    pub artifacts_dir: String,
    /// model config name in the backend's manifest
    pub config: String,
    /// forward entry to serve (must support incremental decode)
    pub entry: String,
    /// concurrent KV slots; 0 = the config's `batch_eval`
    pub slots: usize,
    /// bounded pending queue: submissions past this many undrained
    /// requests come back [`Submit::Rejected`]
    pub queue_cap: usize,
    /// resident tenant-adapter byte budget (LRU eviction past it);
    /// `0` = unlimited
    pub adapter_budget_bytes: usize,
    /// actively cancel requests past their `GenRequest::deadline`
    /// (fault kind `deadline-exceeded`). Off by default: deadlines
    /// stay the advisory EDF hint they have always been, and misses
    /// are merely counted. `max_wall` is enforced regardless.
    pub enforce_deadlines: bool,
    /// supervised engine rebuilds tolerated after panics before the
    /// server gives up and shuts down cleanly
    pub restart_budget: u32,
    /// backoff before restart `n` is `restart_backoff_ms << (n-1)`,
    /// capped at 64× — keeps a crash loop from spinning hot
    pub restart_backoff_ms: u64,
    /// deterministic fault-injection plan (drills/tests). Empty = one
    /// branch per step. When empty, `SHEARS_FAULT` is consulted at
    /// spawn so drills work against an unmodified binary.
    pub fault: FaultPlan,
    /// overload brownout controller (disabled by default — armed, it
    /// degrades opted-in admissions to prefix sub-adapters and sheds
    /// `Overloaded` past the admissible horizon; see
    /// [`super::brownout`])
    pub brownout: BrownoutOpts,
    /// bound on every control-plane round-trip — the spawn readiness
    /// handshake, `metrics()`, `register_adapter()` — so a wedged
    /// runtime thread yields a clear timeout error instead of hanging
    /// the caller forever
    pub control_timeout_ms: u64,
}

impl Default for ServerOpts {
    fn default() -> ServerOpts {
        ServerOpts {
            backend: "native".into(),
            artifacts_dir: "artifacts".into(),
            config: "tiny-llama".into(),
            entry: "forward_eval_base".into(),
            slots: 0,
            queue_cap: 64,
            adapter_budget_bytes: 0,
            enforce_deadlines: false,
            restart_budget: 3,
            restart_backoff_ms: 20,
            fault: FaultPlan::none(),
            brownout: BrownoutOpts::default(),
            control_timeout_ms: 60_000,
        }
    }
}

/// Outcome of a submission attempt.
pub enum Submit {
    Accepted(StreamHandle),
    Rejected(RejectReason),
}

impl Submit {
    /// Convenience: the stream handle, or an error naming the reason.
    pub fn accepted(self) -> Result<StreamHandle> {
        match self {
            Submit::Accepted(h) => Ok(h),
            Submit::Rejected(r) => anyhow::bail!("submission rejected: {r:?}"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// the pending queue is at `queue_cap` — shed load or retry later
    QueueFull,
    /// the server is shutting down (or its thread is gone)
    ShuttingDown,
    /// the request names an adapter id that is not registered —
    /// register it (or fix the id) and resubmit
    UnknownAdapter,
    /// the brownout controller is `Shedding` and the queue is past the
    /// admissible horizon — the server is overloaded; back off and
    /// retry (counted in [`ServeMetrics::shed`], never silently
    /// dropped)
    Overloaded,
}

// ------------------------------------------------------------ streams

struct StreamInner {
    /// generated tokens in arrival order (prompt tokens not included)
    tokens: Vec<i32>,
    done: Option<std::result::Result<GenResponse, String>>,
}

/// One request's delivery cell: the runtime thread pushes tokens and
/// the final response; the submitter blocks on the condvar. The token
/// buffer is preallocated at submission, so warm-path pushes on the
/// runtime thread never allocate.
pub(crate) struct StreamShared {
    inner: Mutex<StreamInner>,
    cv: Condvar,
    /// set by [`StreamHandle::cancel`]; the runtime thread polls it in
    /// its reap sweep and frees the KV slot (no channel round-trip, so
    /// cancellation works even while the server is mid-step)
    // ORDERING(cancel): handshake — Release publish by the canceller,
    // Acquire poll by the runtime thread's reap sweep.
    cancel: AtomicBool,
}

impl StreamShared {
    fn new(capacity: usize) -> StreamShared {
        StreamShared {
            inner: Mutex::new(StreamInner { tokens: Vec::with_capacity(capacity), done: None }),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
        }
    }

    fn lock(&self) -> MutexGuard<'_, StreamInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(crate) fn push_token(&self, t: i32) {
        self.lock().tokens.push(t);
        self.cv.notify_all();
    }

    pub(crate) fn finish(&self, r: std::result::Result<GenResponse, String>) {
        let mut g = self.lock();
        if g.done.is_none() {
            g.done = Some(r);
        }
        drop(g);
        self.cv.notify_all();
    }
}

/// Caller-side handle to one in-flight request: iterate generated
/// tokens as they land, then collect the final [`GenResponse`].
pub struct StreamHandle {
    shared: Arc<StreamShared>,
    read: usize,
    id: u64,
}

impl StreamHandle {
    /// Submission sequence number (also the FIFO tie-break key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the next generated token arrives; `None` once the
    /// request is finished and every token has been consumed.
    pub fn next_token(&mut self) -> Option<i32> {
        let mut g = self.shared.lock();
        loop {
            if self.read < g.tokens.len() {
                let t = g.tokens[self.read];
                self.read += 1;
                return Some(t);
            }
            if g.done.is_some() {
                return None;
            }
            g = self.shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking variant of [`StreamHandle::next_token`]: `None`
    /// means "nothing new yet", not necessarily finished.
    pub fn try_next_token(&mut self) -> Option<i32> {
        let g = self.shared.lock();
        if self.read < g.tokens.len() {
            let t = g.tokens[self.read];
            self.read += 1;
            return Some(t);
        }
        None
    }

    /// Ask the server to cancel this request: if still queued it is
    /// dropped at admission, if decoding its KV slot is freed at the
    /// next reap sweep. Delivery is asynchronous — the stream then
    /// finishes with a `cancelled` fault error (or with the normal
    /// response, if completion raced the cancel). Idempotent.
    pub fn cancel(&self) {
        self.shared.cancel.store(true, AOrd::Release);
    }

    /// Block until the request completes; the response's latency/TTFT
    /// clocks started at submission, so queue wait is included. A
    /// request that faulted, was cancelled, or was shed returns an
    /// error carrying its request id, slot, and fault kind
    /// ([`ServeFault`]'s display), so operators can attribute it.
    pub fn wait(self) -> Result<GenResponse> {
        let mut g = self.shared.lock();
        loop {
            if let Some(done) = &g.done {
                return done.clone().map_err(|e| {
                    // fault errors already lead with "request N (slot
                    // S)" attribution — don't stutter the prefix
                    if e.starts_with("request ") {
                        anyhow::anyhow!("{e}")
                    } else {
                        anyhow::anyhow!("request {}: {e}", self.id)
                    }
                });
            }
            g = self.shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Bounded [`StreamHandle::wait`]: block at most `timeout` for the
    /// request to complete. `Some(result)` once finished (same error
    /// mapping as `wait`); `None` when the budget expires with the
    /// request still running — the handle stays usable: keep
    /// streaming, call again, or [`StreamHandle::cancel`].
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<GenResponse>> {
        let deadline = Instant::now().checked_add(timeout)?;
        let mut g = self.shared.lock();
        loop {
            if let Some(done) = &g.done {
                let id = self.id;
                return Some(done.clone().map_err(|e| {
                    if e.starts_with("request ") {
                        anyhow::anyhow!("{e}")
                    } else {
                        anyhow::anyhow!("request {id}: {e}")
                    }
                }));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _timed_out) = self
                .shared
                .cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            g = ng;
        }
    }
}

// ------------------------------------------------------ pending queue

/// A submitted request waiting for a KV slot.
struct Queued {
    req: GenRequest,
    /// submission sequence number — the FIFO tie-break
    id: u64,
    submitted: Instant,
    /// absolute deadline resolved at submission
    deadline: Option<Instant>,
    stream: Arc<StreamShared>,
    /// tenant binding resolved at submit time (`None` = server
    /// default); holding the `Arc` pins the adapter against eviction
    /// while the request queues
    adapter: Option<Arc<AdapterBinding>>,
}

/// Admission order: earliest deadline first (every deadlined request
/// ahead of the best-effort class), then higher priority, then FIFO.
/// `BinaryHeap<Reverse<Queued>>` pops the minimum under this order.
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> Ordering {
        let by_deadline = match (self.deadline, other.deadline) {
            (Some(a), Some(b)) => a.cmp(&b),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => Ordering::Equal,
        };
        by_deadline
            .then_with(|| other.req.priority.cmp(&self.req.priority))
            .then_with(|| self.id.cmp(&other.id))
    }
}

impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Queued {}

// ------------------------------------------------------------- server

enum Msg {
    Request(Queued),
    Metrics(Sender<ServeMetrics>),
    /// build a tenant binding from the resident super-network weights
    /// (only the runtime thread owns the session) and insert it into
    /// the shared registry
    RegisterAdapter {
        id: AdapterId,
        rank_mask: HostTensor,
        reply: Sender<std::result::Result<(), String>>,
    },
    /// hold admission (requests keep queueing; in-flight slots keep
    /// decoding) — drain control for tests and maintenance
    Pause,
    Resume,
    /// stop accepting, drain pending + in-flight, reply final metrics
    Shutdown(Option<Sender<ServeMetrics>>),
}

/// Submit-side state shared between every handle and the runtime
/// thread. The depth gauge counts accepted-but-not-yet-admitted
/// requests (channel + pending queue), which is exactly what the
/// `queue_cap` backpressure bound applies to.
struct Shared {
    // ORDERING(depth): gauge — the CAS reservation loop and its
    // releases pair AcqRel/Acquire so a reserved token is visible
    // before the queued request is; the CAS-loop preload may be
    // Relaxed (the CAS itself revalidates).
    depth: AtomicUsize,
    // ORDERING(max_depth): counter — monotonic high-water statistic;
    // metrics snapshots tolerate benign lag.
    max_depth: AtomicU64,
    // ORDERING(rejected): counter — statistic, no ordering duty.
    rejected: AtomicU64,
    // ORDERING(accepting): handshake — Release on shutdown, Acquire
    // by submitters; a submitter that sees false must also see the
    // shutdown state that preceded it.
    accepting: AtomicBool,
    /// set by the runtime thread right before its final channel drain:
    /// a submitter observing it after a successful send fails its own
    /// stream (idempotently), closing the drain/send race — see
    /// [`SubmitHandle::submit`]
    // ORDERING(closed): shutdown — SeqCst on both sides: the store
    // must be totally ordered against every submitter's post-send
    // load, or a send racing the final drain could miss both the
    // drain and the self-finish path (see the model checker's
    // `SubmitModel::ClosedAfterDrain`).
    closed: AtomicBool,
    // ORDERING(seq): counter — request-id allocator; uniqueness only.
    seq: AtomicU64,
    /// context window, published by the runtime thread before readiness
    /// (sizes stream buffers so token delivery never reallocates)
    // ORDERING(window): handshake — Release publish at readiness,
    // Acquire read at submit (the buffer sizing must not be reordered
    // ahead of engine construction).
    window: AtomicUsize,
    queue_cap: usize,
    /// submissions bounced [`RejectReason::Overloaded`] by brownout
    /// shedding — disjoint from `rejected` so the three buckets
    /// (accepted, rejected, shed) reconcile with total submissions
    // ORDERING(shed): counter — statistic, no ordering duty.
    shed: AtomicU64,
    /// brownout rung published by the runtime thread after each
    /// controller evaluation (`BrownoutState::gauge` encoding)
    // ORDERING(brownout_state): handshake — Release publish so a
    // reader pairing it with `admissible` sees a consistent rung.
    brownout_state: AtomicU64,
    /// admissible queue depth while `Shedding`; `usize::MAX` = not
    /// shedding (the submit-side check is then never taken)
    // ORDERING(admissible): handshake — Release publish by the
    // controller, Acquire check in submit; pairing a stale admissible
    // with a fresh depth only sheds one request late/early (benign —
    // the cap check below still bounds depth).
    admissible: AtomicUsize,
    /// control-plane round-trip bound (see `ServerOpts::control_timeout_ms`)
    control_timeout: Duration,
    /// written by the runtime thread as it exits, so `metrics()` and
    /// `shutdown()` still return the final numbers after the server
    /// took itself down (restart budget exhausted) and the channel died
    final_metrics: Mutex<Option<ServeMetrics>>,
}

/// Cloneable, `Send` submission endpoint — one per submitter thread.
#[derive(Clone)]
pub struct SubmitHandle {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    /// tenant registry shared with the runtime thread: submit-time
    /// resolution here, binding construction + insertion over there
    registry: Arc<Mutex<AdapterRegistry>>,
}

fn lock_registry(m: &Mutex<AdapterRegistry>) -> MutexGuard<'_, AdapterRegistry> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The exited runtime thread's last snapshot (see `Shared::final_metrics`).
fn final_metrics(shared: &Shared) -> Result<ServeMetrics> {
    shared
        .final_metrics
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
        .context("serve server gone before publishing final metrics")
}

impl SubmitHandle {
    /// Try to enqueue a request. Non-blocking: past `queue_cap`
    /// undrained submissions, after shutdown, or naming an
    /// unregistered adapter, this returns [`Submit::Rejected`]
    /// immediately — callers shed load instead of hanging. Every
    /// rejection path counts into [`ServeMetrics::rejected`], so the
    /// counter reconciles with caller-observed rejects. On acceptance
    /// the request is stamped `submitted = now`, its relative deadline
    /// resolved against that instant and its adapter binding pinned.
    pub fn submit(&self, req: GenRequest) -> Submit {
        if !self.shared.accepting.load(AOrd::Acquire) {
            self.shared.rejected.fetch_add(1, AOrd::Relaxed);
            return Submit::Rejected(RejectReason::ShuttingDown);
        }
        // brownout shedding: while the controller is `Shedding` the
        // runtime thread publishes a finite admissible depth; past it,
        // bounce explicitly (`Overloaded`) — overload never silently
        // drops work. Counted apart from `rejected` so submissions
        // reconcile: accepted + rejected + shed.
        if self.shared.depth.load(AOrd::Acquire) >= self.shared.admissible.load(AOrd::Acquire) {
            self.shared.shed.fetch_add(1, AOrd::Relaxed);
            return Submit::Rejected(RejectReason::Overloaded);
        }
        // resolve the tenant before reserving a queue token: an
        // unknown id rejects without consuming capacity. The binding
        // is fixed here — a later hot-swap does not retarget queued
        // requests.
        let adapter = match lock_registry(&self.registry).resolve(req.adapter.as_deref()) {
            Ok(b) => b,
            Err(_) => {
                self.shared.rejected.fetch_add(1, AOrd::Relaxed);
                return Submit::Rejected(RejectReason::UnknownAdapter);
            }
        };
        // reserve a queue token or reject — never overshoots the cap
        let mut d = self.shared.depth.load(AOrd::Relaxed);
        loop {
            if d >= self.shared.queue_cap {
                self.shared.rejected.fetch_add(1, AOrd::Relaxed);
                return Submit::Rejected(RejectReason::QueueFull);
            }
            match self.shared.depth.compare_exchange_weak(d, d + 1, AOrd::AcqRel, AOrd::Relaxed) {
                Ok(_) => break,
                Err(cur) => d = cur,
            }
        }
        let submitted = Instant::now();
        let deadline = req.deadline.and_then(|dl| submitted.checked_add(dl));
        let id = self.shared.seq.fetch_add(1, AOrd::Relaxed);
        // generated tokens ≤ min(budget, window): full capacity up
        // front keeps the runtime thread's token pushes allocation-free
        let window = self.shared.window.load(AOrd::Acquire).max(1);
        let capacity = req.max_new_tokens.saturating_add(1).min(window);
        let stream = Arc::new(StreamShared::new(capacity));
        let q = Queued { req, id, submitted, deadline, stream: stream.clone(), adapter };
        if self.tx.send(Msg::Request(q)).is_err() {
            self.shared.depth.fetch_sub(1, AOrd::AcqRel);
            self.shared.rejected.fetch_add(1, AOrd::Relaxed);
            return Submit::Rejected(RejectReason::ShuttingDown);
        }
        // the high-water mark records only after the send succeeds —
        // a failed send releases its reservation above, and counting
        // it first would let the gauge exceed any depth the queue
        // actually reached
        self.shared.max_depth.fetch_max(d as u64 + 1, AOrd::Relaxed);
        // Shutdown race: if `closed` is still false here (SeqCst order),
        // our send completed before the runtime thread's final drain
        // began, so the message is guaranteed to be processed (served or
        // failed). If it reads true, the drain may already have ended —
        // fail the stream ourselves; `finish` is idempotent, so whoever
        // got there first wins and the caller never hangs.
        if self.shared.closed.load(AOrd::SeqCst) {
            stream.finish(Err("server shutting down".into()));
        }
        Submit::Accepted(StreamHandle { shared: stream, read: 0, id })
    }

    /// Snapshot the server's cumulative metrics. Blocks for the reply
    /// at most `ServerOpts::control_timeout_ms` (a wedged runtime
    /// thread errors instead of hanging the caller); after the runtime
    /// thread exited (shutdown, or it took itself down when the
    /// restart budget ran out) this returns its final numbers instead
    /// of erroring.
    pub fn metrics(&self) -> Result<ServeMetrics> {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Metrics(tx)).is_err() {
            return final_metrics(&self.shared);
        }
        match rx.recv_timeout(self.shared.control_timeout) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Disconnected) => final_metrics(&self.shared),
            Err(RecvTimeoutError::Timeout) => anyhow::bail!(
                "serve server unresponsive: metrics not answered within {:?}",
                self.shared.control_timeout
            ),
        }
    }

    /// Register (or hot-swap) tenant `id` as a sub-adapter of the
    /// server's resident super-network LoRA weights: `rank_mask`
    /// selects its active heads. The binding is built on the runtime
    /// thread (it owns the session); this blocks for the outcome, at
    /// most `ServerOpts::control_timeout_ms`. Slots already decoding
    /// under a swapped-out binding keep it until they retire.
    pub fn register_adapter(&self, id: &str, rank_mask: &HostTensor) -> Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::RegisterAdapter {
                id: id.to_string(),
                rank_mask: rank_mask.clone(),
                reply: tx,
            })
            .ok()
            .context("serve server gone")?;
        match rx.recv_timeout(self.shared.control_timeout) {
            Ok(r) => r.map_err(|e| anyhow::anyhow!("register adapter '{id}': {e}")),
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("serve server dropped register reply for adapter '{id}'")
            }
            Err(RecvTimeoutError::Timeout) => anyhow::bail!(
                "serve server unresponsive: register adapter '{id}' not acknowledged within {:?}",
                self.shared.control_timeout
            ),
        }
    }

    /// Remove tenant `id`; errors while queued requests or active
    /// slots still hold its binding.
    pub fn deregister_adapter(&self, id: &str) -> Result<()> {
        lock_registry(&self.registry).deregister(id)
    }

    /// Pin a registered adapter as the default for requests naming no
    /// tenant (`None` restores the construction-time binding).
    pub fn pin_default_adapter(&self, id: Option<&str>) -> Result<()> {
        lock_registry(&self.registry).pin_default(id)
    }

    /// Cap resident adapter bytes (`0` = unlimited).
    pub fn set_adapter_budget(&self, bytes: usize) -> Result<()> {
        lock_registry(&self.registry).set_budget(bytes)
    }

    /// Total bytes of registered resident adapters.
    pub fn adapter_bytes(&self) -> usize {
        lock_registry(&self.registry).resident_bytes()
    }

    /// Registered adapter ids, sorted.
    pub fn adapter_ids(&self) -> Vec<AdapterId> {
        lock_registry(&self.registry).ids()
    }
}

/// Handle to the serving thread; dropping it shuts the server down
/// (draining accepted work first).
pub struct ServeServer {
    handle: SubmitHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServeServer {
    /// Spawn the runtime thread, which builds its own backend from
    /// `opts` and owns `stores` (uploaded once; prepared sparse
    /// structure cached for the server's lifetime). Fails fast — and
    /// visibly — if the backend, config, or entry can't serve the
    /// incremental decode path.
    pub fn spawn(
        opts: ServerOpts,
        stores: Vec<ParamStore>,
        rank_mask: Option<HostTensor>,
    ) -> Result<ServeServer> {
        let (tx, rx) = channel::<Msg>();
        let control_timeout = Duration::from_millis(opts.control_timeout_ms.max(1));
        let shared = Arc::new(Shared {
            depth: AtomicUsize::new(0),
            max_depth: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            accepting: AtomicBool::new(true),
            closed: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            window: AtomicUsize::new(0),
            queue_cap: opts.queue_cap,
            shed: AtomicU64::new(0),
            brownout_state: AtomicU64::new(0),
            admissible: AtomicUsize::new(usize::MAX),
            control_timeout,
            final_metrics: Mutex::new(None),
        });
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let registry = Arc::new(Mutex::new(AdapterRegistry::new(opts.adapter_budget_bytes)));
        let shared_t = shared.clone();
        let registry_t = registry.clone();
        let join = std::thread::Builder::new()
            .name("shears-serve-server".into())
            .spawn(move || server_main(rx, opts, stores, rank_mask, shared_t, registry_t, ready_tx))
            .context("spawn serve-server thread")?;
        match ready_rx.recv_timeout(control_timeout) {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = join.join();
                anyhow::bail!("serve server failed to start: {e}");
            }
            Err(RecvTimeoutError::Disconnected) => {
                let _ = join.join();
                anyhow::bail!("serve server died during startup");
            }
            Err(RecvTimeoutError::Timeout) => {
                // deliberately NOT joined: a wedged startup would hang
                // this caller too — the thread is left to finish (or
                // wedge) on its own, detached behind the error
                anyhow::bail!(
                    "serve server unresponsive: not ready within {control_timeout:?} \
                     (backend build or weight upload wedged?)"
                );
            }
        }
        Ok(ServeServer { handle: SubmitHandle { tx, shared, registry }, join: Some(join) })
    }

    /// A cloneable submission endpoint for other threads.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    pub fn submit(&self, req: GenRequest) -> Submit {
        self.handle.submit(req)
    }

    pub fn metrics(&self) -> Result<ServeMetrics> {
        self.handle.metrics()
    }

    /// See [`SubmitHandle::register_adapter`].
    pub fn register_adapter(&self, id: &str, rank_mask: &HostTensor) -> Result<()> {
        self.handle.register_adapter(id, rank_mask)
    }

    /// See [`SubmitHandle::deregister_adapter`].
    pub fn deregister_adapter(&self, id: &str) -> Result<()> {
        self.handle.deregister_adapter(id)
    }

    /// See [`SubmitHandle::pin_default_adapter`].
    pub fn pin_default_adapter(&self, id: Option<&str>) -> Result<()> {
        self.handle.pin_default_adapter(id)
    }

    /// See [`SubmitHandle::set_adapter_budget`].
    pub fn set_adapter_budget(&self, bytes: usize) -> Result<()> {
        self.handle.set_adapter_budget(bytes)
    }

    /// See [`SubmitHandle::adapter_bytes`].
    pub fn adapter_bytes(&self) -> usize {
        self.handle.adapter_bytes()
    }

    /// See [`SubmitHandle::adapter_ids`].
    pub fn adapter_ids(&self) -> Vec<AdapterId> {
        self.handle.adapter_ids()
    }

    /// Hold admission (submissions still queue, in-flight requests keep
    /// decoding). With admission paused the pending queue orders fully
    /// before any pop — deterministic EDF, used by tests and drains.
    pub fn pause(&self) -> Result<()> {
        self.handle.tx.send(Msg::Pause).ok().context("serve server gone")
    }

    pub fn resume(&self) -> Result<()> {
        self.handle.tx.send(Msg::Resume).ok().context("serve server gone")
    }

    /// Stop accepting, drain every accepted request, join the thread,
    /// and return the final cumulative metrics. Still succeeds after
    /// the runtime thread took itself down (restart budget exhausted) —
    /// the final snapshot is read from the shared cell instead.
    pub fn shutdown(mut self) -> Result<ServeMetrics> {
        self.handle.shared.accepting.store(false, AOrd::Release);
        let (tx, rx) = channel();
        let sent = self.handle.tx.send(Msg::Shutdown(Some(tx))).is_ok();
        let m = if sent { rx.recv().ok() } else { None };
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        match m {
            Some(m) => Ok(m),
            None => final_metrics(&self.handle.shared),
        }
    }
}

impl Drop for ServeServer {
    fn drop(&mut self) {
        self.handle.shared.accepting.store(false, AOrd::Release);
        let _ = self.handle.tx.send(Msg::Shutdown(None));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ------------------------------------------------------ runtime thread

/// Completions sampled for percentile snapshots. A ring over the most
/// recent window keeps a long-lived server O(1) in memory and bounds
/// the per-snapshot sort, instead of cloning + sorting an ever-growing
/// history on the decode thread. Exact (full-history) percentiles
/// until the window fills — which covers every test and bench run.
const METRIC_WINDOW: usize = 4096;

// --------------------------------------------------- panic supervision

thread_local! {
    /// true while the runtime thread runs a supervised engine region —
    /// the process-wide delegating hook stays quiet for those panics
    /// (they are caught and become attributable stream errors) while
    /// every other thread's panics keep printing as before
    static SUPERVISED: Cell<bool> = const { Cell::new(false) };
}

static HOOK: Once = Once::new();

fn install_supervised_hook() {
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPERVISED.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
}

/// Run `f` under `catch_unwind`, returning a panicking region's
/// payload as a string. `AssertUnwindSafe` is sound because both
/// callers respond to `Err` by discarding the engine the panic
/// interrupted (supervised restart) — no torn state is ever reused.
fn supervised<T>(f: impl FnOnce() -> T) -> std::result::Result<T, String> {
    install_supervised_hook();
    SUPERVISED.with(|s| s.set(true));
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    SUPERVISED.with(|s| s.set(false));
    r.map_err(|p| {
        if let Some(s) = p.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = p.downcast_ref::<String>() {
            s.clone()
        } else {
            "panic with non-string payload".to_string()
        }
    })
}

struct LoopState {
    pending: BinaryHeap<Reverse<Queued>>,
    paused: bool,
    open: bool,
    /// accepted submissions seen by the runtime thread
    requests: u64,
    /// completed requests (ring write cursor)
    completed: u64,
    misses: u64,
    /// latency/TTFT rings, paired by index (same request)
    lat: Vec<f64>,
    ttft: Vec<f64>,
    /// supervised engine rebuilds performed so far
    restarts: u32,
    /// requests cancelled/shed before ever touching a KV slot (the
    /// engine counts in-flight cancellations; `snapshot` adds the two)
    queue_cancelled: u64,
    /// counters inherited from engines retired by supervised restarts —
    /// `fold_metrics` *sets* fields, so pre-restart work would vanish
    /// from snapshots without this
    carried: ServeMetrics,
    /// overload state machine — here, not on the engine, so supervised
    /// restarts don't reset it mid-overload
    brownout: BrownoutController,
}

fn record_done(state: &mut LoopState, resp: &GenResponse) {
    if state.lat.len() < METRIC_WINDOW {
        state.lat.push(resp.latency_ms);
        state.ttft.push(resp.ttft_ms);
    } else {
        let i = (state.completed as usize) % METRIC_WINDOW;
        state.lat[i] = resp.latency_ms;
        state.ttft[i] = resp.ttft_ms;
    }
    state.completed += 1;
    if resp.deadline_missed {
        state.misses += 1;
    }
    // clean completions feed the controller's miss ring + per-request
    // cost model (no-op unless brownout is enabled)
    state.brownout.observe_completion(resp.new_tokens, resp.deadline_missed);
}

/// Sum engine-owned counters from `c` into `into` (the occupancy mean
/// merges weighted by decode steps). Used both to accumulate a retired
/// engine into `LoopState::carried` and to add `carried` back into a
/// live snapshot.
fn merge_counters(into: &mut ServeMetrics, c: &ServeMetrics) {
    let steps = into.decode_steps + c.decode_steps;
    if steps > 0 {
        into.mean_batch_occupancy = (into.mean_batch_occupancy * into.decode_steps as f64
            + c.mean_batch_occupancy * c.decode_steps as f64)
            / steps as f64;
    }
    into.prefills += c.prefills;
    into.decode_steps += c.decode_steps;
    into.forwards += c.forwards;
    into.generated_tokens += c.generated_tokens;
    into.truncated_prompts += c.truncated_prompts;
    into.faults += c.faults;
    into.cancelled += c.cancelled;
    into.quarantined += c.quarantined;
    into.degraded += c.degraded;
}

/// Deliver retired responses to their streams: clean completions
/// record into the latency rings and resolve `Ok`; faulted/cancelled
/// ones resolve `Err` with the [`ServeFault`] attribution string. The
/// rings track successful completions only, so a burst of
/// cancellations cannot skew the latency percentiles.
fn deliver(
    retired: &mut Vec<(u64, GenResponse)>,
    state: &mut LoopState,
    streams: &mut HashMap<u64, Arc<StreamShared>>,
) {
    for (id, resp) in retired.drain(..) {
        let stream = streams.remove(&id);
        match &resp.fault {
            None => {
                record_done(state, &resp);
                if let Some(s) = stream {
                    s.finish(Ok(resp));
                }
            }
            Some(f) => {
                if let Some(s) = stream {
                    s.finish(Err(f.to_string()));
                }
            }
        }
    }
}

/// A panic (or an engine-wide error) escaped a supervised region: fail
/// every in-flight stream attributably, then rebuild the engine over
/// the decoder's resident prepared weights — fresh K/V planes, the old
/// (suspect) state dropped — carrying the fault plan's attempt counter
/// and the dead engine's metrics counters across. Sleeps the
/// exponential backoff before rebuilding. Returns `false` when the
/// restart budget is exhausted (or the rebuild itself fails): the
/// caller takes the server down cleanly.
fn supervise_restart<'d>(
    engine: &mut StepEngine<'d>,
    decoder: &'d Decoder<'_>,
    detail: &str,
    opts: &ServerOpts,
    state: &mut LoopState,
    streams: &mut HashMap<u64, Arc<StreamShared>>,
    retired: &mut Vec<(u64, GenResponse)>,
) -> bool {
    engine.abort_all(FaultKind::StepPanic, detail, retired);
    deliver(retired, state, streams);
    if state.restarts >= opts.restart_budget {
        return false;
    }
    state.restarts += 1;
    let backoff = opts.restart_backoff_ms.saturating_mul(1 << (state.restarts - 1).min(6));
    if backoff > 0 {
        std::thread::sleep(Duration::from_millis(backoff));
    }
    let plan = engine.take_fault_plan();
    let Ok(mut fresh) = decoder.step_engine() else {
        return false;
    };
    fresh.set_fault_plan(plan);
    let mut c = ServeMetrics::default();
    engine.fold_metrics(&mut c);
    merge_counters(&mut state.carried, &c);
    *engine = fresh;
    true
}

fn snapshot(
    state: &LoopState,
    engine: &StepEngine<'_>,
    shared: &Shared,
    started: Instant,
) -> ServeMetrics {
    let mut m = ServeMetrics { requests: state.requests, ..Default::default() };
    engine.fold_metrics(&mut m);
    merge_counters(&mut m, &state.carried);
    m.restarts = state.restarts as u64;
    m.cancelled += state.queue_cancelled;
    m.wall_secs = started.elapsed().as_secs_f64();
    m.tokens_per_sec = m.generated_tokens as f64 / m.wall_secs.max(1e-9);
    m.queue_depth = shared.depth.load(AOrd::Acquire) as u64;
    m.max_queue_depth = shared.max_depth.load(AOrd::Relaxed);
    m.rejected = shared.rejected.load(AOrd::Relaxed);
    m.deadline_misses = state.misses;
    m.shed = shared.shed.load(AOrd::Relaxed);
    m.brownout_state = state.brownout.state().gauge();
    m.brownout_transitions = state.brownout.transitions();
    m.brownout_degraded_secs = state.brownout.degraded_secs();
    m.brownout_shedding_secs = state.brownout.shedding_secs();
    // percentiles over the bounded recent-completion window (exact
    // full-history until METRIC_WINDOW requests have completed)
    let mut lat = state.lat.clone();
    let mut ttft = state.ttft.clone();
    crate::util::sort_for_percentiles(&mut lat);
    crate::util::sort_for_percentiles(&mut ttft);
    m.p50_latency_ms = crate::util::percentile(&lat, 0.50);
    m.p99_latency_ms = crate::util::percentile(&lat, 0.99);
    m.p50_ttft_ms = crate::util::percentile(&ttft, 0.50);
    m.p99_ttft_ms = crate::util::percentile(&ttft, 0.99);
    m
}

fn handle_msg(
    msg: Msg,
    state: &mut LoopState,
    engine: &StepEngine<'_>,
    decoder: &Decoder<'_>,
    registry: &Mutex<AdapterRegistry>,
    shared: &Shared,
    started: Instant,
    final_reply: &mut Option<Sender<ServeMetrics>>,
) {
    match msg {
        Msg::Request(q) => {
            state.requests += 1;
            state.pending.push(Reverse(q));
        }
        Msg::Metrics(tx) => {
            let _ = tx.send(snapshot(state, engine, shared, started));
        }
        Msg::RegisterAdapter { id, rank_mask, reply } => {
            let r = decoder
                .adapter_binding(&rank_mask)
                .and_then(|b| lock_registry(registry).register(&id, b))
                .map_err(|e| format!("{e:#}"));
            let _ = reply.send(r);
        }
        Msg::Pause => state.paused = true,
        Msg::Resume => state.paused = false,
        Msg::Shutdown(reply) => {
            state.open = false;
            state.paused = false; // a paused drain would never finish
            shared.accepting.store(false, AOrd::Release);
            if reply.is_some() {
                *final_reply = reply;
            }
        }
    }
}

fn server_main(
    rx: Receiver<Msg>,
    opts: ServerOpts,
    stores: Vec<ParamStore>,
    rank_mask: Option<HostTensor>,
    shared: Arc<Shared>,
    registry: Arc<Mutex<AdapterRegistry>>,
    ready: Sender<std::result::Result<(), String>>,
) {
    // startup: any failure reports through the readiness handshake so
    // spawn() errors instead of leaving submitters to hang
    macro_rules! try_start {
        ($e:expr) => {
            match $e {
                Ok(v) => v,
                Err(e) => {
                    shared.accepting.store(false, AOrd::Release);
                    let _ = ready.send(Err(format!("{:#}", e)));
                    return;
                }
            }
        };
    }
    let rt = try_start!(Runtime::from_flag(&opts.backend, &opts.artifacts_dir));
    let manifest = try_start!(rt.manifest());
    let mut cfg = try_start!(manifest.config(&opts.config)).clone();
    if opts.slots > 0 {
        cfg.batch_eval = opts.slots;
    }
    let store_refs: Vec<&ParamStore> = stores.iter().collect();
    let decoder = try_start!(Decoder::new(&rt, &cfg, &opts.entry, store_refs, rank_mask));
    if !decoder.supports_decode() {
        shared.accepting.store(false, AOrd::Release);
        let _ = ready.send(Err(format!(
            "entry '{}' has no incremental decode path on backend '{}' — the async server \
             schedules admit/step waves; serve this entry through Decoder::serve instead",
            opts.entry,
            rt.backend_name()
        )));
        return;
    }
    let mut engine = try_start!(decoder.step_engine());
    // fault plan: the API plan wins; `SHEARS_FAULT` drills arm only
    // when it is empty. A typoed spec fails spawn loudly instead of
    // silently running fault-free.
    if !opts.fault.is_empty() {
        engine.set_fault_plan(opts.fault.clone());
    } else if let Some(plan) = try_start!(FaultPlan::from_env()) {
        engine.set_fault_plan(plan);
    }
    shared.window.store(engine.window(), AOrd::Release);
    let _ = ready.send(Ok(()));

    let started = Instant::now();
    let mut state = LoopState {
        pending: BinaryHeap::new(),
        paused: false,
        open: true,
        requests: 0,
        completed: 0,
        misses: 0,
        lat: Vec::new(),
        ttft: Vec::new(),
        restarts: 0,
        queue_cancelled: 0,
        carried: ServeMetrics::default(),
        brownout: BrownoutController::new(opts.brownout.clone()),
    };
    let mut streams: HashMap<u64, Arc<StreamShared>> = HashMap::new();
    let mut retired: Vec<(u64, GenResponse)> = Vec::with_capacity(engine.slots());
    let mut reap: Vec<(u64, FaultKind)> = Vec::with_capacity(engine.slots());
    let mut final_reply: Option<Sender<ServeMetrics>> = None;

    loop {
        // ---- 1. ingest: block only when there is nothing to decode
        // and nothing admissible; otherwise drain without waiting so
        // queue polls interleave between decode steps
        if state.open {
            let idle = engine.active_slots() == 0 && (state.pending.is_empty() || state.paused);
            if idle {
                match rx.recv() {
                    Ok(m) => handle_msg(
                        m,
                        &mut state,
                        &engine,
                        &decoder,
                        &registry,
                        &shared,
                        started,
                        &mut final_reply,
                    ),
                    Err(_) => {
                        state.open = false;
                        state.paused = false;
                    }
                }
            }
            loop {
                match rx.try_recv() {
                    Ok(m) => handle_msg(
                        m,
                        &mut state,
                        &engine,
                        &decoder,
                        &registry,
                        &shared,
                        started,
                        &mut final_reply,
                    ),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        state.open = false;
                        state.paused = false;
                        break;
                    }
                }
            }
        }
        if !state.open && state.pending.is_empty() && engine.active_slots() == 0 {
            break;
        }
        let mut budget_exhausted = false;

        // ---- 2. reap: hard wall-clock budgets (and deadlines when
        // enforced), explicit cancels, abandoned handles — freed slots
        // refill in this same iteration's admission
        if engine.active_slots() > 0 {
            engine.cancel_expired(Instant::now(), opts.enforce_deadlines, &mut retired);
            reap.clear();
            for (&id, s) in streams.iter() {
                if s.cancel.load(AOrd::Acquire) {
                    reap.push((id, FaultKind::Cancelled));
                } else if Arc::strong_count(s) == 1 {
                    // the map holds the last Arc: the caller dropped its
                    // handle — stop decoding for nobody
                    reap.push((id, FaultKind::Abandoned));
                }
            }
            for &(id, kind) in reap.iter() {
                let detail = match kind {
                    FaultKind::Cancelled => "cancelled by caller",
                    _ => "stream handle dropped before completion",
                };
                if let Some(resp) = engine.abort(id, kind, detail) {
                    retired.push((id, resp));
                }
            }
            deliver(&mut retired, &mut state, &mut streams);
        }

        // ---- 3. admission: free KV slots fill earliest-deadline-first
        if !state.paused {
            while engine.has_free_slot() {
                let Some(Reverse(q)) = state.pending.pop() else { break };
                shared.depth.fetch_sub(1, AOrd::AcqRel);
                let Queued { req, id, submitted, deadline, stream, mut adapter } = q;
                let now = Instant::now();
                let wall_deadline = req.max_wall.and_then(|d| submitted.checked_add(d));
                // queue-side preemption: don't spend a prefill on a
                // request already cancelled, abandoned, or out of
                // wall-clock budget
                let shed = if stream.cancel.load(AOrd::Acquire) {
                    Some((FaultKind::Cancelled, "cancelled by caller while queued"))
                } else if Arc::strong_count(&stream) == 1 {
                    Some((FaultKind::Abandoned, "stream handle dropped while queued"))
                } else if wall_deadline.is_some_and(|d| now > d) {
                    Some((FaultKind::WallClockExceeded, "max_wall exceeded while queued"))
                } else if opts.enforce_deadlines && deadline.is_some_and(|d| now > d) {
                    Some((FaultKind::DeadlineExceeded, "deadline exceeded while queued"))
                } else {
                    None
                };
                if let Some((kind, detail)) = shed {
                    state.queue_cancelled += 1;
                    let f = ServeFault { request: id, slot: None, kind, detail: detail.into() };
                    stream.finish(Err(f.to_string()));
                    continue;
                }
                // brownout degradation: past `Normal`, an opted-in
                // admission swaps its resolved binding for the cached
                // prefix sub-binding of the same parent (warm lookups
                // are a map hit + Arc clone — allocation-free). Only a
                // genuinely cheaper sub-binding counts as degraded.
                let mut degraded = None;
                if state.brownout.degrading()
                    && req.allow_degraded.unwrap_or(state.brownout.default_allow_degraded())
                {
                    let parent =
                        adapter.clone().or_else(|| engine.default_adapter().cloned());
                    if let Some(parent) = &parent {
                        let sub =
                            lock_registry(&registry).prefix_of(parent, state.brownout.fraction());
                        if sub.active_rank() < parent.active_rank() {
                            degraded = Some(sub.rank_fraction());
                            adapter = Some(sub);
                        }
                    }
                }
                let adm = Admission {
                    id,
                    prompt: &req.prompt,
                    max_new: req.max_new_tokens,
                    submitted,
                    deadline,
                    wall_deadline,
                    adapter,
                    degraded,
                };
                let mut on_token = |_id: u64, t: i32| stream.push_token(t);
                match supervised(|| engine.admit(adm, &mut on_token)) {
                    Ok(Ok(Some(resp))) => match &resp.fault {
                        None => {
                            record_done(&mut state, &resp);
                            stream.finish(Ok(resp));
                        }
                        Some(f) => stream.finish(Err(f.to_string())),
                    },
                    Ok(Ok(None)) => {
                        streams.insert(id, stream);
                    }
                    Ok(Err(e)) => stream.finish(Err(format!("request {id}: {e:#}"))),
                    Err(panic_msg) => {
                        let f = ServeFault {
                            request: id,
                            slot: None,
                            kind: FaultKind::StepPanic,
                            detail: format!("engine panicked during admit: {panic_msg}"),
                        };
                        stream.finish(Err(f.to_string()));
                        let detail = format!("engine panicked: {panic_msg}");
                        budget_exhausted = !supervise_restart(
                            &mut engine,
                            &decoder,
                            &detail,
                            &opts,
                            &mut state,
                            &mut streams,
                            &mut retired,
                        );
                        break;
                    }
                }
            }
        }

        // ---- 4. one batched decode step over the active slots
        if !budget_exhausted && engine.active_slots() > 0 {
            // the step clock feeds the controller's EWMA; the timing
            // calls are skipped entirely with brownout off, so the
            // controller-off hot path is untouched
            let step_started = state.brownout.enabled().then(Instant::now);
            let step_res = supervised(|| {
                let mut on_token = |id: u64, t: i32| {
                    if let Some(s) = streams.get(&id) {
                        s.push_token(t);
                    }
                };
                engine.step(&mut on_token, &mut retired)
            });
            match step_res {
                Ok(Ok(())) => {
                    if let Some(t0) = step_started {
                        state.brownout.observe_step(t0.elapsed());
                    }
                    deliver(&mut retired, &mut state, &mut streams)
                }
                Ok(Err(e)) => {
                    // step() quarantine-recovers per-slot failures
                    // internally, so an error escaping it is
                    // engine-wide — restart, same as a panic
                    let detail = format!("engine step failed: {e:#}");
                    deliver(&mut retired, &mut state, &mut streams);
                    budget_exhausted = !supervise_restart(
                        &mut engine,
                        &decoder,
                        &detail,
                        &opts,
                        &mut state,
                        &mut streams,
                        &mut retired,
                    );
                }
                Err(panic_msg) => {
                    // rows that retired cleanly before the panic still
                    // deliver — their responses are complete
                    deliver(&mut retired, &mut state, &mut streams);
                    let detail = format!("engine panicked: {panic_msg}");
                    budget_exhausted = !supervise_restart(
                        &mut engine,
                        &decoder,
                        &detail,
                        &opts,
                        &mut state,
                        &mut streams,
                        &mut retired,
                    );
                }
            }
        }

        // ---- 5. brownout: one controller evaluation per loop
        // iteration, verdicts published into the submit-side atomics.
        // In `Normal` this is observe-only — admission, scheduling,
        // and tokens are bit-identical to a controller-off run.
        if state.brownout.enabled() {
            let queue_depth = shared.depth.load(AOrd::Acquire);
            let st = state.brownout.evaluate(Instant::now(), queue_depth);
            shared.brownout_state.store(st.gauge(), AOrd::Release);
            let admissible = state.brownout.admissible_depth(shared.queue_cap);
            shared.admissible.store(admissible, AOrd::Release);
        }

        if budget_exhausted {
            // restart budget exhausted (or the rebuild failed): stop
            // accepting, shed the queue as rejected, exit cleanly —
            // every accepted request resolves, no handle hangs
            shared.accepting.store(false, AOrd::Release);
            state.open = false;
            while let Some(Reverse(q)) = state.pending.pop() {
                shared.depth.fetch_sub(1, AOrd::AcqRel);
                shared.rejected.fetch_add(1, AOrd::Relaxed);
                let f = ServeFault {
                    request: q.id,
                    slot: None,
                    kind: FaultKind::Shutdown,
                    detail: "restart budget exhausted".into(),
                };
                q.stream.finish(Err(f.to_string()));
            }
            break;
        }
    }

    // drained: publish `closed` BEFORE the final sweep. Any send that
    // completed while `closed` still read false is visible to the
    // try_recv loop below; a send that observes `closed == true` fails
    // its own stream (see submit) — between the two, no accepted
    // request can be left hanging.
    shared.closed.store(true, AOrd::SeqCst);
    let final_m = snapshot(&state, &engine, &shared, started);
    *shared.final_metrics.lock().unwrap_or_else(|e| e.into_inner()) = Some(final_m.clone());
    while let Ok(m) = rx.try_recv() {
        match m {
            Msg::Request(q) => {
                shared.depth.fetch_sub(1, AOrd::AcqRel);
                q.stream.finish(Err("server shutting down".into()));
            }
            Msg::Metrics(tx) => {
                let _ = tx.send(final_m.clone());
            }
            Msg::Shutdown(Some(tx)) => {
                let _ = tx.send(final_m.clone());
            }
            Msg::RegisterAdapter { reply, .. } => {
                let _ = reply.send(Err("server shutting down".into()));
            }
            _ => {}
        }
    }
    if let Some(tx) = final_reply {
        let _ = tx.send(final_m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn queued(id: u64, deadline_ms: Option<u64>, priority: i32, base: Instant) -> Queued {
        Queued {
            req: GenRequest::new(vec![1], 1).with_priority(priority),
            id,
            submitted: base,
            deadline: deadline_ms.map(|ms| base + Duration::from_millis(ms)),
            stream: Arc::new(StreamShared::new(2)),
            adapter: None,
        }
    }

    #[test]
    fn pending_queue_pops_edf_then_priority_then_fifo() {
        let base = Instant::now();
        let mut heap: BinaryHeap<Reverse<Queued>> = BinaryHeap::new();
        // submitted out of order: best-effort first, then deadlines
        heap.push(Reverse(queued(0, None, 0, base))); // best effort, FIFO-early
        heap.push(Reverse(queued(1, Some(500), 0, base))); // late deadline
        heap.push(Reverse(queued(2, Some(100), 0, base))); // early deadline
        heap.push(Reverse(queued(3, None, 5, base))); // best effort, high prio
        heap.push(Reverse(queued(4, Some(100), 3, base))); // same deadline, higher prio
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|Reverse(q)| q.id)).collect();
        // earliest deadline first; equal deadlines by priority; the
        // no-deadline class last, priority then FIFO
        assert_eq!(order, vec![4, 2, 1, 3, 0]);
    }

    #[test]
    fn fifo_breaks_full_ties() {
        let base = Instant::now();
        let mut heap: BinaryHeap<Reverse<Queued>> = BinaryHeap::new();
        let d = Some(250);
        heap.push(Reverse(queued(7, d, 1, base)));
        heap.push(Reverse(queued(3, d, 1, base)));
        heap.push(Reverse(queued(5, d, 1, base)));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|Reverse(q)| q.id)).collect();
        assert_eq!(order, vec![3, 5, 7], "equal deadline+priority is FIFO");
    }

    #[test]
    fn stream_handle_reads_tokens_then_completion() {
        let shared = Arc::new(StreamShared::new(4));
        shared.push_token(11);
        shared.push_token(12);
        let mut h = StreamHandle { shared: shared.clone(), read: 0, id: 0 };
        assert_eq!(h.try_next_token(), Some(11));
        assert_eq!(h.next_token(), Some(12));
        assert_eq!(h.try_next_token(), None, "nothing new yet");
        shared.finish(Ok(GenResponse {
            tokens: vec![1, 11, 12],
            new_tokens: 2,
            latency_ms: 1.0,
            ttft_ms: 0.5,
            deadline_missed: false,
            admission_seq: 0,
            prompt_truncated: false,
            degraded: false,
            rank_fraction: 1.0,
            fault: None,
        }));
        assert_eq!(h.next_token(), None, "done and fully consumed");
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens, vec![1, 11, 12]);
    }

    #[test]
    fn wait_timeout_returns_none_until_finished() {
        let shared = Arc::new(StreamShared::new(2));
        let mut h = StreamHandle { shared: shared.clone(), read: 0, id: 3 };
        assert!(
            h.wait_timeout(Duration::from_millis(5)).is_none(),
            "unfinished stream times out with None, not a hang"
        );
        shared.finish(Err("wedged".into()));
        let r = h.wait_timeout(Duration::from_millis(5)).expect("finished now");
        let s = format!("{:#}", r.unwrap_err());
        assert!(s.contains("request 3"), "wait_timeout keeps attribution: {s}");
        // completion latched: a second bounded wait returns immediately
        assert!(h.wait_timeout(Duration::from_millis(0)).is_some());
    }

    #[test]
    fn stream_error_surfaces_from_wait() {
        let shared = Arc::new(StreamShared::new(1));
        shared.finish(Err("backend exploded".into()));
        let h = StreamHandle { shared, read: 0, id: 9 };
        let e = h.wait().unwrap_err();
        let s = format!("{e:#}");
        assert!(s.contains("backend exploded"));
        assert!(s.contains("request 9"), "bare errors gain attribution: {s}");
    }

    #[test]
    fn wait_does_not_stutter_fault_attribution() {
        let f = ServeFault {
            request: 5,
            slot: Some(1),
            kind: FaultKind::StepPanic,
            detail: "injected".into(),
        };
        let shared = Arc::new(StreamShared::new(1));
        shared.finish(Err(f.to_string()));
        let h = StreamHandle { shared, read: 0, id: 5 };
        let s = format!("{:#}", h.wait().unwrap_err());
        assert!(s.contains("request 5 (slot 1)"), "{s}");
        assert!(!s.contains("request 5: request 5"), "double prefix: {s}");
    }

    #[test]
    fn cancel_flag_reaches_the_shared_cell() {
        let shared = Arc::new(StreamShared::new(1));
        let h = StreamHandle { shared: shared.clone(), read: 0, id: 0 };
        assert!(!shared.cancel.load(AOrd::Acquire));
        h.cancel();
        h.cancel(); // idempotent
        assert!(shared.cancel.load(AOrd::Acquire));
        // completion can still race in; first finish wins either way
        shared.finish(Err("cancelled".into()));
        assert!(h.wait().is_err());
    }

    #[test]
    fn supervised_catches_and_stringifies_panics() {
        assert_eq!(supervised(|| 7).unwrap(), 7);
        let e = supervised(|| panic!("boom {}", 3)).unwrap_err();
        assert!(e.contains("boom 3"), "{e}");
        let e = supervised(|| std::panic::panic_any(42u32)).unwrap_err();
        assert!(e.contains("non-string"), "{e}");
        // the hook restores non-supervised behavior afterwards
        assert!(!SUPERVISED.with(|s| s.get()));
    }

    #[test]
    fn merge_counters_sums_and_weights_occupancy() {
        let mut a = ServeMetrics {
            decode_steps: 10,
            mean_batch_occupancy: 2.0,
            prefills: 3,
            faults: 1,
            ..Default::default()
        };
        let b = ServeMetrics {
            decode_steps: 30,
            mean_batch_occupancy: 4.0,
            prefills: 5,
            cancelled: 2,
            quarantined: 7,
            degraded: 4,
            ..Default::default()
        };
        merge_counters(&mut a, &b);
        assert_eq!(a.decode_steps, 40);
        assert_eq!(a.prefills, 8);
        assert_eq!(a.faults, 1);
        assert_eq!(a.cancelled, 2);
        assert_eq!(a.quarantined, 7);
        assert_eq!(a.degraded, 4);
        assert!((a.mean_batch_occupancy - 3.5).abs() < 1e-12, "10×2 + 30×4 over 40");
    }
}
