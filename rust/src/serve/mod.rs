//! Batched greedy-decoding service over the sparse + adapted model.
//!
//! Demonstrates the paper's §4.4 deployment claim — the Shears model
//! serves inference with adapters *unmerged* (merging would destroy the
//! base-weight sparsity) — as a continuous-batching decoder. On the
//! native backend generation is **KV-cached incremental decoding**
//! ([`Decoder::serve_incremental`]): each admitted request is prefilled
//! once into its slot's cache column, then every wave step advances all
//! active sequences by one token through batched `M = active` prepared
//! matmuls — O(1) transformer work per token instead of the O(seq_len)
//! full re-forward the wave decoder pays. The re-forward path
//! ([`Decoder::serve_reforward`]) remains as the PJRT fallback and the
//! parity baseline: greedy token sequences are identical between the
//! two (`rust/tests/decode.rs`).
//!
//! Latency/throughput metrics come out per run (examples/serve_demo.rs,
//! `perf_runtime`'s `serve` section).

use crate::data::Vocab;
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::{DecodeSession, DecodeState, Runtime};
use crate::tensor::HostTensor;
use crate::train::ForwardSession;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    /// Budget for generated tokens. The decoder always produces at
    /// least one token per request (the retire check runs after the
    /// first greedy pick, as the wave decoder always did), so a budget
    /// of 0 behaves like 1.
    pub max_new_tokens: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    pub new_tokens: usize,
    pub latency_ms: f64,
    /// The prompt exceeded the context window and was cut to `seq_len−1`
    /// tokens before decoding (no silent truncation).
    pub prompt_truncated: bool,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub generated_tokens: u64,
    /// model executions of any kind (prefills + decode steps, or wave
    /// re-forwards on the fallback path)
    pub forwards: u64,
    /// prompt prefills (incremental path only)
    pub prefills: u64,
    /// batched one-token steps (incremental path only)
    pub decode_steps: u64,
    pub truncated_prompts: u64,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// mean active slots per batched step (decode steps on the
    /// incremental path, wave forwards on the re-forward path)
    pub mean_batch_occupancy: f64,
}

/// Greedy pick over one logits row. Ties resolve to the **highest**
/// index (`max_by` keeps the last maximum) — one shared helper so both
/// decoding paths agree even on degenerate rows.
fn argmax(row: &[f32], fallback: i32) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, c| a.1.partial_cmp(c.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(idx, _)| idx as i32)
        .unwrap_or(fallback)
}

/// Clamp a prompt to the decode window: at most `s − 1` tokens are
/// admitted so at least one generated position fits. Empty prompts are
/// seeded with `pad` (the model needs one position to predict from).
/// Returns the admitted tokens and whether the prompt was cut.
fn admit_prompt(prompt: &[i32], s: usize, pad: i32) -> (Vec<i32>, bool) {
    let truncated = prompt.len() > s - 1;
    let mut toks = prompt[..prompt.len().min(s - 1)].to_vec();
    if toks.is_empty() {
        toks.push(pad);
    }
    (toks, truncated)
}

/// Retirement rule shared by both decoding paths: EOS, the request's
/// new-token budget, or a full context window.
fn finished(next: i32, eos: i32, new_count: usize, max_new: usize, len: usize, s: usize) -> bool {
    next == eos || new_count >= max_new || len >= s
}

/// One in-flight request occupying a batch slot.
struct Slot {
    req: usize,
    toks: Vec<i32>,
    /// prompt tokens actually admitted (new-token accounting base)
    admitted: usize,
    truncated: bool,
    started: Instant,
}

/// Greedy batched decoder over a forward entry point. The parameter
/// stores are uploaded once at construction (prepared sparse weights
/// cached), so generation runs the resident fast path — incrementally
/// KV-cached on the native backend, wave re-forward otherwise.
pub struct Decoder<'rt> {
    cfg: &'rt ModelConfig,
    session: ForwardSession<'rt>,
    rank_mask: Option<HostTensor>,
    pub vocab: Vocab,
    /// K/V caches reused across [`Decoder::serve_incremental`] calls
    /// (every admission prefill resets its slot, so stale contents are
    /// never read) — spares the per-call cache allocation + zero-fill.
    state: RefCell<Option<DecodeState>>,
}

impl<'rt> Decoder<'rt> {
    /// `stores` are uploaded here, at construction; the decoder serves
    /// from its resident copies. If a store changes afterwards (prune,
    /// fine-tune step), call [`Decoder::sync`] to re-upload the changed
    /// weights before serving again.
    pub fn new(
        rt: &'rt Runtime,
        cfg: &'rt ModelConfig,
        entry_name: &str,
        stores: Vec<&'rt ParamStore>,
        rank_mask: Option<HostTensor>,
    ) -> Result<Self> {
        let session = ForwardSession::new(rt, cfg, entry_name, &stores)?;
        Ok(Decoder {
            cfg,
            session,
            rank_mask,
            vocab: Vocab::new(cfg.vocab),
            state: RefCell::new(None),
        })
    }

    /// Re-upload weights whose store generation changed since
    /// construction (cheap no-op otherwise). Decode bindings are built
    /// per [`Decoder::serve`] call, so they are never stale.
    pub fn sync(&mut self, stores: &[&ParamStore]) -> Result<()> {
        self.session.sync(stores)
    }

    /// Serve a queue of requests with continuous batching, picking the
    /// fastest decoding path this backend **and entry** support.
    /// Entries the decode engine cannot bind (PJRT, the prefix/series/
    /// parallel baseline forwards) keep the wave re-forward path that
    /// always served them; a bind failure on a decodable entry is a
    /// real error and propagates instead of silently degrading.
    pub fn serve(&self, requests: &[GenRequest]) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        if self.session.supports_decode() {
            self.serve_incremental(requests)
        } else {
            self.serve_reforward(requests)
        }
    }

    /// KV-cached continuous batching (native backend): admission
    /// prefills exactly the joining slot's cache column, every wave
    /// step is one batched `decode_step` over the active slots.
    pub fn serve_incremental(
        &self,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let session = self.session.decoder(self.rank_mask.as_ref())?;
        self.serve_with(session, requests)
    }

    /// Incremental decoding over an already-bound decode session.
    fn serve_with(
        &self,
        session: DecodeSession<'_>,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let b = self.cfg.batch_eval;
        let s = self.cfg.seq_len;
        let v = self.cfg.vocab;
        let eos = self.vocab.eos;
        let start_all = Instant::now();
        // reuse the cached K/V planes when present (prefill resets each
        // joining slot, so a previous queue's contents are never read)
        let mut st = self
            .state
            .borrow_mut()
            .take()
            .filter(|st| st.n_slots() == b)
            .unwrap_or_else(|| self.session.decode_state(b));
        let mut metrics = ServeMetrics { requests: requests.len() as u64, ..Default::default() };
        let mut responses: Vec<Option<GenResponse>> = (0..requests.len()).map(|_| None).collect();
        let mut latencies: Vec<f64> = Vec::new();
        let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
        let mut next_req = 0usize;
        let mut occupancy_sum = 0usize;
        // reused step buffers: warm steps allocate nothing below this fn
        let mut row_logits = vec![0.0f32; v];
        let mut step_logits = vec![0.0f32; b * v];
        let mut active: Vec<usize> = Vec::with_capacity(b);
        let mut step_tokens: Vec<i32> = Vec::with_capacity(b);

        loop {
            // admission: each free slot prefills one pending request
            // (resetting only that slot's cache column)
            for slot in 0..b {
                if slots[slot].is_some() || next_req >= requests.len() {
                    continue;
                }
                let req = next_req;
                next_req += 1;
                let r = &requests[req];
                let started = Instant::now();
                let (mut toks, truncated) = admit_prompt(&r.prompt, s, self.vocab.pad);
                let admitted = toks.len();
                if truncated {
                    metrics.truncated_prompts += 1;
                }
                session.prefill(&mut st, slot, &toks, &mut row_logits)?;
                metrics.prefills += 1;
                metrics.forwards += 1;
                let next = argmax(&row_logits, eos);
                toks.push(next);
                metrics.generated_tokens += 1;
                let new_count = toks.len() - admitted;
                if finished(next, eos, new_count, r.max_new_tokens, toks.len(), s) {
                    let lat = started.elapsed().as_secs_f64() * 1e3;
                    latencies.push(lat);
                    responses[req] = Some(GenResponse {
                        tokens: toks,
                        new_tokens: new_count,
                        latency_ms: lat,
                        prompt_truncated: truncated,
                    });
                } else {
                    slots[slot] = Some(Slot { req, toks, admitted, truncated, started });
                }
            }
            active.clear();
            step_tokens.clear();
            for (slot, state) in slots.iter().enumerate() {
                if let Some(sl) = state {
                    active.push(slot);
                    step_tokens.push(*sl.toks.last().expect("active slot has tokens"));
                }
            }
            if active.is_empty() {
                if next_req >= requests.len() {
                    break;
                }
                continue; // everything admitted finished at prefill; admit more
            }
            // one batched step: every active sequence advances a token
            let out = &mut step_logits[..active.len() * v];
            session.decode_step(&mut st, &active, &step_tokens, out)?;
            metrics.decode_steps += 1;
            metrics.forwards += 1;
            occupancy_sum += active.len();
            for (row, &slot) in active.iter().enumerate() {
                let state = slots[slot].as_mut().expect("active slot");
                let next = argmax(&step_logits[row * v..(row + 1) * v], eos);
                state.toks.push(next);
                metrics.generated_tokens += 1;
                let new_count = state.toks.len() - state.admitted;
                let max_new = requests[state.req].max_new_tokens;
                if finished(next, eos, new_count, max_new, state.toks.len(), s) {
                    let state = slots[slot].take().expect("active slot");
                    let lat = state.started.elapsed().as_secs_f64() * 1e3;
                    latencies.push(lat);
                    responses[state.req] = Some(GenResponse {
                        tokens: state.toks,
                        new_tokens: new_count,
                        latency_ms: lat,
                        prompt_truncated: state.truncated,
                    });
                }
            }
        }
        *self.state.borrow_mut() = Some(st);
        finalize(metrics, start_all, occupancy_sum, latencies, responses, true)
    }

    /// Full re-forward wave decoding: every step recomputes the whole
    /// padded `[batch, seq_len]` context. PJRT fallback and the parity
    /// baseline for the incremental path.
    pub fn serve_reforward(
        &self,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let b = self.cfg.batch_eval;
        let s = self.cfg.seq_len;
        let eos = self.vocab.eos;
        let start_all = Instant::now();
        let mut metrics = ServeMetrics { requests: requests.len() as u64, ..Default::default() };
        let mut responses: Vec<Option<GenResponse>> = (0..requests.len()).map(|_| None).collect();
        let mut latencies: Vec<f64> = Vec::new();
        let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
        let mut next_req = 0usize;
        let mut occupancy_sum = 0usize;

        loop {
            // admit new requests into free slots (continuous batching)
            for slot in slots.iter_mut() {
                if slot.is_none() && next_req < requests.len() {
                    let req = next_req;
                    next_req += 1;
                    let (toks, truncated) =
                        admit_prompt(&requests[req].prompt, s, self.vocab.pad);
                    if truncated {
                        metrics.truncated_prompts += 1;
                    }
                    let admitted = toks.len();
                    *slot = Some(Slot { req, toks, admitted, truncated, started: Instant::now() });
                }
            }
            let active: Vec<usize> = (0..b).filter(|i| slots[*i].is_some()).collect();
            if active.is_empty() {
                break;
            }
            occupancy_sum += active.len();

            // build the wave batch: each active slot's context, padded
            let mut x = vec![self.vocab.pad; b * s];
            for &i in &active {
                let state = slots[i].as_ref().unwrap();
                for (t, tok) in state.toks.iter().enumerate() {
                    x[i * s + t] = *tok;
                }
            }
            let xt = HostTensor::from_i32(&[b, s], x);
            let logits = self.session.logits(&xt, self.rank_mask.as_ref())?;
            metrics.forwards += 1;

            // greedy next token per active slot, retire finished
            let v = self.cfg.vocab;
            let data = logits.f32s();
            for &i in &active {
                let state = slots[i].as_mut().unwrap();
                let pos = state.toks.len() - 1;
                let off = (i * s + pos) * v;
                let next = argmax(&data[off..off + v], eos);
                state.toks.push(next);
                metrics.generated_tokens += 1;
                let new_count = state.toks.len() - state.admitted;
                let max_new = requests[state.req].max_new_tokens;
                if finished(next, eos, new_count, max_new, state.toks.len(), s) {
                    let state = slots[i].take().unwrap();
                    let lat = state.started.elapsed().as_secs_f64() * 1e3;
                    latencies.push(lat);
                    responses[state.req] = Some(GenResponse {
                        tokens: state.toks,
                        new_tokens: new_count,
                        latency_ms: lat,
                        prompt_truncated: state.truncated,
                    });
                }
            }
        }
        finalize(metrics, start_all, occupancy_sum, latencies, responses, false)
    }
}

/// Shared metric finalization. Occupancy averages over batched steps:
/// decode steps on the incremental path, wave forwards otherwise.
fn finalize(
    mut metrics: ServeMetrics,
    start_all: Instant,
    occupancy_sum: usize,
    mut latencies: Vec<f64>,
    responses: Vec<Option<GenResponse>>,
    incremental: bool,
) -> Result<(Vec<GenResponse>, ServeMetrics)> {
    metrics.wall_secs = start_all.elapsed().as_secs_f64();
    metrics.tokens_per_sec = metrics.generated_tokens as f64 / metrics.wall_secs.max(1e-9);
    let steps = if incremental { metrics.decode_steps } else { metrics.forwards };
    metrics.mean_batch_occupancy =
        if steps > 0 { occupancy_sum as f64 / steps as f64 } else { 0.0 };
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pct = |p: f64| {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() - 1) as f64 * p) as usize]
        }
    };
    metrics.p50_latency_ms = pct(0.5);
    metrics.p99_latency_ms = pct(0.99);
    let responses = responses
        .into_iter()
        .map(|r| r.context("request never completed"))
        .collect::<Result<Vec<_>>>()?;
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_clamps_to_window_and_flags() {
        let prompt: Vec<i32> = (0..10).collect();
        let (toks, truncated) = admit_prompt(&prompt, 8, 0);
        assert_eq!(toks.len(), 7, "admits at most s-1 tokens");
        assert_eq!(toks, prompt[..7]);
        assert!(truncated);
        let (toks, truncated) = admit_prompt(&prompt[..3], 8, 0);
        assert_eq!(toks, prompt[..3]);
        assert!(!truncated);
        // exactly s-1 fits without truncation
        let (toks, truncated) = admit_prompt(&prompt[..7], 8, 0);
        assert_eq!(toks.len(), 7);
        assert!(!truncated);
    }

    #[test]
    fn empty_prompt_is_seeded_with_pad() {
        let (toks, truncated) = admit_prompt(&[], 8, 5);
        assert_eq!(toks, vec![5]);
        assert!(!truncated);
    }

    #[test]
    fn retirement_rule_covers_eos_budget_and_window() {
        let (eos, s) = (2, 48);
        assert!(finished(eos, eos, 1, 10, 5, s), "eos retires");
        assert!(finished(7, eos, 10, 10, 5, s), "budget retires");
        assert!(finished(7, eos, 1, 10, s, s), "full window retires");
        assert!(!finished(7, eos, 1, 10, 5, s), "otherwise keep going");
    }

    #[test]
    fn argmax_breaks_ties_toward_highest_index() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0], -1), 2);
        assert_eq!(argmax(&[], 9), 9, "empty row falls back");
        // a prompt filling the window still yields >= 1 generated token
        let (toks, truncated) = admit_prompt(&(0..100).collect::<Vec<i32>>(), 48, 0);
        assert!(truncated);
        assert_eq!(toks.len(), 47);
        // the decoder appends one token before any retirement check, so
        // new_count >= 1 even for truncated prompts
        assert!(!finished(7, 2, 0, 4, toks.len(), 48));
    }
}
