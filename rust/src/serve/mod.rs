//! Batched greedy-decoding service over the sparse + adapted model.
//!
//! Demonstrates the paper's §4.4 deployment claim — the Shears model
//! serves inference with adapters *unmerged* (merging would destroy the
//! base-weight sparsity) — as a continuous-batching decoder. On the
//! native backend generation is **KV-cached incremental decoding**: each
//! admitted request is prefilled once into its slot's cache column, then
//! every wave step advances all active sequences by one token through
//! batched `M = active` prepared matmuls — O(1) transformer work per
//! token instead of the O(seq_len) full re-forward the wave decoder
//! pays. The re-forward path ([`Decoder::serve_reforward`]) remains as
//! the PJRT fallback and the parity baseline: greedy token sequences are
//! identical between the two (`rust/tests/decode.rs`).
//!
//! Two frontends share the decode machinery:
//!
//! * [`Decoder::serve`] — the synchronous batch API: a fixed request
//!   slice, FIFO admission, blocks until the queue drains.
//! * [`server::ServeServer`] — the asynchronous frontend: any thread
//!   submits [`GenRequest`]s (optionally carrying a deadline and a
//!   priority) over a channel and gets a streaming handle back, while a
//!   dedicated runtime thread owns the decoder and fills free KV slots
//!   from a deadline-ordered pending queue (EDF with FIFO tie-break).
//!
//! Both are built on [`StepEngine`], the resumable admit/step/retire
//! core: one decode binding held across the loop, one batched decode
//! step per call, so the server can interleave queue polls between
//! steps without re-binding or re-prefilling anything.
//!
//! Serving is **multi-tenant**: adapter identity lives on the slot,
//! not the decoder. Requests may name a registered tenant adapter
//! ([`GenRequest::adapter`], resolved through [`AdapterRegistry`]),
//! and one batched decode step applies each active slot's own LoRA
//! windows + rank-mask over the shared frozen sparse base — greedy
//! outputs are bit-identical to running each tenant in an isolated
//! decoder (`rust/tests/multi_tenant.rs`).
//!
//! Latency metrics clock from **submission** (the `serve()` call on the
//! batch path, `submit()` on the async path), so queue wait is visible
//! in p50/p99 and in the time-to-first-token percentiles.
//!
//! Serving is **fault-tolerant**: a non-finite logits row or a failed
//! decode step quarantines *that slot only* (the request retires with
//! an attributable [`ServeFault`] instead of failing the batch, and
//! surviving slots rebuild their suspect KV columns via re-prefill —
//! bit-identical to the uninterrupted run by the decode≡prefill parity
//! `rust/tests/decode.rs` pins). Panics are caught by the async
//! server's supervisor, which rebuilds the engine from the resident
//! base weights under a bounded restart budget. The [`fault`] module's
//! deterministic injection harness (`SHEARS_FAULT`) pins every one of
//! these paths in `rust/tests/serve_faults.rs`.
//!
//! Serving is **overload-adaptive**: the async server's [`brownout`]
//! controller watches EWMA step latency, queue depth, and the
//! deadline-miss rate, and under pressure binds opted-in admissions to
//! a cheaper *prefix sub-adapter* ([`AdapterBinding::prefix`] — the
//! NLS search space is prefix-nested, so rank truncation is itself a
//! legitimate sub-adapter) before it ever sheds work; past the
//! admissible horizon it rejects explicitly
//! ([`RejectReason::Overloaded`]), never silently
//! (`rust/tests/serve_overload.rs`).

pub mod brownout;
pub mod registry;
pub mod server;

/// Fault injection grew beyond serving (eval/train injectors live on
/// the same plan) and moved to the crate root; re-exported so
/// `serve::fault::…` paths keep working.
pub use crate::fault;

pub use brownout::{BrownoutController, BrownoutOpts, BrownoutState, BrownoutThresholds};
pub use crate::fault::{FaultKind, FaultPlan, ServeFault};
pub use registry::{binding_from_store, AdapterId, AdapterRegistry};
pub use server::{RejectReason, ServeServer, ServerOpts, StreamHandle, Submit, SubmitHandle};

use crate::data::Vocab;
use crate::model::{ModelConfig, ParamStore};
use crate::ops::model::{logits_row_finite, AdapterBinding};
use crate::runtime::{DecodeSession, DecodeState, Runtime};
use crate::tensor::HostTensor;
use crate::train::ForwardSession;
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    /// Budget for generated tokens. The decoder always produces at
    /// least one token per request (the retire check runs after the
    /// first greedy pick, as the wave decoder always did), so a budget
    /// of 0 behaves like 1.
    pub max_new_tokens: usize,
    /// Completion budget relative to submission (`submit()` on the
    /// async server, the `serve()` call on the batch path). The async
    /// server admits pending requests earliest-deadline-first; a
    /// request finishing after its deadline is flagged on its response
    /// and counted in [`ServeMetrics::deadline_misses`]. `None` = best
    /// effort, admitted after every deadlined request.
    pub deadline: Option<Duration>,
    /// Orders the queue among equal deadlines (and within the
    /// no-deadline class): higher admits first, FIFO breaks the rest.
    pub priority: i32,
    /// Tenant adapter this request decodes under. `None` = the server
    /// default (the registry's pinned default, else the decoder's
    /// construction-time binding). A named adapter must be registered
    /// — unknown ids are rejected at submit/admit time
    /// ([`RejectReason::UnknownAdapter`] on the async path).
    pub adapter: Option<AdapterId>,
    /// Hard wall-clock budget from submission. Unlike `deadline` — a
    /// scheduling hint that is only *counted* when missed — this is
    /// always **enforced**: a request still queued or decoding past it
    /// is actively cancelled (fault kind `wall-clock-exceeded`),
    /// freeing its KV slot for the next request. `None` = unbounded.
    pub max_wall: Option<Duration>,
    /// Whether this request may be served a cheaper **prefix
    /// sub-adapter** while the server is browning out (see
    /// [`brownout::BrownoutOpts`]): under `Degraded`/`Shedding` an
    /// opted-in admission is bound to
    /// `AdapterBinding::prefix(fraction)` instead of risking its
    /// deadline. The response reports what was served
    /// ([`GenResponse::degraded`] + [`GenResponse::rank_fraction`]).
    /// `None` defers to `ServerOpts::brownout.default_allow_degraded`.
    pub allow_degraded: Option<bool>,
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens,
            deadline: None,
            priority: 0,
            adapter: None,
            max_wall: None,
            allow_degraded: None,
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> GenRequest {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_priority(mut self, priority: i32) -> GenRequest {
        self.priority = priority;
        self
    }

    pub fn with_adapter(mut self, adapter: impl Into<AdapterId>) -> GenRequest {
        self.adapter = Some(adapter.into());
        self
    }

    /// Hard wall-clock cancellation budget, in milliseconds from
    /// submission (see [`GenRequest::max_wall`]).
    pub fn with_max_wall_ms(mut self, ms: u64) -> GenRequest {
        self.max_wall = Some(Duration::from_millis(ms));
        self
    }

    /// Opt in to (or out of) brownout degradation (see
    /// [`GenRequest::allow_degraded`]).
    pub fn with_allow_degraded(mut self, allow: bool) -> GenRequest {
        self.allow_degraded = Some(allow);
        self
    }
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    pub new_tokens: usize,
    /// submission → completion, queue wait included
    pub latency_ms: f64,
    /// submission → first generated token (the prefill's greedy pick)
    pub ttft_ms: f64,
    /// the request had a deadline and completed after it
    pub deadline_missed: bool,
    /// order this request was admitted to a KV slot (0-based); under
    /// the async server this exposes the EDF schedule, on the batch
    /// path it equals the FIFO request order
    pub admission_seq: u64,
    /// The prompt exceeded the context window and was cut to `seq_len−1`
    /// tokens before decoding (no silent truncation).
    pub prompt_truncated: bool,
    /// Served under a brownout **prefix sub-adapter** instead of the
    /// full binding (the request opted in via
    /// [`GenRequest::allow_degraded`] while the controller was past
    /// `Normal`). Never silently: degraded responses always say so.
    pub degraded: bool,
    /// Fraction of the adapter's active rank actually served —
    /// `1.0` for non-degraded responses, the prefix sub-binding's
    /// [`AdapterBinding::rank_fraction`] otherwise.
    pub rank_fraction: f32,
    /// `Some` when the request ended **abnormally** — quarantined by a
    /// fault, cancelled past a deadline/wall budget, or aborted —
    /// with the attribution record (request id, slot, fault kind).
    /// `tokens` still holds everything generated before retirement.
    /// The async server surfaces this as a stream error instead of a
    /// normal completion.
    pub fault: Option<ServeFault>,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub generated_tokens: u64,
    /// model executions of any kind (prefills + decode steps, or wave
    /// re-forwards on the fallback path)
    pub forwards: u64,
    /// prompt prefills (incremental path only)
    pub prefills: u64,
    /// batched one-token steps (incremental path only)
    pub decode_steps: u64,
    pub truncated_prompts: u64,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    /// end-to-end (submission → completion) percentiles, nearest-rank.
    /// Batch path: exact over the served slice; async server: over a
    /// bounded window of the most recent completions (see
    /// `server::METRIC_WINDOW`), so long-lived servers stay O(1).
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// submission → first-token percentiles, nearest-rank (same
    /// windowing as the latency percentiles)
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    /// completed requests that blew their deadline
    pub deadline_misses: u64,
    /// submissions refused at queue capacity (async server only)
    pub rejected: u64,
    /// pending queue length at snapshot time (async server only)
    pub queue_depth: u64,
    /// pending queue high-water mark (async server only)
    pub max_queue_depth: u64,
    /// mean active slots per batched step (decode steps on the
    /// incremental path, wave forwards on the re-forward path)
    pub mean_batch_occupancy: f64,
    /// supervised engine rebuilds after a caught panic (async server)
    pub restarts: u64,
    /// requests retired by an engine fault (panic, unrecovered step
    /// error, non-finite logits) — disjoint from `cancelled`
    pub faults: u64,
    /// requests actively cancelled: caller `cancel()`, abandoned
    /// stream handle, enforced deadline, or `max_wall` budget
    pub cancelled: u64,
    /// suspect KV columns rebuilt via recovery re-prefill after a
    /// failed batched step (the slot survived and kept decoding)
    pub quarantined: u64,
    /// requests admitted under a brownout prefix sub-adapter
    pub degraded: u64,
    /// submissions rejected `Overloaded` by brownout shedding — a
    /// third bucket disjoint from `requests` and `rejected`, so
    /// `requests + rejected + shed` reconciles with submissions
    pub shed: u64,
    /// brownout rung at snapshot: 0 normal, 1 degraded, 2 shedding
    /// (async server only; see [`BrownoutState::gauge`])
    pub brownout_state: u64,
    /// brownout state-machine transitions since spawn
    pub brownout_transitions: u64,
    /// cumulative seconds the controller has spent in `Degraded`
    pub brownout_degraded_secs: f64,
    /// cumulative seconds the controller has spent in `Shedding`
    pub brownout_shedding_secs: f64,
}

/// Greedy pick over one logits row. Ties resolve to the **highest**
/// index — one shared helper so both decoding paths agree even on
/// degenerate rows. NaN entries lose deterministically (a NaN logit
/// must never make the pick depend on scan order); an all-NaN or empty
/// row yields `fallback`.
fn argmax(row: &[f32], fallback: i32) -> i32 {
    let mut best: Option<(usize, f32)> = None;
    for (i, &x) in row.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        // `x >= b` keeps the later index on ties
        let better = match best {
            Some((_, b)) => x >= b,
            None => true,
        };
        if better {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i as i32).unwrap_or(fallback)
}

/// Clamp a prompt to the decode window: at most `s − 1` tokens are
/// admitted so at least one generated position fits. Empty prompts are
/// seeded with `pad` (the model needs one position to predict from).
/// Returns the admitted tokens (with capacity for the full window, so
/// in-flight token pushes never reallocate) and whether the prompt was
/// cut. `s == 0` must never reach here — [`Decoder::new`] and
/// [`ServeServer::spawn`] reject zero-window configs up front — but
/// the arithmetic saturates rather than underflowing `usize` if it
/// does (the old `s - 1` panicked in debug and wrapped in release).
fn admit_prompt(prompt: &[i32], s: usize, pad: i32) -> (Vec<i32>, bool) {
    let keep = s.saturating_sub(1);
    let truncated = prompt.len() > keep;
    let mut toks = Vec::with_capacity(s.max(1));
    toks.extend_from_slice(&prompt[..prompt.len().min(keep)]);
    if toks.is_empty() {
        toks.push(pad);
    }
    (toks, truncated)
}

/// Retirement rule shared by both decoding paths: EOS, the request's
/// new-token budget, or a full context window.
fn finished(next: i32, eos: i32, new_count: usize, max_new: usize, len: usize, s: usize) -> bool {
    next == eos || new_count >= max_new || len >= s
}

/// One in-flight request occupying a batch slot.
struct Slot {
    /// caller-side identity (batch path: index into the request slice;
    /// async server: submission sequence number)
    id: u64,
    toks: Vec<i32>,
    /// prompt tokens actually admitted (new-token accounting base)
    admitted: usize,
    truncated: bool,
    max_new: usize,
    /// when the request entered the system, NOT when it won a slot —
    /// latency and TTFT both clock queue wait
    submitted: Instant,
    deadline: Option<Instant>,
    /// absolute hard-cancellation point (`submitted + max_wall`);
    /// unlike `deadline`, always enforced by [`StepEngine::cancel_expired`]
    wall_deadline: Option<Instant>,
    first_token_at: Option<Instant>,
    admission_seq: u64,
    /// tenant binding this slot decodes under (`None` = bare base);
    /// holding the `Arc` marks the adapter in-flight to the registry
    adapter: Option<Arc<AdapterBinding>>,
    /// `Some(rank_fraction)` when `adapter` is a brownout prefix
    /// sub-binding rather than the request's full resolved binding
    degraded: Option<f32>,
}

/// Build the response for a retiring slot. Latency spans submission →
/// now (queue wait included); TTFT spans submission → first greedy
/// pick. Moves the token buffer — no allocation on the retire path.
fn complete(sl: Slot) -> GenResponse {
    let now = Instant::now();
    let latency_ms = now.duration_since(sl.submitted).as_secs_f64() * 1e3;
    let ttft_ms = sl
        .first_token_at
        .map(|t| t.duration_since(sl.submitted).as_secs_f64() * 1e3)
        .unwrap_or(latency_ms);
    GenResponse {
        new_tokens: sl.toks.len() - sl.admitted,
        latency_ms,
        ttft_ms,
        deadline_missed: sl.deadline.is_some_and(|d| now > d),
        admission_seq: sl.admission_seq,
        prompt_truncated: sl.truncated,
        degraded: sl.degraded.is_some(),
        rank_fraction: sl.degraded.unwrap_or(1.0),
        fault: None,
        tokens: sl.toks,
    }
}

/// Build the fault-tagged response for a slot retiring **abnormally**
/// (quarantine, cancellation, abort): same shape as [`complete`] — the
/// partial token buffer moves out — plus the attribution record the
/// async server formats into the stream error.
fn fault_complete(sl: Slot, slot: usize, kind: FaultKind, detail: String) -> GenResponse {
    let request = sl.id;
    let mut resp = complete(sl);
    resp.fault = Some(ServeFault { request, slot: Some(slot), kind, detail });
    resp
}

// ------------------------------------------------------- step engine

/// Admission parameters for [`StepEngine::admit`]: one request's
/// identity plus its scheduling/cancellation envelope, resolved to
/// absolute instants by the caller (the two frontends clock from
/// different submission points).
pub struct Admission<'r> {
    pub id: u64,
    pub prompt: &'r [i32],
    pub max_new: usize,
    /// when the request entered the system (latency/TTFT base)
    pub submitted: Instant,
    /// advisory completion target (EDF scheduling; enforced only when
    /// the server opts in)
    pub deadline: Option<Instant>,
    /// hard cancellation point (`submitted + max_wall`); always
    /// enforced by [`StepEngine::cancel_expired`]
    pub wall_deadline: Option<Instant>,
    /// tenant binding (`None` = the session default)
    pub adapter: Option<Arc<AdapterBinding>>,
    /// `Some(rank_fraction)` when `adapter` is a brownout prefix
    /// sub-binding (the async server derives it at admission while
    /// the controller is past `Normal`; `None` on the batch path)
    pub degraded: Option<f32>,
}

/// The resumable core of KV-cached serving: a decode binding plus the
/// per-slot bookkeeping, exposed as `admit` / `step` / (implicit)
/// retire so a caller can interleave its own work — queue polls,
/// stream delivery — between decode steps without re-binding the
/// session or re-prefilling anything. [`Decoder::serve_incremental`]
/// drives it to drain a fixed slice; [`server::ServeServer`]'s runtime
/// thread drives it forever.
///
/// Warm steps are allocation-free: token buffers carry window capacity
/// from admission, step scratch is preallocated, retirement *moves*
/// the token buffer into the response (`rust/tests/alloc_count.rs`).
pub struct StepEngine<'d> {
    session: DecodeSession<'d>,
    st: DecodeState,
    slots: Vec<Option<Slot>>,
    eos: i32,
    pad: i32,
    /// context window (tokens per slot)
    s: usize,
    /// vocab (logits row width)
    v: usize,
    admissions: u64,
    prefills: u64,
    decode_steps: u64,
    generated_tokens: u64,
    truncated_prompts: u64,
    occupancy_sum: u64,
    faults: u64,
    cancelled: u64,
    quarantined: u64,
    degraded_admissions: u64,
    /// deterministic injection schedule; empty = one branch per step
    fault: FaultPlan,
    // reused step buffers: warm admit/step cycles allocate nothing here
    // (Arc clones into step_adapters are refcount bumps, not allocations)
    row_logits: Vec<f32>,
    step_logits: Vec<f32>,
    active: Vec<usize>,
    step_tokens: Vec<i32>,
    step_adapters: Vec<Option<Arc<AdapterBinding>>>,
}

impl<'d> StepEngine<'d> {
    /// `st` fixes the slot count; prefill resets each joining slot, so
    /// a recycled state's stale contents are never read.
    pub fn new(session: DecodeSession<'d>, st: DecodeState, vocab: &Vocab) -> StepEngine<'d> {
        let n = st.n_slots();
        let s = session.capacity();
        let v = session.vocab();
        StepEngine {
            session,
            st,
            slots: (0..n).map(|_| None).collect(),
            eos: vocab.eos,
            pad: vocab.pad,
            s,
            v,
            admissions: 0,
            prefills: 0,
            decode_steps: 0,
            generated_tokens: 0,
            truncated_prompts: 0,
            occupancy_sum: 0,
            faults: 0,
            cancelled: 0,
            quarantined: 0,
            degraded_admissions: 0,
            fault: FaultPlan::none(),
            row_logits: vec![0.0; v],
            step_logits: vec![0.0; n * v],
            active: Vec::with_capacity(n),
            step_tokens: Vec::with_capacity(n),
            step_adapters: Vec::with_capacity(n),
        }
    }

    /// Total KV slots.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Slots currently decoding a request.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_free_slot(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Context-window capacity per slot.
    pub fn window(&self) -> usize {
        self.s
    }

    pub fn prefill_count(&self) -> u64 {
        self.prefills
    }

    pub fn decode_step_count(&self) -> u64 {
        self.decode_steps
    }

    /// The session's construction-time binding — what an admission
    /// naming no tenant decodes under, and therefore the parent the
    /// brownout controller derives prefix sub-bindings from for such
    /// requests.
    pub fn default_adapter(&self) -> Option<&Arc<AdapterBinding>> {
        self.session.default_adapter()
    }

    /// Admit one request into the first free slot: clamp the prompt,
    /// prefill that slot's cache column under the admission's tenant
    /// binding (`None` = the session default resolved at bind time),
    /// pick the first token (emitted through `on_token`). Returns the
    /// finished response if the request retires at prefill (EOS /
    /// exhausted budget / non-finite logits). Errors if no slot is
    /// free — callers gate on [`StepEngine::has_free_slot`].
    pub fn admit(
        &mut self,
        adm: Admission<'_>,
        on_token: &mut dyn FnMut(u64, i32),
    ) -> Result<Option<GenResponse>> {
        let slot = self.slots.iter().position(|s| s.is_none()).context("admit: no free slot")?;
        let adapter = adm.adapter.or_else(|| self.session.default_adapter().cloned());
        let (mut toks, truncated) = admit_prompt(adm.prompt, self.s, self.pad);
        let admitted = toks.len();
        if truncated {
            self.truncated_prompts += 1;
        }
        if adm.degraded.is_some() {
            self.degraded_admissions += 1;
        }
        self.session
            .prefill_as(&mut self.st, slot, &toks, adapter.as_deref(), &mut self.row_logits)?;
        self.prefills += 1;
        let admission_seq = self.admissions;
        self.admissions += 1;
        if !logits_row_finite(&self.row_logits) {
            // poisoned before the first pick: retire without emitting a
            // token, and leave the slot free (nothing trusts its KV)
            self.faults += 1;
            let sl = Slot {
                id: adm.id,
                toks,
                admitted,
                truncated,
                max_new: adm.max_new,
                submitted: adm.submitted,
                deadline: adm.deadline,
                wall_deadline: adm.wall_deadline,
                first_token_at: None,
                admission_seq,
                adapter,
                degraded: adm.degraded,
            };
            return Ok(Some(fault_complete(
                sl,
                slot,
                FaultKind::NanLogits,
                "non-finite logits at prefill".to_string(),
            )));
        }
        let next = argmax(&self.row_logits, self.eos);
        toks.push(next);
        self.generated_tokens += 1;
        let first_token_at = Some(Instant::now());
        on_token(adm.id, next);
        let sl = Slot {
            id: adm.id,
            toks,
            admitted,
            truncated,
            max_new: adm.max_new,
            submitted: adm.submitted,
            deadline: adm.deadline,
            wall_deadline: adm.wall_deadline,
            first_token_at,
            admission_seq,
            adapter,
            degraded: adm.degraded,
        };
        if finished(next, self.eos, sl.toks.len() - admitted, adm.max_new, sl.toks.len(), self.s) {
            return Ok(Some(complete(sl)));
        }
        self.slots[slot] = Some(sl);
        Ok(None)
    }

    /// One batched decode step over every occupied slot: each active
    /// sequence advances a token (emitted through `on_token`); retiring
    /// requests are pushed into `retired` (pre-size it to
    /// [`StepEngine::slots`] and drain between calls — pushes within
    /// that capacity never allocate). No-op when nothing is active.
    ///
    /// Fault containment: `decode_step` validates everything before
    /// touching per-slot state and bumps sequence lengths only after
    /// all compute succeeds (see `ops::model::decode_step`), so a
    /// failed step leaves every slot at its pre-step position. Recovery
    /// therefore re-prefills each survivor's column from its token
    /// history — bit-identical continuation by the prefill/step logits
    /// equivalence pinned in `tests/decode.rs` — and retires only the
    /// slot the failure is attributable to. A non-finite logits row
    /// quarantines just that slot. Panics (injected or real) are NOT
    /// caught here — the async server supervises them with
    /// `catch_unwind` and a full engine rebuild.
    pub fn step(
        &mut self,
        on_token: &mut dyn FnMut(u64, i32),
        retired: &mut Vec<(u64, GenResponse)>,
    ) -> Result<()> {
        self.active.clear();
        self.step_tokens.clear();
        self.step_adapters.clear();
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(sl) = s {
                self.active.push(i);
                self.step_tokens.push(*sl.toks.last().expect("active slot has tokens"));
                self.step_adapters.push(sl.adapter.clone());
            }
        }
        if self.active.is_empty() {
            return Ok(());
        }
        // deterministic fault injection: one `is_empty` branch when no
        // plan is armed (the production hot path), otherwise advance
        // the plan's step-attempt counter and apply whatever fires
        let mut injected_nan: Option<usize> = None;
        if !self.fault.is_empty() {
            let f = self.fault.fire();
            if f.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(f.delay_ms));
            }
            if f.rank_delay_us > 0 {
                // rank-proportional latency: emulate compute that scales
                // with the Σ of active slots' bound adapter ranks, so
                // brownout drills can prove prefix degradation buys
                // deterministic wall-clock headroom
                // a slot with no explicit binding decodes on the
                // session default adapter — charge that rank, so only
                // a truly adapter-less engine is free
                let units: u64 = self
                    .step_adapters
                    .iter()
                    .map(|a| {
                        a.as_ref()
                            .or(self.session.default_adapter())
                            .map_or(0, |b| b.active_rank() as u64)
                    })
                    .sum();
                if units > 0 {
                    std::thread::sleep(Duration::from_micros(f.rank_delay_us * units));
                }
            }
            if f.panic {
                panic!("injected step panic (attempt {})", f.attempt);
            }
            if f.error {
                self.step_adapters.clear();
                let poison = f.error_slot.filter(|s| self.slots.get(*s).is_some_and(|x| x.is_some()));
                return self.recover_step("injected step error", poison, on_token, retired);
            }
            injected_nan = f.nan_slot;
        }
        let out = &mut self.step_logits[..self.active.len() * self.v];
        let stepped = self.session.decode_step_rows(
            &mut self.st,
            &self.active,
            &self.step_tokens,
            &self.step_adapters,
            out,
        );
        // drop the step's Arc clones now, not at the next step: a
        // retiring slot must release its registry in-flight pin here
        self.step_adapters.clear();
        if let Err(e) = stepped {
            // no slot advanced (decode_step's failure atomicity); no
            // single slot is attributable, so quarantine-recover all
            return self.recover_step(&format!("step failed: {e:#}"), None, on_token, retired);
        }
        if let Some(slot) = injected_nan {
            if let Some(row) = self.active.iter().position(|&s| s == slot) {
                self.step_logits[row * self.v] = f32::NAN;
            }
        }
        self.decode_steps += 1;
        self.occupancy_sum += self.active.len() as u64;
        for (row, &slot) in self.active.iter().enumerate() {
            let logits = &self.step_logits[row * self.v..(row + 1) * self.v];
            if !logits_row_finite(logits) {
                // this slot's KV column is suspect: quarantine it alone;
                // the batch's other rows are untouched by construction
                // (row-independent kernels, pinned in multi_tenant.rs)
                let sl = self.slots[slot].take().expect("active slot");
                self.faults += 1;
                retired.push((
                    sl.id,
                    fault_complete(
                        sl,
                        slot,
                        FaultKind::NanLogits,
                        format!("non-finite logits row at decode step {}", self.decode_steps),
                    ),
                ));
                continue;
            }
            let sl = self.slots[slot].as_mut().expect("active slot");
            let next = argmax(logits, self.eos);
            sl.toks.push(next);
            self.generated_tokens += 1;
            on_token(sl.id, next);
            let new_count = sl.toks.len() - sl.admitted;
            if finished(next, self.eos, new_count, sl.max_new, sl.toks.len(), self.s) {
                let sl = self.slots[slot].take().expect("active slot");
                retired.push((sl.id, complete(sl)));
            }
        }
        Ok(())
    }

    /// Recover from a failed decode step without trusting any slot's
    /// KV cache: re-prefill each surviving slot's column from its token
    /// history (advancing it the one token the failed step owed it),
    /// and retire `poison` — the slot the failure is attributable to —
    /// with a fault response. Only a recovery prefill that *itself*
    /// fails retires its slot too; everything else continues
    /// bit-identically (prefill's final-row logits ≡ `decode_step`
    /// logits, pinned in `tests/decode.rs`).
    fn recover_step(
        &mut self,
        cause: &str,
        poison: Option<usize>,
        on_token: &mut dyn FnMut(u64, i32),
        retired: &mut Vec<(u64, GenResponse)>,
    ) -> Result<()> {
        let active = std::mem::take(&mut self.active);
        for &slot in &active {
            let sl = self.slots[slot].as_mut().expect("active slot");
            if poison == Some(slot) {
                let sl = self.slots[slot].take().expect("active slot");
                self.faults += 1;
                retired.push((
                    sl.id,
                    fault_complete(sl, slot, FaultKind::StepError, cause.to_string()),
                ));
                continue;
            }
            let refill = self.session.prefill_as(
                &mut self.st,
                slot,
                &sl.toks,
                sl.adapter.as_deref(),
                &mut self.row_logits,
            );
            if let Err(e) = refill {
                let sl = self.slots[slot].take().expect("active slot");
                self.faults += 1;
                retired.push((
                    sl.id,
                    fault_complete(
                        sl,
                        slot,
                        FaultKind::StepError,
                        format!("{cause}; recovery prefill failed: {e:#}"),
                    ),
                ));
                continue;
            }
            self.prefills += 1;
            self.quarantined += 1;
            if !logits_row_finite(&self.row_logits) {
                let sl = self.slots[slot].take().expect("active slot");
                self.faults += 1;
                retired.push((
                    sl.id,
                    fault_complete(
                        sl,
                        slot,
                        FaultKind::NanLogits,
                        format!("{cause}; non-finite logits after recovery prefill"),
                    ),
                ));
                continue;
            }
            let next = argmax(&self.row_logits, self.eos);
            let sl = self.slots[slot].as_mut().expect("active slot");
            sl.toks.push(next);
            self.generated_tokens += 1;
            on_token(sl.id, next);
            let new_count = sl.toks.len() - sl.admitted;
            if finished(next, self.eos, new_count, sl.max_new, sl.toks.len(), self.s) {
                let sl = self.slots[slot].take().expect("active slot");
                retired.push((sl.id, complete(sl)));
            }
        }
        self.active = active;
        Ok(())
    }

    /// Cancel one in-flight request by id (stream cancel / abandoned
    /// handle / queue preemption), freeing its slot immediately. The
    /// partial tokens ride the fault response. Returns `None` when `id`
    /// is not in flight (already retired — cancellation raced EOS).
    pub fn abort(&mut self, id: u64, kind: FaultKind, detail: &str) -> Option<GenResponse> {
        let slot = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|sl| sl.id == id))?;
        let sl = self.slots[slot].take().expect("matched slot");
        self.cancelled += 1;
        Some(fault_complete(sl, slot, kind, detail.to_string()))
    }

    /// Retire every in-flight request whose hard wall-clock budget
    /// (`max_wall`) — or, when `enforce_deadlines`, whose deadline —
    /// has passed at `now`. Freed slots are immediately admittable.
    pub fn cancel_expired(
        &mut self,
        now: Instant,
        enforce_deadlines: bool,
        retired: &mut Vec<(u64, GenResponse)>,
    ) {
        for slot in 0..self.slots.len() {
            let Some(sl) = self.slots[slot].as_ref() else { continue };
            let (kind, limit) = if sl.wall_deadline.is_some_and(|d| now > d) {
                (FaultKind::WallClockExceeded, "max_wall")
            } else if enforce_deadlines && sl.deadline.is_some_and(|d| now > d) {
                (FaultKind::DeadlineExceeded, "deadline")
            } else {
                continue;
            };
            let sl = self.slots[slot].take().expect("matched slot");
            self.cancelled += 1;
            retired.push((
                sl.id,
                fault_complete(sl, slot, kind, format!("{limit} exceeded mid-decode")),
            ));
        }
    }

    /// Clear every occupied slot (supervised restart / shutdown),
    /// retiring each with a fault response so the caller can fail its
    /// stream attributably. Counts toward `faults`, not `cancelled`.
    pub fn abort_all(
        &mut self,
        kind: FaultKind,
        detail: &str,
        retired: &mut Vec<(u64, GenResponse)>,
    ) {
        for slot in 0..self.slots.len() {
            if let Some(sl) = self.slots[slot].take() {
                self.faults += 1;
                retired.push((sl.id, fault_complete(sl, slot, kind, detail.to_string())));
            }
        }
    }

    /// Arm a deterministic fault-injection plan (testing / chaos
    /// drills). The plan's step-attempt counter lives on the plan, so
    /// moving it across an engine rebuild preserves the schedule.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault = plan;
    }

    /// Take the armed plan (counter state included) — the supervisor
    /// moves it onto the rebuilt engine after a panic.
    pub fn take_fault_plan(&mut self) -> FaultPlan {
        std::mem::take(&mut self.fault)
    }

    /// Fold the engine's cumulative counters into a metrics record.
    pub fn fold_metrics(&self, m: &mut ServeMetrics) {
        m.prefills = self.prefills;
        m.decode_steps = self.decode_steps;
        m.forwards = self.prefills + self.decode_steps;
        m.generated_tokens = self.generated_tokens;
        m.truncated_prompts = self.truncated_prompts;
        m.faults = self.faults;
        m.cancelled = self.cancelled;
        m.quarantined = self.quarantined;
        m.degraded = self.degraded_admissions;
        m.mean_batch_occupancy = if self.decode_steps > 0 {
            self.occupancy_sum as f64 / self.decode_steps as f64
        } else {
            0.0
        };
    }

    /// Recover the K/V planes for reuse (see [`Decoder::recycle`]).
    pub fn into_state(self) -> DecodeState {
        self.st
    }
}

// ----------------------------------------------------------- decoder

/// Greedy batched decoder over a forward entry point. The parameter
/// stores are uploaded once at construction (prepared sparse weights
/// cached), so generation runs the resident fast path — incrementally
/// KV-cached on the native backend, wave re-forward otherwise.
pub struct Decoder<'rt> {
    session: ForwardSession<'rt>,
    rank_mask: Option<HostTensor>,
    pub vocab: Vocab,
    /// K/V caches reused across [`Decoder::serve_incremental`] calls
    /// (every admission prefill resets its slot, so stale contents are
    /// never read) — spares the per-call cache allocation + zero-fill.
    state: RefCell<Option<DecodeState>>,
    /// tenant adapters requests may name (`GenRequest::adapter`)
    registry: RefCell<AdapterRegistry>,
}

impl<'rt> Decoder<'rt> {
    /// `stores` are uploaded here, at construction; the decoder serves
    /// from its resident copies (the session keeps its own `cfg`
    /// snapshot, so nothing here borrows past the runtime). If a store
    /// changes afterwards (prune, fine-tune step), call
    /// [`Decoder::sync`] to re-upload the changed weights before
    /// serving again.
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        entry_name: &str,
        stores: Vec<&ParamStore>,
        rank_mask: Option<HostTensor>,
    ) -> Result<Self> {
        ensure!(
            cfg.seq_len > 0,
            "decode window is zero (cfg.seq_len = 0): no position to predict from"
        );
        let session = ForwardSession::new(rt, cfg, entry_name, &stores)?;
        Ok(Decoder {
            session,
            rank_mask,
            vocab: Vocab::new(cfg.vocab),
            state: RefCell::new(None),
            registry: RefCell::new(AdapterRegistry::new(0)),
        })
    }

    /// Register (or hot-swap) tenant `id` as a sub-adapter of this
    /// decoder's resident super-network LoRA weights: `rank_mask`
    /// selects the tenant's active heads (`SearchSpace::rank_mask`).
    /// Requires an adapter-carrying entry (`forward_eval*`, not
    /// `forward_eval_base`).
    pub fn register_adapter(&self, id: &str, rank_mask: &HostTensor) -> Result<()> {
        let binding = self.session.adapter_binding(rank_mask)?;
        self.registry.borrow_mut().register(id, binding)
    }

    /// Build (without registering) a tenant binding over this
    /// decoder's resident super-network LoRA weights — the async
    /// server registers into its own shared registry.
    pub fn adapter_binding(&self, rank_mask: &HostTensor) -> Result<AdapterBinding> {
        self.session.adapter_binding(rank_mask)
    }

    /// Register (or hot-swap) tenant `id` from an externally-built
    /// binding (e.g. [`binding_from_store`] over a checkpoint's
    /// adapter store).
    pub fn register_adapter_binding(&self, id: &str, binding: AdapterBinding) -> Result<()> {
        self.registry.borrow_mut().register(id, binding)
    }

    /// Remove tenant `id`; errors while its binding is still held by
    /// an active slot, a queued request, or the pinned default.
    pub fn deregister_adapter(&self, id: &str) -> Result<()> {
        self.registry.borrow_mut().deregister(id)
    }

    /// Pin a registered adapter as the default for requests naming no
    /// tenant (`None` restores the construction-time binding).
    pub fn pin_default_adapter(&self, id: Option<&str>) -> Result<()> {
        self.registry.borrow_mut().pin_default(id)
    }

    /// Cap resident adapter bytes (`0` = unlimited); evicts idle LRU
    /// entries if shrinking requires it.
    pub fn set_adapter_budget(&self, bytes: usize) -> Result<()> {
        self.registry.borrow_mut().set_budget(bytes)
    }

    /// Total bytes of registered resident adapters.
    pub fn adapter_bytes(&self) -> usize {
        self.registry.borrow().resident_bytes()
    }

    /// Registered adapter ids, sorted.
    pub fn adapter_ids(&self) -> Vec<AdapterId> {
        self.registry.borrow().ids()
    }

    /// Whether `id` is registered.
    pub fn has_adapter(&self, id: &str) -> bool {
        self.registry.borrow().contains(id)
    }

    /// Re-upload weights whose store generation changed since
    /// construction (cheap no-op otherwise). Decode bindings are built
    /// per [`Decoder::serve`] call, so they are never stale.
    pub fn sync(&mut self, stores: &[&ParamStore]) -> Result<()> {
        self.session.sync(stores)
    }

    /// Whether this decoder can run the KV-cached incremental path
    /// (native backend + a plain forward entry).
    pub fn supports_decode(&self) -> bool {
        self.session.supports_decode()
    }

    /// The model configuration this decoder serves.
    pub fn config(&self) -> &ModelConfig {
        self.session.config()
    }

    /// Bind a fresh [`StepEngine`] over this decoder's resident
    /// weights, reusing the cached K/V planes when their slot count
    /// still matches `config().batch_eval`. Give the planes back with
    /// [`Decoder::recycle`] when the drive loop ends.
    pub fn step_engine(&self) -> Result<StepEngine<'_>> {
        let b = self.session.config().batch_eval;
        let session = self.session.decoder(self.rank_mask.as_ref())?;
        let st = self
            .state
            .borrow_mut()
            .take()
            .filter(|st| st.n_slots() == b)
            .unwrap_or_else(|| self.session.decode_state(b));
        Ok(StepEngine::new(session, st, &self.vocab))
    }

    /// Stash an engine's K/V planes for the next [`Decoder::step_engine`].
    pub fn recycle(&self, st: DecodeState) {
        *self.state.borrow_mut() = Some(st);
    }

    /// Serve a queue of requests with continuous batching, picking the
    /// fastest decoding path this backend **and entry** support.
    /// Entries the decode engine cannot bind (PJRT, the prefix/series/
    /// parallel baseline forwards) keep the wave re-forward path that
    /// always served them; a bind failure on a decodable entry is a
    /// real error and propagates instead of silently degrading.
    pub fn serve(&self, requests: &[GenRequest]) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        if self.session.supports_decode() {
            self.serve_incremental(requests)
        } else {
            self.serve_reforward(requests)
        }
    }

    /// KV-cached continuous batching (native backend): admission
    /// prefills exactly the joining slot's cache column, every wave
    /// step is one batched `decode_step` over the active slots.
    pub fn serve_incremental(
        &self,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let engine = self.step_engine()?;
        self.serve_with(engine, requests)
    }

    /// Drain a fixed request slice through a [`StepEngine`]: FIFO
    /// admission into free slots, one batched step per wave.
    fn serve_with(
        &self,
        mut engine: StepEngine<'_>,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let start_all = Instant::now();
        let mut metrics = ServeMetrics { requests: requests.len() as u64, ..Default::default() };
        let mut responses: Vec<Option<GenResponse>> = (0..requests.len()).map(|_| None).collect();
        let mut retired: Vec<(u64, GenResponse)> = Vec::with_capacity(engine.slots());
        let mut next_req = 0usize;
        let mut sink = |_id: u64, _tok: i32| {};

        loop {
            // admission: each free slot prefills one pending request
            // (resetting only that slot's cache column). Every request
            // is stamped with the serve() entry time, so a long queue
            // shows up in its latency, not just the decode tail.
            while engine.has_free_slot() && next_req < requests.len() {
                let id = next_req as u64;
                let r = &requests[next_req];
                next_req += 1;
                let adapter = self
                    .registry
                    .borrow_mut()
                    .resolve(r.adapter.as_deref())
                    .with_context(|| format!("request {id}"))?;
                let adm = Admission {
                    id,
                    prompt: &r.prompt,
                    max_new: r.max_new_tokens,
                    submitted: start_all,
                    deadline: r.deadline.and_then(|d| start_all.checked_add(d)),
                    wall_deadline: r.max_wall.and_then(|d| start_all.checked_add(d)),
                    adapter,
                    degraded: None,
                };
                if let Some(resp) = engine.admit(adm, &mut sink)? {
                    responses[id as usize] = Some(resp);
                }
            }
            if engine.active_slots() == 0 {
                if next_req >= requests.len() {
                    break;
                }
                continue; // everything admitted finished at prefill; admit more
            }
            // hard wall-clock budgets are enforced even on the batch
            // path (deadlines stay advisory here, as they always were)
            engine.cancel_expired(Instant::now(), false, &mut retired);
            // one batched step: every active sequence advances a token
            engine.step(&mut sink, &mut retired)?;
            for (id, resp) in retired.drain(..) {
                responses[id as usize] = Some(resp);
            }
        }
        engine.fold_metrics(&mut metrics);
        self.recycle(engine.into_state());
        finalize(metrics, start_all, responses)
    }

    /// Full re-forward wave decoding: every step recomputes the whole
    /// padded `[batch, seq_len]` context. PJRT fallback and the parity
    /// baseline for the incremental path.
    pub fn serve_reforward(
        &self,
        requests: &[GenRequest],
    ) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        ensure!(
            requests.iter().all(|r| r.adapter.is_none()),
            "per-request adapters need the KV-cached decode path; \
             the re-forward fallback serves the construction-time binding only"
        );
        let cfg = self.session.config();
        let b = cfg.batch_eval;
        let s = cfg.seq_len;
        let v = cfg.vocab;
        let eos = self.vocab.eos;
        let start_all = Instant::now();
        let mut metrics = ServeMetrics { requests: requests.len() as u64, ..Default::default() };
        let mut responses: Vec<Option<GenResponse>> = (0..requests.len()).map(|_| None).collect();
        let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
        let mut next_req = 0usize;
        let mut occupancy_sum = 0usize;
        let mut admissions = 0u64;

        loop {
            // admit new requests into free slots (continuous batching);
            // the latency clock started at serve() entry for everyone
            for slot in slots.iter_mut() {
                if slot.is_none() && next_req < requests.len() {
                    let req = next_req;
                    next_req += 1;
                    let r = &requests[req];
                    let (toks, truncated) = admit_prompt(&r.prompt, s, self.vocab.pad);
                    if truncated {
                        metrics.truncated_prompts += 1;
                    }
                    let admitted = toks.len();
                    *slot = Some(Slot {
                        id: req as u64,
                        toks,
                        admitted,
                        truncated,
                        max_new: r.max_new_tokens,
                        submitted: start_all,
                        deadline: r.deadline.and_then(|d| start_all.checked_add(d)),
                        wall_deadline: r.max_wall.and_then(|d| start_all.checked_add(d)),
                        first_token_at: None,
                        admission_seq: admissions,
                        adapter: None,
                        degraded: None,
                    });
                    admissions += 1;
                }
            }
            // hard wall-clock budgets hold on this path too
            let now = Instant::now();
            for i in 0..b {
                if slots[i].as_ref().is_some_and(|sl| sl.wall_deadline.is_some_and(|d| now > d)) {
                    let sl = slots[i].take().unwrap();
                    metrics.cancelled += 1;
                    responses[sl.id as usize] = Some(fault_complete(
                        sl,
                        i,
                        FaultKind::WallClockExceeded,
                        "max_wall exceeded mid-decode".to_string(),
                    ));
                }
            }
            let active: Vec<usize> = (0..b).filter(|i| slots[*i].is_some()).collect();
            if active.is_empty() {
                if next_req >= requests.len() {
                    break;
                }
                continue; // the sweep freed every slot; admit the rest
            }
            occupancy_sum += active.len();

            // build the wave batch: each active slot's context, padded
            let mut x = vec![self.vocab.pad; b * s];
            for &i in &active {
                let state = slots[i].as_ref().unwrap();
                for (t, tok) in state.toks.iter().enumerate() {
                    x[i * s + t] = *tok;
                }
            }
            let xt = HostTensor::from_i32(&[b, s], x);
            let logits = self.session.logits(&xt, self.rank_mask.as_ref())?;
            metrics.forwards += 1;

            // greedy next token per active slot, retire finished
            let data = logits.f32s();
            for &i in &active {
                let sl = slots[i].as_mut().unwrap();
                let pos = sl.toks.len() - 1;
                let off = (i * s + pos) * v;
                let next = argmax(&data[off..off + v], eos);
                sl.toks.push(next);
                metrics.generated_tokens += 1;
                if sl.first_token_at.is_none() {
                    sl.first_token_at = Some(Instant::now());
                }
                let new_count = sl.toks.len() - sl.admitted;
                if finished(next, eos, new_count, sl.max_new, sl.toks.len(), s) {
                    let sl = slots[i].take().unwrap();
                    responses[sl.id as usize] = Some(complete(sl));
                }
            }
        }
        metrics.mean_batch_occupancy = if metrics.forwards > 0 {
            occupancy_sum as f64 / metrics.forwards as f64
        } else {
            0.0
        };
        finalize(metrics, start_all, responses)
    }
}

/// Shared metric finalization: wall/throughput, nearest-rank latency +
/// TTFT percentiles and deadline misses read off the completed
/// responses (occupancy is set by the caller — the two paths average
/// over different step kinds).
fn finalize(
    mut metrics: ServeMetrics,
    start_all: Instant,
    responses: Vec<Option<GenResponse>>,
) -> Result<(Vec<GenResponse>, ServeMetrics)> {
    metrics.wall_secs = start_all.elapsed().as_secs_f64();
    metrics.tokens_per_sec = metrics.generated_tokens as f64 / metrics.wall_secs.max(1e-9);
    let responses = responses
        .into_iter()
        .map(|r| r.context("request never completed"))
        .collect::<Result<Vec<_>>>()?;
    let mut lat: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let mut ttft: Vec<f64> = responses.iter().map(|r| r.ttft_ms).collect();
    crate::util::sort_for_percentiles(&mut lat);
    crate::util::sort_for_percentiles(&mut ttft);
    metrics.p50_latency_ms = crate::util::percentile(&lat, 0.50);
    metrics.p99_latency_ms = crate::util::percentile(&lat, 0.99);
    metrics.p50_ttft_ms = crate::util::percentile(&ttft, 0.50);
    metrics.p99_ttft_ms = crate::util::percentile(&ttft, 0.99);
    metrics.deadline_misses = responses.iter().filter(|r| r.deadline_missed).count() as u64;
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_clamps_to_window_and_flags() {
        let prompt: Vec<i32> = (0..10).collect();
        let (toks, truncated) = admit_prompt(&prompt, 8, 0);
        assert_eq!(toks.len(), 7, "admits at most s-1 tokens");
        assert_eq!(toks, prompt[..7]);
        assert!(truncated);
        let (toks, truncated) = admit_prompt(&prompt[..3], 8, 0);
        assert_eq!(toks, prompt[..3]);
        assert!(!truncated);
        // exactly s-1 fits without truncation
        let (toks, truncated) = admit_prompt(&prompt[..7], 8, 0);
        assert_eq!(toks.len(), 7);
        assert!(!truncated);
        // window capacity up front: in-flight pushes never reallocate
        assert!(toks.capacity() >= 8);
    }

    #[test]
    fn empty_prompt_is_seeded_with_pad() {
        let (toks, truncated) = admit_prompt(&[], 8, 5);
        assert_eq!(toks, vec![5]);
        assert!(!truncated);
    }

    #[test]
    fn zero_window_admission_saturates_instead_of_underflowing() {
        // s == 0 is rejected at Decoder/ServeServer construction, but
        // the clamp itself must not underflow usize (debug panic /
        // release wraparound admitting ~usize::MAX tokens)
        let (toks, truncated) = admit_prompt(&[1, 2, 3], 0, 9);
        assert_eq!(toks, vec![9], "nothing fits; pad-seeded");
        assert!(truncated);
        let (toks, truncated) = admit_prompt(&[], 0, 9);
        assert_eq!(toks, vec![9]);
        assert!(!truncated);
    }

    #[test]
    fn one_token_window_admits_pad_only() {
        // s == 1: zero prompt positions fit (the one slot is reserved
        // for generation), any non-empty prompt is truncated away
        let (toks, truncated) = admit_prompt(&[4, 5], 1, 7);
        assert_eq!(toks, vec![7]);
        assert!(truncated);
        let (toks, truncated) = admit_prompt(&[], 1, 7);
        assert_eq!(toks, vec![7]);
        assert!(!truncated);
    }

    #[test]
    fn with_adapter_tags_the_request() {
        let r = GenRequest::new(vec![1], 4);
        assert_eq!(r.adapter, None);
        let r = r.with_adapter("tenant-a");
        assert_eq!(r.adapter.as_deref(), Some("tenant-a"));
    }

    #[test]
    fn retirement_rule_covers_eos_budget_and_window() {
        let (eos, s) = (2, 48);
        assert!(finished(eos, eos, 1, 10, 5, s), "eos retires");
        assert!(finished(7, eos, 10, 10, 5, s), "budget retires");
        assert!(finished(7, eos, 1, 10, s, s), "full window retires");
        assert!(!finished(7, eos, 1, 10, 5, s), "otherwise keep going");
    }

    #[test]
    fn argmax_breaks_ties_toward_highest_index() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0], -1), 2);
        assert_eq!(argmax(&[], 9), 9, "empty row falls back");
        // a prompt filling the window still yields >= 1 generated token
        let (toks, truncated) = admit_prompt(&(0..100).collect::<Vec<i32>>(), 48, 0);
        assert!(truncated);
        assert_eq!(toks.len(), 47);
        // the decoder appends one token before any retirement check, so
        // new_count >= 1 even for truncated prompts
        assert!(!finished(7, 2, 0, 4, toks.len(), 48));
    }

    #[test]
    fn argmax_nan_loses_deterministically() {
        // a NaN anywhere must not capture the pick or break ties
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0], -1), 2);
        assert_eq!(argmax(&[f32::NAN, 1.0, f32::NAN], -1), 1);
        assert_eq!(argmax(&[3.0, f32::NAN, 1.0], -1), 0);
        // scan-order invariance: reversing the finite values mirrors
        // the pick; the NaN never wins from either direction
        assert_eq!(argmax(&[f32::NAN, 5.0, 4.0], -1), 1);
        assert_eq!(argmax(&[4.0, 5.0, f32::NAN], -1), 1);
        // ties still resolve to the highest index with NaNs interleaved
        assert_eq!(argmax(&[3.0, f32::NAN, 3.0], -1), 2);
        // all-NaN rows fall back exactly like empty rows
        assert_eq!(argmax(&[f32::NAN, f32::NAN], 7), 7);
        // -inf is a real (losing) value, not a NaN
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN], -1), 0);
    }

    #[test]
    fn request_builders_set_scheduling_fields() {
        let r = GenRequest::new(vec![1, 2], 4);
        assert_eq!(r.deadline, None);
        assert_eq!(r.priority, 0);
        assert_eq!(r.max_wall, None);
        let r = r
            .with_deadline(Duration::from_millis(250))
            .with_priority(3)
            .with_max_wall_ms(900);
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        assert_eq!(r.priority, 3);
        assert_eq!(r.max_wall, Some(Duration::from_millis(900)));
    }

    #[test]
    fn finite_row_check_matches_contract() {
        assert!(logits_row_finite(&[1.0, -2.5, 0.0]));
        assert!(logits_row_finite(&[]), "empty row has nothing non-finite");
        assert!(!logits_row_finite(&[1.0, f32::NAN]));
        assert!(!logits_row_finite(&[f32::INFINITY, 0.0]));
        assert!(!logits_row_finite(&[f32::NEG_INFINITY]));
    }
}
