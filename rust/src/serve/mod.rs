//! Batched greedy-decoding service over the sparse + adapted model.
//!
//! Demonstrates the paper's §4.4 deployment claim — the Shears model
//! serves inference with adapters *unmerged* (merging would destroy the
//! base-weight sparsity) — as a minimal continuous-batching decoder:
//! requests join a wave, every wave step runs ONE forward for all active
//! sequences, finished sequences retire and new requests take their slot.
//! Latency/throughput metrics come out per run (examples/serve_demo.rs).

use crate::data::Vocab;
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use crate::train::ForwardSession;
use anyhow::{Context, Result};
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    pub new_tokens: usize,
    pub latency_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub generated_tokens: u64,
    pub forwards: u64,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub mean_batch_occupancy: f64,
}

/// Greedy batched decoder over a forward entry point. The parameter
/// stores are uploaded once at construction (prepared sparse weights
/// cached), so every wave forward runs the resident fast path.
pub struct Decoder<'rt> {
    cfg: &'rt ModelConfig,
    session: ForwardSession<'rt>,
    rank_mask: Option<HostTensor>,
    pub vocab: Vocab,
}

impl<'rt> Decoder<'rt> {
    /// `stores` are uploaded here, at construction; the decoder serves
    /// from its resident copies. If a store changes afterwards (prune,
    /// fine-tune step), call [`Decoder::sync`] to re-upload the changed
    /// weights before serving again.
    pub fn new(
        rt: &'rt Runtime,
        cfg: &'rt ModelConfig,
        entry_name: &str,
        stores: Vec<&'rt ParamStore>,
        rank_mask: Option<HostTensor>,
    ) -> Result<Self> {
        let session = ForwardSession::new(rt, cfg, entry_name, &stores)?;
        Ok(Decoder { cfg, session, rank_mask, vocab: Vocab::new(cfg.vocab) })
    }

    /// Re-upload weights whose store generation changed since
    /// construction (cheap no-op otherwise).
    pub fn sync(&mut self, stores: &[&ParamStore]) -> Result<()> {
        self.session.sync(stores)
    }

    /// Serve a queue of requests with wave-style continuous batching.
    pub fn serve(&self, requests: &[GenRequest]) -> Result<(Vec<GenResponse>, ServeMetrics)> {
        let b = self.cfg.batch_eval;
        let s = self.cfg.seq_len;
        let start_all = Instant::now();
        let mut metrics = ServeMetrics { requests: requests.len() as u64, ..Default::default() };
        let mut responses: Vec<Option<GenResponse>> = vec![None; requests.len()];
        let mut latencies: Vec<f64> = Vec::new();

        // active slots: (request index, tokens so far, start time)
        let mut next_req = 0usize;
        let mut slots: Vec<Option<(usize, Vec<i32>, Instant)>> = vec![None; b];
        let mut occupancy_sum = 0usize;

        loop {
            // admit new requests into free slots (continuous batching)
            for slot in slots.iter_mut() {
                if slot.is_none() && next_req < requests.len() {
                    let r = &requests[next_req];
                    let mut toks = r.prompt.clone();
                    toks.truncate(s - 1);
                    *slot = Some((next_req, toks, Instant::now()));
                    next_req += 1;
                }
            }
            let active: Vec<usize> = (0..b).filter(|i| slots[*i].is_some()).collect();
            if active.is_empty() {
                break;
            }
            occupancy_sum += active.len();

            // build the wave batch: each active slot's context, padded
            let mut x = vec![self.vocab.pad; b * s];
            for &i in &active {
                let (_, toks, _) = slots[i].as_ref().unwrap();
                for (t, tok) in toks.iter().enumerate() {
                    x[i * s + t] = *tok;
                }
            }
            let xt = HostTensor::from_i32(&[b, s], x);
            let logits = self.forward(&xt)?;
            metrics.forwards += 1;

            // greedy next token per active slot, retire finished
            let v = self.cfg.vocab;
            for &i in &active {
                let (req_idx, toks, started) = slots[i].take().unwrap();
                let pos = toks.len() - 1;
                let off = (i * s + pos) * v;
                let data = logits.f32s();
                let slice = &data[off..off + v];
                let next = slice
                    .iter()
                    .enumerate()
                    .max_by(|a, c| a.1.partial_cmp(c.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(idx, _)| idx as i32)
                    .unwrap_or(self.vocab.eos);
                let mut toks = toks;
                toks.push(next);
                metrics.generated_tokens += 1;
                let new_count = toks.len() - requests[req_idx].prompt.len().min(s - 1);
                let done = next == self.vocab.eos
                    || new_count >= requests[req_idx].max_new_tokens
                    || toks.len() >= s;
                if done {
                    let lat = started.elapsed().as_secs_f64() * 1e3;
                    latencies.push(lat);
                    responses[req_idx] = Some(GenResponse {
                        tokens: toks,
                        new_tokens: new_count,
                        latency_ms: lat,
                    });
                } else {
                    slots[i] = Some((req_idx, toks, started));
                }
            }
        }

        metrics.wall_secs = start_all.elapsed().as_secs_f64();
        metrics.tokens_per_sec = metrics.generated_tokens as f64 / metrics.wall_secs.max(1e-9);
        metrics.mean_batch_occupancy = if metrics.forwards > 0 {
            occupancy_sum as f64 / metrics.forwards as f64
        } else {
            0.0
        };
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pct = |p: f64| {
            if latencies.is_empty() {
                0.0
            } else {
                latencies[((latencies.len() - 1) as f64 * p) as usize]
            }
        };
        metrics.p50_latency_ms = pct(0.5);
        metrics.p99_latency_ms = pct(0.99);
        let responses = responses
            .into_iter()
            .map(|r| r.context("request never completed"))
            .collect::<Result<Vec<_>>>()?;
        Ok((responses, metrics))
    }

    fn forward(&self, x: &HostTensor) -> Result<HostTensor> {
        self.session.logits(x, self.rank_mask.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_response_shapes() {
        let r = GenRequest { prompt: vec![1, 5, 9], max_new_tokens: 4 };
        assert_eq!(r.prompt.len(), 3);
        let resp = GenResponse { tokens: vec![1, 5, 9, 2], new_tokens: 1, latency_ms: 1.0 };
        assert_eq!(resp.tokens.len(), 4);
    }
}
