//! Pluggable execution backends behind one `Runtime` facade.
//!
//! Two implementations of the same artifact-shaped contract (load an
//! entry point by file name, execute it over positionally-ordered
//! tensors, keep frozen inputs device-resident):
//!
//! * [`native`] — pure-Rust CPU executor (`src/ops/`). Hermetic: no
//!   Python, no XLA, no `artifacts/` directory. This is the default and
//!   what tier-1 CI runs.
//! * [`pjrt`] *(cargo feature `xla`)* — the original PJRT path over
//!   AOT'd HLO text from `make artifacts`.
//!
//! Selection: [`Runtime::native`] / [`Runtime::pjrt`] explicitly,
//! [`Runtime::new`] for artifact-directory auto-detection (PJRT when
//! built with `xla` and a manifest exists, native otherwise),
//! [`Runtime::from_flag`] for the CLI `--backend native|pjrt|auto`, and
//! [`Runtime::from_env`] for the `SHEARS_BACKEND` env var (benches).
//!
//! [`DeviceBuffer`] abstracts the §Perf buffer-residency lever: on PJRT
//! an uploaded buffer lives on device and skips per-step literal
//! round-trips; on native it simply pins a host copy, keeping
//! `TrainSession` backend-agnostic.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

use crate::model::Manifest;
use crate::tensor::HostTensor;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;

/// Backend-resident input reused across many executions (frozen base
/// weights, masks).
pub enum DeviceBuffer {
    /// native backend: a pinned host copy
    Native(HostTensor),
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtBuffer),
}

/// Execution input: a resident buffer or a per-call host tensor.
pub enum Arg<'a> {
    Buf(&'a DeviceBuffer),
    Host(&'a HostTensor),
}

/// A loaded entry point, bound to the backend that produced it.
#[derive(Clone)]
pub struct Exe {
    pub name: String,
    /// input arity; used to turn mismatches into errors before execution
    /// (the PJRT buffer path segfaults on them)
    pub param_count: usize,
    kind: ExeKind,
}

#[derive(Clone)]
enum ExeKind {
    Native(Rc<native::NativeExe>),
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtExe),
}

pub struct Runtime {
    inner: Inner,
    /// executions performed (metrics)
    pub exec_count: RefCell<u64>,
}

enum Inner {
    Native(native::NativeBackend),
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtBackend),
}

impl Runtime {
    /// The pure-Rust CPU backend over the built-in manifest.
    pub fn native() -> Result<Runtime> {
        crate::info!("runtime up: backend=native (built-in manifest)");
        Ok(Runtime {
            inner: Inner::Native(native::NativeBackend::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// The PJRT artifact executor over `artifacts_dir`.
    #[cfg(feature = "xla")]
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            inner: Inner::Pjrt(pjrt::PjrtBackend::new(artifacts_dir)?),
            exec_count: RefCell::new(0),
        })
    }

    /// Auto-detect: PJRT when this build has the `xla` feature and
    /// `artifacts_dir` holds a manifest; the native backend otherwise.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        #[cfg(feature = "xla")]
        if dir.join("manifest.json").exists() {
            return Self::pjrt(dir);
        }
        if dir.join("manifest.json").exists() {
            crate::info!(
                "artifacts present at {} but built without the `xla` feature; using the native backend",
                dir.display()
            );
        }
        Self::native()
    }

    /// CLI backend selection: `native`, `pjrt` (alias `xla`), or `auto`.
    pub fn from_flag(backend: &str, artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        match backend {
            "native" => Self::native(),
            "auto" | "" => Self::new(artifacts_dir),
            "pjrt" | "xla" => {
                #[cfg(feature = "xla")]
                {
                    Self::pjrt(artifacts_dir)
                }
                #[cfg(not(feature = "xla"))]
                {
                    let _ = artifacts_dir;
                    bail!(
                        "this build has no PJRT backend — rebuild with \
                         `--features xla` (and the vendored xla crate, see README)"
                    )
                }
            }
            other => bail!("unknown backend '{other}' (expected native|pjrt|auto)"),
        }
    }

    /// `SHEARS_BACKEND` env override (default `auto`); used by benches so
    /// the same binary compares backends apples-to-apples.
    pub fn from_env(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let spec = std::env::var("SHEARS_BACKEND").unwrap_or_else(|_| "auto".into());
        Self::from_flag(&spec, artifacts_dir)
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.inner {
            Inner::Native(_) => "native",
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => "pjrt",
        }
    }

    /// The manifest this runtime executes against: built-in for native,
    /// on-disk for PJRT.
    pub fn manifest(&self) -> Result<Manifest> {
        match &self.inner {
            Inner::Native(n) => Ok(n.manifest().clone()),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Manifest::load(p.dir()),
        }
    }

    /// Artifact directory (PJRT only; the native backend has none).
    pub fn artifacts_dir(&self) -> Option<&Path> {
        match &self.inner {
            Inner::Native(_) => None,
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Some(p.dir()),
        }
    }

    /// Load an entry point / prune op by artifact file name.
    pub fn load(&self, file: &str) -> Result<Exe> {
        match &self.inner {
            Inner::Native(n) => {
                let ne = n.load(file)?;
                Ok(Exe {
                    name: file.to_string(),
                    param_count: ne.param_count(),
                    kind: ExeKind::Native(ne),
                })
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => {
                let (pe, param_count) = p.load(file)?;
                Ok(Exe { name: file.to_string(), param_count, kind: ExeKind::Pjrt(pe) })
            }
        }
    }

    pub fn compiled_count(&self) -> usize {
        match &self.inner {
            Inner::Native(n) => n.compiled_count(),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => p.compiled_count(),
        }
    }

    /// Pin a host tensor backend-side for reuse across executions.
    ///
    /// On native this clones once to take ownership (the caller's store
    /// keeps its copy — acceptable at current model scale; sharing via
    /// refcounted stores is a future lever if bases grow large).
    pub fn upload(&self, t: &HostTensor) -> Result<DeviceBuffer> {
        match &self.inner {
            Inner::Native(_) => Ok(DeviceBuffer::Native(t.clone())),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Ok(DeviceBuffer::Pjrt(p.upload(t)?)),
        }
    }

    fn check_arity(exe: &Exe, supplied: usize) -> Result<()> {
        if exe.param_count != usize::MAX && exe.param_count != supplied {
            bail!(
                "{}: supplied {supplied} inputs but the entry takes {} \
                 (manifest out of sync?)",
                exe.name,
                exe.param_count
            );
        }
        Ok(())
    }

    fn native_exe<'e>(exe: &'e Exe) -> Result<&'e native::NativeExe> {
        match &exe.kind {
            ExeKind::Native(ne) => Ok(ne),
            #[cfg(feature = "xla")]
            ExeKind::Pjrt(_) => {
                bail!("executable '{}' was loaded by the pjrt backend", exe.name)
            }
        }
    }

    /// All-host-tensor execution path.
    pub fn run(&self, exe: &Exe, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        Self::check_arity(exe, inputs.len())?;
        *self.exec_count.borrow_mut() += 1;
        match &self.inner {
            Inner::Native(_) => native::execute(Self::native_exe(exe)?, inputs),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => match &exe.kind {
                ExeKind::Pjrt(pe) => p.run(pe, &exe.name, inputs),
                ExeKind::Native(_) => {
                    bail!("executable '{}' was loaded by the native backend", exe.name)
                }
            },
        }
    }

    /// Mixed resident-buffer / host-tensor execution path.
    pub fn run_args(&self, exe: &Exe, inputs: &[Arg]) -> Result<Vec<HostTensor>> {
        Self::check_arity(exe, inputs.len())?;
        *self.exec_count.borrow_mut() += 1;
        match &self.inner {
            Inner::Native(_) => {
                let resolved: Vec<&HostTensor> = inputs
                    .iter()
                    .map(|a| match a {
                        Arg::Host(t) => Ok(*t),
                        Arg::Buf(DeviceBuffer::Native(t)) => Ok(t),
                        #[cfg(feature = "xla")]
                        Arg::Buf(DeviceBuffer::Pjrt(_)) => bail!(
                            "{}: pjrt device buffer passed to the native backend",
                            exe.name
                        ),
                    })
                    .collect::<Result<_>>()?;
                native::execute(Self::native_exe(exe)?, &resolved)
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => match &exe.kind {
                ExeKind::Pjrt(pe) => p.run_args(pe, &exe.name, inputs),
                ExeKind::Native(_) => {
                    bail!("executable '{}' was loaded by the native backend", exe.name)
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_is_hermetic() {
        // no artifacts directory anywhere in sight
        let rt = Runtime::new("/definitely/not/a/dir").unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert!(rt.artifacts_dir().is_none());
        let m = rt.manifest().unwrap();
        assert!(m.config("tiny-llama").is_ok());
    }

    #[test]
    fn flag_selection() {
        assert_eq!(Runtime::from_flag("native", "x").unwrap().backend_name(), "native");
        assert!(Runtime::from_flag("bogus", "x").is_err());
        #[cfg(not(feature = "xla"))]
        {
            let e = Runtime::from_flag("pjrt", "x").unwrap_err();
            assert!(format!("{e:#}").contains("xla"), "{e:#}");
        }
    }

    #[test]
    fn arity_mismatch_is_an_error() {
        let rt = Runtime::native().unwrap();
        let cfgm = rt.manifest().unwrap();
        let cfg = cfgm.config("tiny-llama").unwrap();
        let entry = cfg.entry("forward_eval_base").unwrap();
        let exe = rt.load(&entry.file).unwrap();
        let t = HostTensor::zeros(&[1]);
        let e = rt.run(&exe, &[&t]).unwrap_err();
        assert!(format!("{e:#}").contains("inputs"), "{e:#}");
    }

    #[test]
    fn upload_roundtrips_on_native() {
        let rt = Runtime::native().unwrap();
        let t = HostTensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        match rt.upload(&t).unwrap() {
            DeviceBuffer::Native(copy) => assert_eq!(copy, t),
            #[cfg(feature = "xla")]
            DeviceBuffer::Pjrt(_) => panic!("native runtime returned a pjrt buffer"),
        }
    }
}
