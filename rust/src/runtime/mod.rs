//! Pluggable execution backends behind one `Runtime` facade.
//!
//! Two implementations of the same artifact-shaped contract (load an
//! entry point by file name, execute it over positionally-ordered
//! tensors, keep frozen inputs device-resident):
//!
//! * [`native`] — pure-Rust CPU executor (`src/ops/`). Hermetic: no
//!   Python, no XLA, no `artifacts/` directory. This is the default and
//!   what tier-1 CI runs.
//! * [`pjrt`] *(cargo feature `xla`)* — the original PJRT path over
//!   AOT'd HLO text from `make artifacts`.
//!
//! Selection: [`Runtime::native`] / [`Runtime::pjrt`] explicitly,
//! [`Runtime::new`] for artifact-directory auto-detection (PJRT when
//! built with `xla` and a manifest exists, native otherwise),
//! [`Runtime::from_flag`] for the CLI `--backend native|pjrt|auto`, and
//! [`Runtime::from_env`] for the `SHEARS_BACKEND` env var (benches).
//!
//! [`DeviceBuffer`] abstracts the §Perf buffer-residency lever: on PJRT
//! an uploaded buffer lives on device and skips per-step literal
//! round-trips; on native it pins a host copy **plus the weight's
//! prepared sparse/dense structure** ([`NativeBuffer`]) — the CSR
//! forward gather *and* its lazily-built CSC companion for the
//! backward `dx = dy @ W` — so eval/search/serve loops over thousands
//! of sub-adapter configs, and training loops over a frozen pruned
//! base, never re-derive either view. [`ResidentParams`] keeps a whole
//! `ParamStore` resident, re-uploading only weights whose generation
//! changed (prune step, optimizer update) — cached structure (CSC
//! included, it lives inside the same `PreparedWeight`) is invalidated
//! exactly when a weight actually changes.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

use crate::model::{Manifest, ParamStore};
use crate::ops::model::{AdapterBinding, DecodeModel, PreparedCell, RowAdapters};
pub use crate::ops::model::DecodeState;
use crate::tensor::HostTensor;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

/// Native resident buffer: a pinned host copy plus the lazily-built
/// prepared-weight slot shared into the kernels on every execution.
pub struct NativeBuffer {
    pub tensor: HostTensor,
    pub prepared: PreparedCell,
}

impl NativeBuffer {
    pub fn new(tensor: HostTensor) -> NativeBuffer {
        NativeBuffer { tensor, prepared: PreparedCell::default() }
    }
}

/// Backend-resident input reused across many executions (frozen base
/// weights, masks).
pub enum DeviceBuffer {
    /// native backend: a pinned host copy + prepared-weight cache
    Native(NativeBuffer),
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtBuffer),
}

impl DeviceBuffer {
    /// Host view of the resident tensor. `None` on device-only
    /// backends (PJRT) where reading back requires a transfer.
    pub fn host(&self) -> Option<&HostTensor> {
        match self {
            DeviceBuffer::Native(nb) => Some(&nb.tensor),
            #[cfg(feature = "xla")]
            DeviceBuffer::Pjrt(_) => None,
        }
    }
}

/// Execution input: a resident buffer, a per-call host tensor, or —
/// for [`Runtime::bind_decode`] only — a positional hole where the
/// decode path supplies the value itself (the `x` token batch).
pub enum Arg<'a> {
    Buf(&'a DeviceBuffer),
    Host(&'a HostTensor),
    /// Input the decode engine replaces; rejected by full executions.
    Absent,
}

/// A loaded entry point, bound to the backend that produced it.
#[derive(Clone)]
pub struct Exe {
    pub name: String,
    /// input arity; used to turn mismatches into errors before execution
    /// (the PJRT buffer path segfaults on them)
    pub param_count: usize,
    kind: ExeKind,
}

#[derive(Clone)]
enum ExeKind {
    Native(Rc<native::NativeExe>),
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtExe),
}

pub struct Runtime {
    inner: Inner,
    /// executions performed (metrics)
    pub exec_count: RefCell<u64>,
}

enum Inner {
    Native(native::NativeBackend),
    #[cfg(feature = "xla")]
    Pjrt(pjrt::PjrtBackend),
}

impl Runtime {
    /// The pure-Rust CPU backend over the built-in manifest.
    pub fn native() -> Result<Runtime> {
        crate::info!("runtime up: backend=native (built-in manifest)");
        Ok(Runtime {
            inner: Inner::Native(native::NativeBackend::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// The PJRT artifact executor over `artifacts_dir`.
    #[cfg(feature = "xla")]
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            inner: Inner::Pjrt(pjrt::PjrtBackend::new(artifacts_dir)?),
            exec_count: RefCell::new(0),
        })
    }

    /// Auto-detect: PJRT when this build has the `xla` feature and
    /// `artifacts_dir` holds a manifest; the native backend otherwise.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        #[cfg(feature = "xla")]
        if dir.join("manifest.json").exists() {
            return Self::pjrt(dir);
        }
        if dir.join("manifest.json").exists() {
            crate::info!(
                "artifacts present at {} but built without the `xla` feature; using the native backend",
                dir.display()
            );
        }
        Self::native()
    }

    /// CLI backend selection: `native`, `pjrt` (alias `xla`), or `auto`.
    pub fn from_flag(backend: &str, artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        match backend {
            "native" => Self::native(),
            "auto" | "" => Self::new(artifacts_dir),
            "pjrt" | "xla" => {
                #[cfg(feature = "xla")]
                {
                    Self::pjrt(artifacts_dir)
                }
                #[cfg(not(feature = "xla"))]
                {
                    let _ = artifacts_dir;
                    bail!(
                        "this build has no PJRT backend — rebuild with \
                         `--features xla` (and the vendored xla crate, see README)"
                    )
                }
            }
            other => bail!("unknown backend '{other}' (expected native|pjrt|auto)"),
        }
    }

    /// `SHEARS_BACKEND` env override (default `auto`); used by benches so
    /// the same binary compares backends apples-to-apples.
    pub fn from_env(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let spec = std::env::var("SHEARS_BACKEND").unwrap_or_else(|_| "auto".into());
        Self::from_flag(&spec, artifacts_dir)
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.inner {
            Inner::Native(_) => "native",
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => "pjrt",
        }
    }

    /// The manifest this runtime executes against: built-in for native,
    /// on-disk for PJRT.
    pub fn manifest(&self) -> Result<Manifest> {
        match &self.inner {
            Inner::Native(n) => Ok(n.manifest().clone()),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Manifest::load(p.dir()),
        }
    }

    /// Artifact directory (PJRT only; the native backend has none).
    pub fn artifacts_dir(&self) -> Option<&Path> {
        match &self.inner {
            Inner::Native(_) => None,
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Some(p.dir()),
        }
    }

    /// Load an entry point / prune op by artifact file name.
    pub fn load(&self, file: &str) -> Result<Exe> {
        match &self.inner {
            Inner::Native(n) => {
                let ne = n.load(file)?;
                Ok(Exe {
                    name: file.to_string(),
                    param_count: ne.param_count(),
                    kind: ExeKind::Native(ne),
                })
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => {
                let (pe, param_count) = p.load(file)?;
                Ok(Exe { name: file.to_string(), param_count, kind: ExeKind::Pjrt(pe) })
            }
        }
    }

    pub fn compiled_count(&self) -> usize {
        match &self.inner {
            Inner::Native(n) => n.compiled_count(),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => p.compiled_count(),
        }
    }

    /// `(misses, takes)` of the native scratch arena — `misses` stops
    /// growing once steady-state loops reuse every buffer. `None` on
    /// PJRT (no host-side arena).
    pub fn scratch_stats(&self) -> Option<(u64, u64)> {
        match &self.inner {
            Inner::Native(n) => Some((n.scratch().misses(), n.scratch().takes())),
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => None,
        }
    }

    /// Pin a host tensor backend-side for reuse across executions.
    ///
    /// On native this clones once to take ownership (the caller's store
    /// keeps its copy — acceptable at current model scale; sharing via
    /// refcounted stores is a future lever if bases grow large) and
    /// attaches an empty prepared-weight slot, filled at first use.
    pub fn upload(&self, t: &HostTensor) -> Result<DeviceBuffer> {
        match &self.inner {
            Inner::Native(_) => Ok(DeviceBuffer::Native(NativeBuffer::new(t.clone()))),
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => Ok(DeviceBuffer::Pjrt(p.upload(t)?)),
        }
    }

    fn check_arity(exe: &Exe, supplied: usize) -> Result<()> {
        if exe.param_count != usize::MAX && exe.param_count != supplied {
            bail!(
                "{}: supplied {supplied} inputs but the entry takes {} \
                 (manifest out of sync?)",
                exe.name,
                exe.param_count
            );
        }
        Ok(())
    }

    fn native_exe<'e>(exe: &'e Exe) -> Result<&'e native::NativeExe> {
        match &exe.kind {
            ExeKind::Native(ne) => Ok(ne),
            #[cfg(feature = "xla")]
            ExeKind::Pjrt(_) => {
                bail!("executable '{}' was loaded by the pjrt backend", exe.name)
            }
        }
    }

    /// All-host-tensor execution path (no cross-call prepared caching;
    /// hot loops should upload their frozen weights and use
    /// [`Runtime::run_args`]).
    pub fn run(&self, exe: &Exe, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        Self::check_arity(exe, inputs.len())?;
        *self.exec_count.borrow_mut() += 1;
        match &self.inner {
            Inner::Native(n) => {
                let resolved: Vec<native::ExecInput> =
                    inputs.iter().map(|t| native::ExecInput::host(t)).collect();
                n.execute(Self::native_exe(exe)?, &resolved)
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => match &exe.kind {
                ExeKind::Pjrt(pe) => p.run(pe, &exe.name, inputs),
                ExeKind::Native(_) => {
                    bail!("executable '{}' was loaded by the native backend", exe.name)
                }
            },
        }
    }

    /// Mixed resident-buffer / host-tensor execution path. Resident
    /// buffers carry their prepared-weight cache into the kernels.
    pub fn run_args(&self, exe: &Exe, inputs: &[Arg]) -> Result<Vec<HostTensor>> {
        Self::check_arity(exe, inputs.len())?;
        *self.exec_count.borrow_mut() += 1;
        match &self.inner {
            Inner::Native(n) => {
                let resolved: Vec<native::ExecInput> = inputs
                    .iter()
                    .map(|a| match a {
                        Arg::Host(t) => Ok(native::ExecInput::host(t)),
                        Arg::Buf(DeviceBuffer::Native(nb)) => Ok(native::ExecInput {
                            t: &nb.tensor,
                            prepared: Some(&nb.prepared),
                        }),
                        Arg::Absent => {
                            bail!("{}: absent input passed to a full execution", exe.name)
                        }
                        #[cfg(feature = "xla")]
                        Arg::Buf(DeviceBuffer::Pjrt(_)) => bail!(
                            "{}: pjrt device buffer passed to the native backend",
                            exe.name
                        ),
                    })
                    .collect::<Result<_>>()?;
                n.execute(Self::native_exe(exe)?, &resolved)
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(p) => match &exe.kind {
                ExeKind::Pjrt(pe) => p.run_args(pe, &exe.name, inputs),
                ExeKind::Native(_) => {
                    bail!("executable '{}' was loaded by the native backend", exe.name)
                }
            },
        }
    }
}

// --------------------------------------------------- incremental decode

impl Runtime {
    /// Whether this backend has a KV-cached incremental decode path.
    /// The native executor does; PJRT serves via full re-forward.
    pub fn supports_decode(&self) -> bool {
        match &self.inner {
            Inner::Native(_) => true,
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => false,
        }
    }

    /// Whether `exe` can actually be bound for incremental decoding on
    /// this backend: native **and** a plain forward entry (train steps,
    /// calibration, and the PEFT-baseline forwards cannot).
    /// [`crate::serve::Decoder`] dispatches on this, so a bind error on
    /// a decodable entry surfaces instead of silently degrading to the
    /// re-forward path.
    pub fn decodable(&self, exe: &Exe) -> bool {
        match &self.inner {
            Inner::Native(_) => match Self::native_exe(exe) {
                Ok(ne) => ne.decodable(),
                Err(_) => false,
            },
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => false,
        }
    }

    /// Bind a plain forward entry for incremental decoding. `inputs`
    /// align positionally with the entry signature exactly as in
    /// [`Runtime::run_args`]; pass [`Arg::Absent`] for the per-batch
    /// `x` input (the decode calls supply tokens directly). Resident
    /// buffers carry their prepared-weight cells into the binding, so
    /// decode steps ride the same cached CSR/dense structures as the
    /// batch forward. Rebind after any weight re-upload (`sync`).
    pub fn bind_decode<'p>(
        &'p self,
        exe: &'p Exe,
        inputs: &[Arg<'p>],
    ) -> Result<DecodeSession<'p>> {
        Self::check_arity(exe, inputs.len())?;
        match &self.inner {
            Inner::Native(n) => {
                let resolved: Vec<Option<native::ExecInput<'p>>> = inputs
                    .iter()
                    .map(|a| match a {
                        Arg::Absent => Ok(None),
                        Arg::Host(t) => Ok(Some(native::ExecInput::host(t))),
                        Arg::Buf(DeviceBuffer::Native(nb)) => Ok(Some(native::ExecInput {
                            t: &nb.tensor,
                            prepared: Some(&nb.prepared),
                        })),
                        #[cfg(feature = "xla")]
                        Arg::Buf(DeviceBuffer::Pjrt(_)) => bail!(
                            "{}: pjrt device buffer passed to the native backend",
                            exe.name
                        ),
                    })
                    .collect::<Result<_>>()?;
                let (model, default) = n.bind_decode(Self::native_exe(exe)?, &resolved)?;
                Ok(DecodeSession {
                    rt: self,
                    model,
                    default_adapter: default.map(Arc::new),
                })
            }
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => bail!(
                "incremental decode requires the native backend \
                 (pjrt serves via full re-forward)"
            ),
        }
    }
}

/// A forward entry bound for KV-cached decoding, tied to the runtime's
/// scratch arena. Steps count as executions (`Runtime::exec_count`).
/// Steady-state [`DecodeSession::decode_step`]s are allocation-free
/// once the arena is warm.
pub struct DecodeSession<'p> {
    rt: &'p Runtime,
    model: DecodeModel<'p>,
    /// The binding resolved from the entry's own LoRA inputs at bind
    /// time (the single-tenant behaviour of earlier PRs); `None` when
    /// the entry is base-only or bound without a rank mask.
    default_adapter: Option<Arc<AdapterBinding>>,
}

impl DecodeSession<'_> {
    fn scratch(&self) -> &crate::ops::Scratch {
        match &self.rt.inner {
            Inner::Native(n) => n.scratch(),
            #[cfg(feature = "xla")]
            Inner::Pjrt(_) => unreachable!("decode sessions only bind on native"),
        }
    }

    /// The adapter resolved from the entry's own inputs at bind time,
    /// applied when a slot names no tenant of its own.
    pub fn default_adapter(&self) -> Option<&Arc<AdapterBinding>> {
        self.default_adapter.as_ref()
    }

    /// Whether the bound entry carries unmerged LoRA sites (tenant
    /// bindings can only apply when it does).
    pub fn supports_adapters(&self) -> bool {
        self.model.has_adapter_sites()
    }

    /// Shape-check a tenant binding against the bound base.
    pub fn check_adapter(&self, b: &AdapterBinding) -> Result<()> {
        self.model.check_adapter(b)
    }

    /// Run a prompt through `slot`'s cache column; final-position
    /// logits land in `logits` (`[vocab]`). Resets only that slot.
    /// Applies the session default adapter.
    pub fn prefill(
        &self,
        st: &mut DecodeState,
        slot: usize,
        tokens: &[i32],
        logits: &mut [f32],
    ) -> Result<()> {
        self.prefill_as(st, slot, tokens, self.default_adapter.as_deref(), logits)
    }

    /// [`DecodeSession::prefill`] under an explicit tenant binding
    /// (`None` = bare sparse base, not the session default).
    pub fn prefill_as(
        &self,
        st: &mut DecodeState,
        slot: usize,
        tokens: &[i32],
        adapter: Option<&AdapterBinding>,
        logits: &mut [f32],
    ) -> Result<()> {
        *self.rt.exec_count.borrow_mut() += 1;
        self.model.prefill(self.scratch(), st, slot, tokens, adapter, logits)
    }

    /// Advance the ascending active `slots` one token each; per-row
    /// next-token logits land in `logits` (`[slots.len(), vocab]`).
    /// Applies the session default adapter to every row.
    pub fn decode_step(
        &self,
        st: &mut DecodeState,
        slots: &[usize],
        tokens: &[i32],
        logits: &mut [f32],
    ) -> Result<()> {
        *self.rt.exec_count.borrow_mut() += 1;
        self.model.decode_step(
            self.scratch(),
            st,
            slots,
            tokens,
            RowAdapters::Uniform(self.default_adapter.as_deref()),
            logits,
        )
    }

    /// [`DecodeSession::decode_step`] with per-row tenant bindings:
    /// row `r` applies `adapters[r]` (`None` = bare sparse base).
    pub fn decode_step_rows(
        &self,
        st: &mut DecodeState,
        slots: &[usize],
        tokens: &[i32],
        adapters: &[Option<Arc<AdapterBinding>>],
        logits: &mut [f32],
    ) -> Result<()> {
        *self.rt.exec_count.borrow_mut() += 1;
        self.model.decode_step(
            self.scratch(),
            st,
            slots,
            tokens,
            RowAdapters::PerRow(adapters),
            logits,
        )
    }

    /// Vocabulary size (logits row width) of the bound entry.
    pub fn vocab(&self) -> usize {
        self.model.vocab()
    }

    /// Context-window capacity per slot.
    pub fn capacity(&self) -> usize {
        self.model.capacity()
    }
}

// ------------------------------------------------- resident param stores

/// A `ParamStore` kept resident backend-side, synced by `(name,
/// generation)`: unchanged weights keep their uploaded buffer **and**
/// its cached prepared sparse/dense structure across calls; a weight
/// whose generation bumped (prune step, optimizer update, checkpoint
/// reload) is re-uploaded, so cached structure is rebuilt exactly when
/// the weight actually changed — never stale, never re-derived
/// needlessly. Tracks one store; use one instance per store.
#[derive(Default)]
pub struct ResidentParams {
    bufs: HashMap<String, (u64, DeviceBuffer)>,
}

impl ResidentParams {
    pub fn new() -> ResidentParams {
        ResidentParams::default()
    }

    /// Upload new/changed entries, drop removed ones. Cheap no-op when
    /// nothing changed.
    pub fn sync(&mut self, rt: &Runtime, store: &ParamStore) -> Result<()> {
        self.bufs.retain(|name, _| store.contains(name));
        for (name, t, generation) in store.entries() {
            let stale = match self.bufs.get(name) {
                Some((g, _)) => *g != generation,
                None => true,
            };
            if stale {
                self.bufs.insert(name.clone(), (generation, rt.upload(t)?));
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&DeviceBuffer> {
        self.bufs.get(name).map(|(_, b)| b)
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_is_hermetic() {
        // no artifacts directory anywhere in sight
        let rt = Runtime::new("/definitely/not/a/dir").unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert!(rt.artifacts_dir().is_none());
        let m = rt.manifest().unwrap();
        assert!(m.config("tiny-llama").is_ok());
    }

    #[test]
    fn flag_selection() {
        assert_eq!(Runtime::from_flag("native", "x").unwrap().backend_name(), "native");
        assert!(Runtime::from_flag("bogus", "x").is_err());
        #[cfg(not(feature = "xla"))]
        {
            let e = Runtime::from_flag("pjrt", "x").unwrap_err();
            assert!(format!("{e:#}").contains("xla"), "{e:#}");
        }
    }

    #[test]
    fn arity_mismatch_is_an_error() {
        let rt = Runtime::native().unwrap();
        let cfgm = rt.manifest().unwrap();
        let cfg = cfgm.config("tiny-llama").unwrap();
        let entry = cfg.entry("forward_eval_base").unwrap();
        let exe = rt.load(&entry.file).unwrap();
        let t = HostTensor::zeros(&[1]);
        let e = rt.run(&exe, &[&t]).unwrap_err();
        assert!(format!("{e:#}").contains("inputs"), "{e:#}");
    }

    #[test]
    fn upload_roundtrips_on_native() {
        let rt = Runtime::native().unwrap();
        let t = HostTensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        match rt.upload(&t).unwrap() {
            DeviceBuffer::Native(nb) => {
                assert_eq!(nb.tensor, t);
                assert!(nb.prepared.borrow().is_none(), "prepared cache must be lazy");
            }
            #[cfg(feature = "xla")]
            DeviceBuffer::Pjrt(_) => panic!("native runtime returned a pjrt buffer"),
        }
    }

    #[test]
    fn resident_params_resync_only_on_generation_bump() {
        let rt = Runtime::native().unwrap();
        let mut store = ParamStore::new();
        store.insert("w", HostTensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 2.0]));
        store.insert("b", HostTensor::from_f32(&[2], vec![0.5, -0.5]));
        let mut res = ResidentParams::new();
        res.sync(&rt, &store).unwrap();
        assert_eq!(res.len(), 2);
        let before = match res.get("w").unwrap() {
            DeviceBuffer::Native(nb) => nb.tensor.clone(),
            #[cfg(feature = "xla")]
            _ => unreachable!(),
        };
        // no-change sync keeps the resident tensor identical
        res.sync(&rt, &store).unwrap();
        match res.get("w").unwrap() {
            DeviceBuffer::Native(nb) => assert_eq!(nb.tensor, before),
            #[cfg(feature = "xla")]
            _ => unreachable!(),
        }
        // mutate w (generation bump) → re-upload with the new contents
        store.get_mut("w").unwrap().f32s_mut()[0] = 9.0;
        res.sync(&rt, &store).unwrap();
        match res.get("w").unwrap() {
            DeviceBuffer::Native(nb) => assert_eq!(nb.tensor.f32s()[0], 9.0),
            #[cfg(feature = "xla")]
            _ => unreachable!(),
        }
        // removing a param drops its resident buffer on the next sync
        let mut store2 = ParamStore::new();
        store2.insert("w", HostTensor::from_f32(&[1], vec![3.0]));
        res.sync(&rt, &store2).unwrap();
        assert_eq!(res.len(), 1);
        assert!(res.get("b").is_none());
    }
}
