//! PJRT artifact executor (cargo feature `xla`): load AOT'd HLO text,
//! compile once, execute many.
//!
//! This wraps the `xla` crate exactly the way /opt/xla-example/load_hlo
//! does: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Every artifact is compiled at most once
//! per process and cached. All entry points were lowered with
//! `return_tuple=True`, so execution returns one tuple literal which is
//! decomposed into `HostTensor`s.

use crate::runtime::{Arg, DeviceBuffer};
use crate::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A compiled artifact. Cheap to clone (shared executable).
#[derive(Clone)]
pub struct PjrtExe {
    inner: Rc<xla::PjRtLoadedExecutable>,
}

/// Parse the parameter count of the ENTRY computation from HLO text.
/// The text format puts parameters as `%x = ty[...] parameter(N)` lines
/// inside the `ENTRY <name> { ... }` block.
fn hlo_entry_param_count(text: &str) -> Option<usize> {
    let start = text.lines().position(|l| l.trim_start().starts_with("ENTRY "))?;
    let mut count = 0usize;
    for line in text.lines().skip(start + 1) {
        let t = line.trim_start();
        if t.starts_with('}') {
            break;
        }
        if t.contains(" parameter(") {
            count += 1;
        }
    }
    Some(count)
}

pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, (PjrtExe, usize)>>,
}

impl PjrtBackend {
    /// CPU PJRT client over an artifacts directory (`make artifacts` output).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.join("manifest.json").exists() {
            bail!(
                "no manifest.json in {} — run `make artifacts` first",
                dir.display()
            );
        }
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        crate::info!(
            "pjrt runtime up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client, dir, cache: RefCell::new(HashMap::new()) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Load + compile an HLO text artifact (cached by file name).
    /// Returns the executable and its entry parameter count.
    pub fn load(&self, file: &str) -> Result<(PjrtExe, usize)> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let t = crate::util::log::Timer::new(&format!("compile {file}"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read HLO text {}", path.display()))?;
        let param_count = hlo_entry_param_count(&text).unwrap_or(usize::MAX);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {file}"))?;
        t.stop();
        let exe = PjrtExe { inner: Rc::new(exe) };
        self.cache
            .borrow_mut()
            .insert(file.to_string(), (exe.clone(), param_count));
        Ok((exe, param_count))
    }

    /// Upload a host tensor to a device buffer (for inputs reused across
    /// many executions — frozen base weights, masks).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        self.client
            .buffer_from_host_literal(None, &lit)
            .context("upload literal to device")
    }

    /// Literal-path execution; decomposes the output tuple.
    pub fn run(&self, exe: &PjrtExe, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let out = exe
            .inner
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {name}"))?;
        Self::unpack(out)
    }

    /// Buffer-path execution: mixed device buffers + per-call host tensors.
    /// Host tensors are uploaded for this call only; `Arg::Buf` inputs are
    /// reused device buffers (upload once via `Runtime::upload`).
    pub fn run_args(&self, exe: &PjrtExe, name: &str, inputs: &[Arg]) -> Result<Vec<HostTensor>> {
        // pass 1: upload the per-call host tensors (owned must outlive refs)
        let owned: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .filter_map(|a| match a {
                Arg::Host(t) => Some(self.upload(t)),
                Arg::Buf(_) | Arg::Absent => None,
            })
            .collect::<Result<_>>()?;
        // pass 2: assemble the argument list in order
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut k = 0usize;
        for a in inputs {
            match a {
                Arg::Buf(DeviceBuffer::Pjrt(b)) => refs.push(b),
                Arg::Buf(DeviceBuffer::Native(_)) => {
                    bail!("{name}: native device buffer passed to the pjrt backend")
                }
                Arg::Host(_) => {
                    refs.push(&owned[k]);
                    k += 1;
                }
                Arg::Absent => {
                    bail!("{name}: absent input passed to a full execution")
                }
            }
        }
        let out = exe
            .inner
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("execute_b {name}"))?;
        Self::unpack(out)
    }

    fn unpack(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        let buf = out
            .first()
            .and_then(|v| v.first())
            .context("empty execution result")?;
        let tuple = buf.to_literal_sync().context("result to literal")?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/integration.rs;
    // here we check constructor error handling and the HLO header parser.
    use super::*;

    #[test]
    fn missing_manifest_is_error() {
        let e = PjrtBackend::new("/definitely/not/a/dir");
        assert!(e.is_err());
        let msg = format!("{:#}", e.err().unwrap());
        assert!(msg.contains("manifest.json"), "{msg}");
    }

    #[test]
    fn entry_param_count_parses_text_format() {
        let hlo = "\
HloModule m\n\
\n\
region_0 {\n\
  a = f32[] parameter(0)\n\
  b = f32[] parameter(1)\n\
  ROOT s = f32[] add(a, b)\n\
}\n\
\n\
ENTRY main.5 {\n\
  p0 = f32[2,2]{1,0} parameter(0)\n\
  p1 = f32[2,2]{1,0} parameter(1)\n\
  p2 = s32[4]{0} parameter(2)\n\
  ROOT t = (f32[2,2]) tuple(p0)\n\
}\n";
        assert_eq!(hlo_entry_param_count(hlo), Some(3));
        assert_eq!(hlo_entry_param_count("no entry here"), None);
        assert_eq!(hlo_entry_param_count("ENTRY e {\n}\n"), Some(0));
    }
}
