//! Native CPU backend: executes manifest entry points in pure Rust.
//!
//! Resolution works exactly like the PJRT path — [`NativeBackend::load`]
//! takes an artifact *file name* (`tiny-llama__train_step_nls.hlo.txt`)
//! and resolves it against the built-in manifest
//! ([`crate::model::builtin`]) to a [`NativeExe`] — but execution runs
//! the `ops::` kernels instead of a compiled executable. Inputs arrive
//! positionally in the manifest's declared order and are re-keyed by
//! name, so the callers (`train`, `pruning`, `serve`, `coordinator`)
//! are backend-agnostic.
//!
//! Two pieces of cross-call state make this the fast path:
//!
//! * each [`ExecInput`] may carry the prepared-weight cache cell of its
//!   resident buffer, so the CSR/dense structure of a frozen weight —
//!   and, for train entries, the CSC companion its backward gathers
//!   through — is derived once per upload rather than once per matmul;
//! * the backend owns a [`Scratch`] arena threaded through the model,
//!   so steady-state forward/train steps reuse every intermediate
//!   buffer instead of reallocating it.
//!
//! The kernels themselves dispatch over the persistent worker pool in
//! `ops::linalg` (sized by `SHEARS_NUM_THREADS`); execution here stays
//! single-threaded at the entry-point level.
//!
//! Serving additionally gets a third piece of cross-call state:
//! [`NativeBackend::bind_decode`] resolves a plain forward entry into a
//! name-free [`DecodeModel`] (weight slices + the resident prepared
//! cells) so KV-cached prefill/decode steps skip per-call name
//! resolution entirely — see `ops::model`'s decode section.

use crate::model::{EntryPoint, Manifest, ModelConfig, PruneOpSpec};
use crate::ops::model::{
    AdapterBinding, DecodeModel, Dims, Extra, GradMode, Model, NamedTensors, PreparedCell,
};
use crate::ops::scratch::Scratch;
use crate::ops::{nn, prune};
use crate::tensor::HostTensor;
use anyhow::{bail, ensure, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// One positional execution input: the tensor plus (for resident
/// buffers) its prepared-weight cache slot.
#[derive(Clone, Copy)]
pub struct ExecInput<'a> {
    pub t: &'a HostTensor,
    pub prepared: Option<&'a PreparedCell>,
}

impl<'a> ExecInput<'a> {
    /// A per-call host tensor (no cross-call prepared cache).
    pub fn host(t: &'a HostTensor) -> ExecInput<'a> {
        ExecInput { t, prepared: None }
    }
}

/// A resolved native "executable".
pub struct NativeExe {
    pub file: String,
    pub op: NativeOp,
}

pub enum NativeOp {
    Entry {
        cfg: Box<ModelConfig>,
        name: String,
        entry: EntryPoint,
    },
    Prune(PruneOpSpec),
}

impl NativeExe {
    pub fn param_count(&self) -> usize {
        match &self.op {
            NativeOp::Entry { entry, .. } => entry.inputs.len(),
            NativeOp::Prune(spec) => spec.inputs.len(),
        }
    }

    /// Whether this op has an incremental decode path: plain forward
    /// entries only (train steps, calibration, prune ops, and the
    /// prefix/series/parallel baseline forwards do not).
    pub fn decodable(&self) -> bool {
        match &self.op {
            NativeOp::Entry { name, .. } => match entry_spec(name) {
                Ok(s) => s.train.is_none() && !s.collect && s.extra == Extra::None,
                Err(_) => false,
            },
            NativeOp::Prune(_) => false,
        }
    }
}

pub struct NativeBackend {
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<NativeExe>>>,
    /// arena reused across executions (zero-alloc steady state)
    scratch: Scratch,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend {
            manifest: Manifest::builtin(),
            cache: RefCell::new(HashMap::new()),
            scratch: Scratch::new(),
        }
    }

    /// The backend's scratch arena (bench/test introspection).
    pub fn scratch(&self) -> &Scratch {
        &self.scratch
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Resolve an artifact file name to a native op (cached).
    pub fn load(&self, file: &str) -> Result<Rc<NativeExe>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let exe = self.resolve(file)?;
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    fn resolve(&self, file: &str) -> Result<Rc<NativeExe>> {
        for cfg in self.manifest.configs.values() {
            for (name, entry) in &cfg.entrypoints {
                if entry.file == file {
                    // fail at load time (not first execution) if the
                    // manifest grew an entry this backend can't execute
                    entry_spec(name)?;
                    return Ok(Rc::new(NativeExe {
                        file: file.to_string(),
                        op: NativeOp::Entry {
                            cfg: Box::new(cfg.clone()),
                            name: name.clone(),
                            entry: entry.clone(),
                        },
                    }));
                }
            }
        }
        for spec in self.manifest.prune_ops.values() {
            if spec.file == file {
                return Ok(Rc::new(NativeExe {
                    file: file.to_string(),
                    op: NativeOp::Prune(spec.clone()),
                }));
            }
        }
        bail!("'{file}' does not name any entry point or prune op in the built-in manifest")
    }
}

impl NativeBackend {
    /// Execute a native op over positional inputs (manifest order).
    pub fn execute(&self, exe: &NativeExe, inputs: &[ExecInput]) -> Result<Vec<HostTensor>> {
        match &exe.op {
            NativeOp::Prune(spec) => run_prune(spec, inputs),
            NativeOp::Entry { cfg, name, entry } => {
                run_entry(cfg, name, entry, inputs, &self.scratch)
            }
        }
    }

    /// Bind a plain forward entry for KV-cached incremental decoding: a
    /// name-free [`DecodeModel`] holding weight slices and the resident
    /// buffers' prepared-weight cells (shared with the batch forward
    /// path, so the CSR structure of a pruned weight is derived once
    /// per upload). `inputs` align positionally with the entry's
    /// manifest signature; per-batch inputs the decode path replaces
    /// (`x`) arrive as `None`. For adapter entries also returns the
    /// default [`AdapterBinding`] resolved from the entry's own LoRA
    /// tensors and rank mask — `None` when the rank-mask input was
    /// left absent (callers then serve the bare base by default and
    /// supply per-slot tenant bindings themselves).
    pub fn bind_decode<'p>(
        &self,
        exe: &'p NativeExe,
        inputs: &[Option<ExecInput<'p>>],
    ) -> Result<(DecodeModel<'p>, Option<AdapterBinding>)> {
        let NativeOp::Entry { cfg, name, entry } = &exe.op else {
            bail!("'{}' is a prune op — nothing to decode", exe.file);
        };
        let spec = entry_spec(name)?;
        ensure!(
            spec.train.is_none() && !spec.collect && spec.extra == Extra::None,
            "entry point '{name}' has no incremental decode path (plain forwards only)"
        );
        let mut named = NamedTensors::new();
        for (io, ei) in entry.inputs.iter().zip(inputs) {
            if let Some(ei) = ei {
                match ei.prepared {
                    Some(cell) => named.insert_prepared(&io.name, ei.t, cell),
                    None => named.insert(&io.name, ei.t),
                }
            }
        }
        let model = DecodeModel::bind(cfg, &named, spec.use_adapters)?;
        let default = if spec.use_adapters && named.contains("rank_mask") {
            let binding = AdapterBinding::from_named(cfg, &named, named.f("rank_mask")?)?;
            model.check_adapter(&binding)?;
            Some(binding)
        } else {
            None
        };
        Ok((model, default))
    }
}

fn run_prune(spec: &PruneOpSpec, inputs: &[ExecInput]) -> Result<Vec<HostTensor>> {
    let mut named = NamedTensors::new();
    for (io, ei) in spec.inputs.iter().zip(inputs) {
        named.insert(&io.name, ei.t);
    }
    let (n, k) = spec.shape;
    let w = named.f("w")?;
    if w.len() != n * k {
        bail!("prune op {}: weight has {} elements, expected {n}x{k}", spec.file, w.len());
    }
    let keep = named.f("keep_frac")?[0];
    let (wp, mask) = match spec.kind.as_str() {
        "wanda" => {
            let xsq = named.f("xnorm_sq")?;
            if xsq.len() != k {
                bail!("prune op {}: xnorm_sq has {} elements, expected {k}", spec.file, xsq.len());
            }
            prune::wanda(w, xsq, keep, n, k)
        }
        "magnitude" => prune::magnitude(w, keep, n, k),
        "sparsegpt" => {
            let gram = named.f("gram")?;
            if gram.len() != k * k {
                bail!("prune op {}: gram has {} elements, expected {k}x{k}", spec.file, gram.len());
            }
            prune::sparsegpt(w, gram, keep, n, k)
        }
        other => bail!("unknown prune kind '{other}'"),
    };
    Ok(vec![
        HostTensor::from_f32(&[n, k], wp),
        HostTensor::from_f32(&[n, k], mask),
    ])
}

/// Flags describing what one entry-point variant computes.
struct EntrySpec {
    use_adapters: bool,
    extra: Extra,
    train: Option<GradMode>,
    collect: bool,
}

fn entry_spec(name: &str) -> Result<EntrySpec> {
    let spec = |use_adapters, extra, train, collect| EntrySpec { use_adapters, extra, train, collect };
    Ok(match name {
        // the pallas-lowered artifact runs distinct HLO; natively both
        // names execute the same (numerically identical) kernels
        "forward_eval" | "forward_eval_pallas" => spec(true, Extra::None, None, false),
        "forward_eval_base" => spec(false, Extra::None, None, false),
        "forward_eval_prefix" => spec(false, Extra::Prefix, None, false),
        "forward_eval_series" => spec(false, Extra::Series, None, false),
        "forward_eval_parallel" => spec(false, Extra::Parallel, None, false),
        "calib_stats" => spec(false, Extra::None, None, true),
        "train_step_nls" => spec(true, Extra::None, Some(GradMode::Adapters), false),
        "train_step_full" => spec(false, Extra::None, Some(GradMode::Base), false),
        "train_step_prefix" => spec(false, Extra::Prefix, Some(GradMode::Prefix), false),
        "train_step_series" => spec(false, Extra::Series, Some(GradMode::Series), false),
        "train_step_parallel" => spec(false, Extra::Parallel, Some(GradMode::Parallel), false),
        other => bail!("native backend does not implement entry point '{other}'"),
    })
}

fn run_entry(
    cfg: &ModelConfig,
    name: &str,
    entry: &EntryPoint,
    inputs: &[ExecInput],
    sc: &Scratch,
) -> Result<Vec<HostTensor>> {
    let spec = entry_spec(name)?;
    let mut named = NamedTensors::new();
    for (io, ei) in entry.inputs.iter().zip(inputs) {
        match ei.prepared {
            Some(cell) => named.insert_prepared(&io.name, ei.t, cell),
            None => named.insert(&io.name, ei.t),
        }
    }
    let x_t = named.get("x")?;
    if x_t.shape.len() != 2 || x_t.shape[1] != cfg.seq_len {
        bail!(
            "{name}: x has shape {:?}, expected [*, {}]",
            x_t.shape,
            cfg.seq_len
        );
    }
    let b = x_t.shape[0];
    let x = x_t.i32s();
    let dims = Dims::from_config(cfg, b);
    let rank_mask = if spec.use_adapters { Some(named.f("rank_mask")?) } else { None };
    let model = Model {
        dims,
        p: &named,
        use_adapters: spec.use_adapters,
        rank_mask,
        extra: spec.extra,
    };

    let Some(mode) = spec.train else {
        // forward-only entries (eval forwards + calib_stats)
        let fwd = model.forward_scratch(sc, x, false, spec.collect)?;
        if spec.collect {
            let mut outs = Vec::with_capacity(fwd.stats.len() * 2);
            for (site, sumsq, gram) in fwd.stats {
                let dim = sumsq.len();
                outs.push(HostTensor::from_f32(&[dim], sumsq));
                outs.push(HostTensor::from_f32(&[dim, dim], gram));
            }
            return Ok(outs);
        }
        return Ok(vec![HostTensor::from_f32(
            &[b, cfg.seq_len, cfg.vocab],
            fwd.logits,
        )]);
    };

    // fused train step: forward + backward + AdamW (+ mask re-application)
    let step = named.f("step")?[0];
    let lr = named.f("lr")?[0];
    let y = named.get("y")?.i32s();
    let loss_mask = named.f("loss_mask")?;
    let (loss, mut grads) = model.loss_and_grads_scratch(sc, x, y, loss_mask, mode)?;
    let weight_decay = if mode == GradMode::Base { 0.01 } else { 0.0 };

    let mut new_p: HashMap<&str, Vec<f32>> = HashMap::new();
    let mut new_m: HashMap<&str, Vec<f32>> = HashMap::new();
    let mut new_v: HashMap<&str, Vec<f32>> = HashMap::new();
    for out in &entry.outputs {
        let pname = out.name.as_str();
        if pname == "loss" || pname.starts_with("m.") || pname.starts_with("v.") {
            continue;
        }
        let mut p = named.f(pname)?.to_vec();
        let mut m = named.f(&format!("m.{pname}"))?.to_vec();
        let mut v = named.f(&format!("v.{pname}"))?.to_vec();
        let g = grads.take(pname, p.len());
        if g.len() != p.len() {
            bail!("{name}: gradient/param size mismatch for '{pname}'");
        }
        nn::adamw(&mut p, &g, &mut m, &mut v, step, lr, weight_decay);
        sc.give(g);
        // keep pruned weights (and their optimizer state) at exactly zero
        let mask_name = format!("mask.{pname}");
        if named.contains(&mask_name) {
            let mask = named.f(&mask_name)?;
            for i in 0..p.len() {
                p[i] *= mask[i];
                m[i] *= mask[i];
                v[i] *= mask[i];
            }
        }
        new_p.insert(pname, p);
        new_m.insert(pname, m);
        new_v.insert(pname, v);
    }
    let mut outs = Vec::with_capacity(entry.outputs.len());
    for out in &entry.outputs {
        let oname = out.name.as_str();
        let t = if oname == "loss" {
            HostTensor::scalar_f32(loss)
        } else if let Some(rest) = oname.strip_prefix("m.") {
            HostTensor::from_f32(&out.shape, new_m.remove(rest).context("missing m state")?)
        } else if let Some(rest) = oname.strip_prefix("v.") {
            HostTensor::from_f32(&out.shape, new_v.remove(rest).context("missing v state")?)
        } else {
            HostTensor::from_f32(&out.shape, new_p.remove(oname).context("missing updated param")?)
        };
        outs.push(t);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_entry_and_prune_files() {
        let be = NativeBackend::new();
        let e = be.load("tiny-llama__forward_eval_base.hlo.txt").unwrap();
        assert!(matches!(&e.op, NativeOp::Entry { name, .. } if name == "forward_eval_base"));
        assert!(e.param_count() > 1);
        let p = be.load("prune__wanda_48x48.hlo.txt").unwrap();
        assert!(matches!(&p.op, NativeOp::Prune(s) if s.kind == "wanda"));
        assert_eq!(p.param_count(), 3);
        assert!(be.load("nope.hlo.txt").is_err());
        // cache: same Rc handed back
        assert_eq!(be.compiled_count(), 2);
        let _ = be.load("prune__wanda_48x48.hlo.txt").unwrap();
        assert_eq!(be.compiled_count(), 2);
    }

    #[test]
    fn unknown_entry_kind_is_rejected() {
        assert!(entry_spec("train_step_quantum").is_err());
        assert!(entry_spec("forward_eval_pallas").is_ok());
    }
}
