//! Hand-rolled CLI parser (offline substitute for `clap`, DESIGN.md §3).
//!
//! Grammar: `shears <subcommand> [--flag value]... [--switch]...`
//! Flags are declared up front so typos fail fast with usage output.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Declared flag: name, default (None = required), help.
pub struct FlagSpec {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl Args {
    /// Parse argv against declared flags; unknown flags error.
    pub fn parse(
        argv: &[String],
        known_flags: &[FlagSpec],
        known_switches: &[&str],
    ) -> Result<Args> {
        if argv.is_empty() {
            bail!("missing subcommand");
        }
        let subcommand = argv[0].clone();
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            if known_switches.contains(&name) {
                switches.push(name.to_string());
                i += 1;
                continue;
            }
            let Some(spec) = known_flags.iter().find(|f| f.name == name) else {
                bail!("unknown flag --{name}");
            };
            let Some(value) = argv.get(i + 1) else {
                bail!("flag --{} needs a value ({})", spec.name, spec.help);
            };
            flags.insert(name.to_string(), value.clone());
            i += 2;
        }
        // apply defaults / check required
        for spec in known_flags {
            if !flags.contains_key(spec.name) {
                match spec.default {
                    Some(d) => {
                        flags.insert(spec.name.to_string(), d.to_string());
                    }
                    None => bail!("missing required flag --{} ({})", spec.name, spec.help),
                }
            }
        }
        Ok(Args { subcommand, flags, switches })
    }

    pub fn get(&self, name: &str) -> &str {
        self.flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    /// Byte-size flag: a plain count, or with a `k`/`m`/`g` suffix
    /// (binary multiples, case-insensitive) — `64k`, `2M`, `1g`.
    pub fn get_bytes(&self, name: &str) -> Result<usize> {
        parse_bytes(self.get(name))
            .map_err(|e| anyhow::anyhow!("flag --{name}: {e:#}"))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Parse `"512"`, `"64k"`, `"2M"`, `"1g"` into a byte count
/// (binary multiples). Overflow and junk suffixes are errors.
pub fn parse_bytes(s: &str) -> Result<usize> {
    let s = s.trim();
    let (digits, shift) = match s.char_indices().last() {
        Some((i, 'k' | 'K')) => (&s[..i], 10),
        Some((i, 'm' | 'M')) => (&s[..i], 20),
        Some((i, 'g' | 'G')) => (&s[..i], 30),
        _ => (s, 0),
    };
    let n: usize = digits.trim().parse()?;
    n.checked_shl(shift)
        .filter(|v| v >> shift == n)
        .ok_or_else(|| anyhow::anyhow!("byte size '{s}' overflows"))
}

pub fn usage(flags: &[FlagSpec], switches: &[&str]) -> String {
    let mut out = String::from("flags:\n");
    for f in flags {
        out.push_str(&format!(
            "  --{:<18} {} {}\n",
            f.name,
            f.help,
            f.default.map(|d| format!("(default {d})")).unwrap_or_else(|| "(required)".into())
        ));
    }
    for s in switches {
        out.push_str(&format!("  --{s:<18} (switch)\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "config", default: Some("tiny-llama"), help: "model config" },
            FlagSpec { name: "steps", default: None, help: "train steps" },
        ]
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_defaults_switches() {
        let a = Args::parse(
            &argv(&["train", "--steps", "100", "--verbose"]),
            &flags(),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("config"), "tiny-llama");
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert_eq!(a.get_u64("steps").unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_required_flag_errors() {
        assert!(Args::parse(&argv(&["train"]), &flags(), &[]).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&argv(&["t", "--steps", "1", "--bogus", "2"]), &flags(), &[]).is_err());
    }

    #[test]
    fn flag_without_value_errors() {
        assert!(Args::parse(&argv(&["t", "--steps"]), &flags(), &[]).is_err());
    }

    #[test]
    fn byte_sizes_parse_with_binary_suffixes() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("512").unwrap(), 512);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("2M").unwrap(), 2 << 20);
        assert_eq!(parse_bytes("1g").unwrap(), 1 << 30);
        assert!(parse_bytes("12q").is_err());
        assert!(parse_bytes("").is_err());
    }
}
