//! `shears-lint` — run the crate-native static-analysis pass
//! ([`shears::analysis`]) over this crate's own `src/` tree and exit
//! nonzero on any diagnostic. Wired into CI as a blocking leg and into
//! tier-1 via `tests/lints.rs`; `shears lint` is the same pass.

fn main() {
    let report = match shears::analysis::lint_self() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("shears-lint: cannot read crate sources: {e}");
            std::process::exit(2);
        }
    };
    for d in &report.diags {
        println!("{d}");
    }
    println!(
        "shears-lint: {} file(s), {} diagnostic(s), allowlist {}/{} entries used",
        report.files,
        report.diags.len(),
        report.allow_used,
        report.allow_total
    );
    if !report.diags.is_empty() {
        std::process::exit(1);
    }
}
