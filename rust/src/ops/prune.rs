//! Native prune ops: Wanda, magnitude, SparseGPT-lite.
//!
//! Ports of `python/compile/prune.py` / `kernels/ref.py` with identical
//! semantics (per-row top-k by score with a `>=`-threshold mask, so score
//! ties keep both entries — exactly like the lowered artifacts). The
//! SparseGPT-lite column-sweep (OBS error compensation over the upper
//! Cholesky factor of H⁻¹, Frantar & Alistarh 2023 Eq. 3/4) is ported
//! loop-for-loop from the jnp version, including its hand-rolled
//! Cholesky/triangular-inverse (no LAPACK anywhere).

use crate::ops::linalg;

/// `round(k·keep)` clipped to `[1, k]`, with jnp's round-half-to-even.
fn n_keep(k: usize, keep_frac: f32) -> usize {
    let x = k as f64 * keep_frac as f64;
    let floor = x.floor();
    let frac = x - floor;
    let r = if (frac - 0.5).abs() < 1e-9 {
        if (floor as i64) % 2 == 0 {
            floor
        } else {
            floor + 1.0
        }
    } else {
        x.round()
    };
    (r as usize).clamp(1, k)
}

/// Per-row `{0,1}` mask keeping entries whose score reaches the row's
/// `n_keep`-th largest score (ties inclusive, matching `_row_topk_mask`).
fn row_topk_mask(scores: &[f32], keep_frac: f32, n: usize, k: usize) -> Vec<f32> {
    let keep = n_keep(k, keep_frac);
    let mut mask = vec![0.0f32; n * k];
    let mut sorted = vec![0.0f32; k];
    for row in 0..n {
        let sr = &scores[row * k..(row + 1) * k];
        sorted.copy_from_slice(sr);
        sorted.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
        let thresh = sorted[keep - 1];
        for (j, mv) in mask[row * k..(row + 1) * k].iter_mut().enumerate() {
            if sr[j] >= thresh {
                *mv = 1.0;
            }
        }
    }
    mask
}

/// Wanda (paper Eq. 1): score `S = |W| · ‖X‖₂` per row; `xnorm_sq` is the
/// calibration-accumulated Σx² (the sqrt happens here, like the artifact).
pub fn wanda(w: &[f32], xnorm_sq: &[f32], keep_frac: f32, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let xnorm: Vec<f32> = xnorm_sq.iter().map(|v| v.sqrt()).collect();
    let scores: Vec<f32> = w
        .iter()
        .enumerate()
        .map(|(i, wv)| wv.abs() * xnorm[i % k])
        .collect();
    let mask = row_topk_mask(&scores, keep_frac, n, k);
    let wp = w.iter().zip(&mask).map(|(wv, mv)| wv * mv).collect();
    (wp, mask)
}

/// Per-row magnitude pruning (`S = |W|`), the classical baseline.
pub fn magnitude(w: &[f32], keep_frac: f32, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let scores: Vec<f32> = w.iter().map(|v| v.abs()).collect();
    let mask = row_topk_mask(&scores, keep_frac, n, k);
    let wp = w.iter().zip(&mask).map(|(wv, mv)| wv * mv).collect();
    (wp, mask)
}

/// Right-looking Cholesky factor L (a = L·Lᵀ), ported from
/// `prune._chol_lower` including its clamping.
fn chol_lower(a: &[f32], k: usize) -> Vec<f32> {
    let mut a = a.to_vec();
    for j in 0..k {
        let d = a[j * k + j].max(1e-20).sqrt();
        // col = a[:, j] / d, zeroed at i < j, col[j] = d
        let mut col = vec![0.0f32; k];
        for i in 0..k {
            if i > j {
                col[i] = a[i * k + j] / d;
            }
        }
        col[j] = d;
        // rank-1 downdate over the strictly-below part
        for i in 0..k {
            let ci = if i > j { col[i] } else { 0.0 };
            if ci == 0.0 {
                continue;
            }
            for l in 0..k {
                let cl = if l > j { col[l] } else { 0.0 };
                a[i * k + l] -= ci * cl;
            }
        }
        for i in 0..k {
            a[i * k + j] = col[i];
        }
    }
    // tril
    for i in 0..k {
        for j in i + 1..k {
            a[i * k + j] = 0.0;
        }
    }
    a
}

/// Inverse of a lower-triangular matrix by forward substitution
/// (`prune._tril_inv`).
fn tril_inv(l: &[f32], k: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; k * k];
    for i in 0..k {
        // acc = (l[i, :i]) @ x  (rows of x above i are already filled)
        let mut acc = vec![0.0f32; k];
        for j in 0..i {
            let lv = l[i * k + j];
            if lv == 0.0 {
                continue;
            }
            for c in 0..k {
                acc[c] += lv * x[j * k + c];
            }
        }
        let d = l[i * k + i];
        for c in 0..k {
            let e = if c == i { 1.0 } else { 0.0 };
            x[i * k + c] = (e - acc[c]) / d;
        }
    }
    x
}

/// SparseGPT-lite: up-front mask from `w²/diag(U)²`, then the OBS
/// column-sequential error-compensation sweep over `U` (upper Cholesky
/// factor of H⁻¹).
pub fn sparsegpt(w: &[f32], gram: &[f32], keep_frac: f32, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    const DAMP: f32 = 0.01;
    let trace: f32 = (0..k).map(|i| gram[i * k + i]).sum();
    let lambda = DAMP * (trace / k as f32 + 1e-6);
    let mut h = gram.to_vec();
    for i in 0..k {
        h[i * k + i] += lambda;
    }
    let linv = tril_inv(&chol_lower(&h, k), k);
    // hinv = linvᵀ @ linv
    let hinv = linalg::matmul_tn(&linv, &linv, k, k, k);
    // u = chol_lower(hinv)ᵀ  (upper: hinv = uᵀ·u)
    let lc = chol_lower(&hinv, k);
    let mut u = vec![0.0f32; k * k];
    for i in 0..k {
        for j in 0..k {
            u[i * k + j] = lc[j * k + i];
        }
    }
    let d: Vec<f32> = (0..k).map(|j| u[j * k + j].max(1e-10)).collect();
    let scores: Vec<f32> = w
        .iter()
        .enumerate()
        .map(|(i, wv)| {
            let dj = d[i % k];
            wv * wv / (dj * dj)
        })
        .collect();
    let mask = row_topk_mask(&scores, keep_frac, n, k);
    let mut wp = w.to_vec();
    for j in 0..k {
        let ujj = u[j * k + j];
        let urow = &u[j * k..(j + 1) * k];
        for row in 0..n {
            let e = if mask[row * k + j] > 0.0 {
                0.0
            } else {
                wp[row * k + j] / ujj
            };
            if e == 0.0 {
                continue;
            }
            let wr = &mut wp[row * k..(row + 1) * k];
            for (wv, uv) in wr.iter_mut().zip(urow) {
                *wv -= e * uv;
            }
        }
    }
    for (wv, mv) in wp.iter_mut().zip(&mask) {
        *wv *= mv;
    }
    (wp, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_keep_rounds_half_to_even() {
        assert_eq!(n_keep(10, 0.5), 5);
        assert_eq!(n_keep(10, 0.45), 4); // 4.5 -> 4 (even)
        assert_eq!(n_keep(10, 0.55), 6); // 5.5 -> 6 (even)
        assert_eq!(n_keep(10, 0.0), 1); // clip low
        assert_eq!(n_keep(10, 2.0), 10); // clip high
    }

    #[test]
    fn magnitude_keeps_largest_per_row() {
        let w = vec![0.1, -5.0, 0.2, 3.0, /* row 2 */ 1.0, -0.5, 0.01, -2.0];
        let (wp, mask) = magnitude(&w, 0.5, 2, 4);
        assert_eq!(&mask[..4], &[0.0, 1.0, 0.0, 1.0]);
        assert_eq!(&mask[4..], &[1.0, 0.0, 0.0, 1.0]);
        assert_eq!(wp[0], 0.0);
        assert_eq!(wp[1], -5.0);
    }

    #[test]
    fn wanda_weights_by_activation_norm() {
        // |w| equal everywhere; the activation norm decides what survives
        let w = vec![1.0f32; 6];
        let xsq = vec![9.0, 1.0, 0.01];
        let (_, mask) = wanda(&w, &xsq, 0.34, 2, 3); // keep 1 of 3
        assert_eq!(&mask[..3], &[1.0, 0.0, 0.0]);
        assert_eq!(&mask[3..], &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn cholesky_and_inverse_roundtrip() {
        // spd matrix a = b bᵀ + I
        let k = 4;
        let b: Vec<f32> = (0..k * k).map(|i| ((i * 7 % 5) as f32) * 0.3).collect();
        let mut a = linalg::matmul_nt(&b, &b, k, k, k);
        for i in 0..k {
            a[i * k + i] += 1.0;
        }
        let l = chol_lower(&a, k);
        // l @ lᵀ == a
        let re = linalg::matmul_nt(&l, &l, k, k, k);
        for (x, y) in re.iter().zip(&a) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        // l @ inv(l) == I
        let li = tril_inv(&l, k);
        let eye = linalg::matmul_nn(&l, &li, k, k, k);
        for i in 0..k {
            for j in 0..k {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye[i * k + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sparsegpt_hits_row_sparsity_and_masks_align() {
        let n = 6;
        let k = 8;
        let w: Vec<f32> = (0..n * k).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.1).collect();
        let x: Vec<f32> = (0..3 * k).map(|i| ((i * 5 % 11) as f32 - 5.0) * 0.2).collect();
        let gram = linalg::matmul_tn(&x, &x, 3, k, k);
        let (wp, mask) = sparsegpt(&w, &gram, 0.5, n, k);
        for row in 0..n {
            let nz = mask[row * k..(row + 1) * k].iter().filter(|m| **m > 0.0).count();
            assert_eq!(nz, 4, "row {row}");
            for j in 0..k {
                if mask[row * k + j] == 0.0 {
                    assert_eq!(wp[row * k + j], 0.0);
                }
            }
        }
    }
}
