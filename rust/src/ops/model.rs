//! Native decoder forward/backward: the pure-Rust implementation of the
//! L2 model (`python/compile/model.py`) that the native backend executes.
//!
//! One [`Model`] handles every entry-point variant: llama-sim (RMSNorm,
//! RoPE, SwiGLU) and mpt-sim (LayerNorm, ALiBi, GELU), elastic-LoRA
//! adapters gated by a rank mask, the prefix/series/parallel PEFT
//! baselines, Wanda/SparseGPT calibration-statistics collection, and the
//! hand-derived backward pass for each trainable group (adapters, full
//! base, prefix, series, parallel).
//!
//! The hot path runs on the prepared-weight kernel engine: linear
//! weights resolve through [`NamedTensors::prepared`] to a cached
//! [`PreparedWeight`] (CSR for pruned weights, register-blocked dense
//! otherwise) built once per resident buffer, and every intermediate
//! buffer comes from a [`Scratch`] arena so steady-state forward/train
//! steps perform no per-matmul heap allocation (only the entry-point
//! boundary tensors — logits, updated params — still allocate). The
//! backward pass is sparsity-aware too: `dx = dy @ W` for a frozen
//! pruned weight routes through the cached CSC companion of the same
//! `PreparedWeight` ([`Model::matw_bwd`]), so a 50%-sparse base weight
//! costs half the multiply-accumulates in training as well as in the
//! forward. The `forward`/`loss_and_grads` wrappers keep the original
//! signatures for fixture tests and host-tensor callers.
//!
//! The backward formulas are validated two ways: golden fixtures from
//! `python/compile/fixtures.py` pin the numerics against `jax.grad` in
//! `rust/tests/parity.rs`, and finite-difference checks cover the local
//! vjps in `ops::nn`. Accumulation order differs from XLA, so agreement
//! is to f32 round-off, not bit-exact.

use crate::model::ModelConfig;
use crate::ops::linalg::{self, add_assign, axpy, PreparedWeight};
use crate::ops::nn;
use crate::ops::scratch::Scratch;
use crate::tensor::HostTensor;
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

/// Lazily-built prepared weight slot, owned by a resident buffer
/// (`runtime::DeviceBuffer`) and shared into [`NamedTensors`] by
/// reference. `None` until the first matmul touches the weight.
pub type PreparedCell = RefCell<Option<Rc<PreparedWeight>>>;

/// Name → tensor view over one entry point's positional inputs, plus
/// (for resident buffers) the prepared-weight cache cells.
#[derive(Default)]
pub struct NamedTensors<'a> {
    map: HashMap<&'a str, &'a HostTensor>,
    prepared: HashMap<&'a str, &'a PreparedCell>,
}

impl<'a> NamedTensors<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &'a str, t: &'a HostTensor) {
        self.map.insert(name, t);
    }

    /// Register a tensor together with its prepared-weight cache slot
    /// (resident buffers: the slot outlives this call set, so the CSR /
    /// dense decision is made once per upload, not once per matmul).
    pub fn insert_prepared(&mut self, name: &'a str, t: &'a HostTensor, cell: &'a PreparedCell) {
        self.map.insert(name, t);
        self.prepared.insert(name, cell);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Result<&'a HostTensor> {
        self.map
            .get(name)
            .copied()
            .with_context(|| format!("native entry input '{name}' missing"))
    }

    pub fn f(&self, name: &str) -> Result<&'a [f32]> {
        Ok(self.get(name)?.f32s())
    }

    /// Cached prepared form of weight `name` (`[n, k]`), built on first
    /// use. `None` when the tensor arrived without a cache slot (plain
    /// host tensor) — callers then fall back to the per-call path.
    pub fn prepared(&self, name: &str, n: usize, k: usize) -> Result<Option<Rc<PreparedWeight>>> {
        let Some(cell) = self.prepared.get(name) else {
            return Ok(None);
        };
        let mut slot = cell.borrow_mut();
        if let Some(pw) = slot.as_ref() {
            if pw.n == n && pw.k == k {
                return Ok(Some(pw.clone()));
            }
        }
        let w = self.f(name)?;
        let pw = Rc::new(PreparedWeight::build(w, n, k));
        *slot = Some(pw.clone());
        Ok(Some(pw))
    }
}

/// Model dimensions resolved for one batch.
#[derive(Clone, Debug)]
pub struct Dims {
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub nh: usize,
    pub dh: usize,
    pub f: usize,
    pub v: usize,
    pub r: usize,
    pub n_layers: usize,
    pub llama: bool,
    pub plen: usize,
    pub bn: usize,
    pub scale: f32,
    pub mods: Vec<String>,
}

impl Dims {
    pub fn from_config(cfg: &ModelConfig, batch: usize) -> Dims {
        Dims {
            b: batch,
            s: cfg.seq_len,
            d: cfg.d_model,
            nh: cfg.n_heads,
            dh: cfg.d_model / cfg.n_heads,
            f: cfg.d_ff,
            v: cfg.vocab,
            r: cfg.max_rank,
            n_layers: cfg.n_layers,
            llama: cfg.arch == "llama",
            plen: cfg.prefix_len,
            bn: cfg.bottleneck,
            scale: cfg.lora_scale(),
            mods: cfg.adapter_modules.clone(),
        }
    }
}

/// Which PEFT baseline (if any) is active in the forward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Extra {
    None,
    Prefix,
    Series,
    Parallel,
}

/// Which parameter group the backward pass produces gradients for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMode {
    Adapters,
    Base,
    Prefix,
    Series,
    Parallel,
}

/// Accumulating gradient store keyed by parameter name.
#[derive(Default)]
pub struct Grads {
    pub map: HashMap<String, Vec<f32>>,
}

impl Grads {
    /// Accumulate `g` under `name`; a buffer made redundant by an
    /// existing accumulator goes back to the arena.
    fn add(&mut self, sc: &Scratch, name: &str, g: Vec<f32>) {
        match self.map.get_mut(name) {
            Some(acc) => {
                add_assign(acc, &g);
                sc.give(g);
            }
            None => {
                self.map.insert(name.to_string(), g);
            }
        }
    }

    pub fn take(&mut self, name: &str, numel: usize) -> Vec<f32> {
        self.map.remove(name).unwrap_or_else(|| vec![0.0; numel])
    }
}

enum NormTape {
    /// cached 1/rms per row (llama)
    Rms(Vec<f32>),
    /// cached normalized input + 1/σ per row (mpt)
    Ln { xhat: Vec<f32>, inv: Vec<f32> },
}

impl NormTape {
    fn release(self, sc: &Scratch) {
        match self {
            NormTape::Rms(inv) => sc.give(inv),
            NormTape::Ln { xhat, inv } => {
                sc.give(xhat);
                sc.give(inv);
            }
        }
    }
}

struct LayerTape {
    h_in: Vec<f32>,
    norm1: NormTape,
    t_attn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>,
    ctx: Vec<f32>,
    h_mid: Vec<f32>,
    norm2: NormTape,
    t_mlp: Vec<f32>,
    g_pre: Vec<f32>,
    u_pre: Vec<f32>,
    act: Vec<f32>,
    lora_p: HashMap<String, Vec<f32>>,
    s_out_in: Vec<f32>,
    s_zpre: Vec<f32>,
    s_z: Vec<f32>,
    p_zpre: Vec<f32>,
    p_z: Vec<f32>,
}

impl LayerTape {
    /// Hand every cached activation back to the arena.
    fn release(self, sc: &Scratch) {
        for v in [
            self.h_in, self.t_attn, self.q, self.k, self.v, self.probs, self.ctx, self.h_mid,
            self.t_mlp, self.g_pre, self.u_pre, self.act, self.s_out_in, self.s_zpre, self.s_z,
            self.p_zpre, self.p_z,
        ] {
            sc.give(v);
        }
        self.norm1.release(sc);
        self.norm2.release(sc);
        for (_, p) in self.lora_p {
            sc.give(p);
        }
    }
}

struct Tape {
    layers: Vec<LayerTape>,
    h_final_in: Vec<f32>,
    norm_f: NormTape,
    t_final: Vec<f32>,
}

/// Forward output: logits plus (optionally) calibration stats and the
/// activation tape for the backward pass.
pub struct Forward {
    /// `[B, S, V]` row-major
    pub logits: Vec<f32>,
    /// per-site (Σx², Gram) in `calib_sites` order
    pub stats: Vec<(String, Vec<f32>, Vec<f32>)>,
    tape: Option<Tape>,
}

/// One forward/backward construction over resolved named tensors.
pub struct Model<'a> {
    pub dims: Dims,
    pub p: &'a NamedTensors<'a>,
    pub use_adapters: bool,
    pub rank_mask: Option<&'a [f32]>,
    pub extra: Extra,
}

impl<'a> Model<'a> {
    /// `y = x @ wᵀ` for weight `name`: cached prepared representation
    /// when the weight is resident, per-call scan-and-dispatch otherwise
    /// (the original behavior for plain host tensors).
    fn matw(
        &self,
        name: &str,
        x: &[f32],
        m: usize,
        out_dim: usize,
        in_dim: usize,
        y: &mut [f32],
    ) -> Result<()> {
        let w = self.p.f(name)?;
        match self.p.prepared(name, out_dim, in_dim)? {
            Some(pw) => linalg::matmul_nt_prepared_into(x, w, &pw, m, y),
            None => linalg::matmul_nt_auto_into(x, w, m, in_dim, out_dim, y),
        }
        Ok(())
    }

    /// `dx = dy @ w` for weight `name` (`[out_dim, in_dim]` row-major):
    /// the backward companion of [`Model::matw`]. Resident pruned
    /// weights take the cached CSC gather (skipping the zeros); dense
    /// or unprepared host weights take the dense axpy kernel, which is
    /// what the per-call path always did.
    fn matw_bwd(
        &self,
        name: &str,
        dy: &[f32],
        m: usize,
        out_dim: usize,
        in_dim: usize,
        dx: &mut [f32],
    ) -> Result<()> {
        let w = self.p.f(name)?;
        match self.p.prepared(name, out_dim, in_dim)? {
            Some(pw) => linalg::matmul_nn_prepared_into(dy, w, &pw, m, dx),
            None => linalg::matmul_nn_into(dy, w, m, out_dim, in_dim, dx),
        }
        Ok(())
    }

    fn norm_fwd(
        &self,
        sc: &Scratch,
        x: &[f32],
        name: &str,
        m: usize,
    ) -> Result<(Vec<f32>, NormTape)> {
        let d = self.dims.d;
        let g = self.p.f(&format!("{name}.g"))?;
        let mut y = sc.take(m * d);
        let mut inv = sc.take(m);
        if self.dims.llama {
            nn::rmsnorm_into(x, g, m, d, &mut y, &mut inv);
            Ok((y, NormTape::Rms(inv)))
        } else {
            let b = self.p.f(&format!("{name}.b"))?;
            let mut xhat = sc.take(m * d);
            nn::layernorm_into(x, g, b, m, d, &mut y, &mut xhat, &mut inv);
            Ok((y, NormTape::Ln { xhat, inv }))
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn norm_bwd(
        &self,
        sc: &Scratch,
        dy: &[f32],
        x: &[f32],
        name: &str,
        tape: &NormTape,
        m: usize,
        grads: &mut Grads,
        mode: GradMode,
    ) -> Result<Vec<f32>> {
        let d = self.dims.d;
        let g = self.p.f(&format!("{name}.g"))?;
        let mut dx = sc.take(m * d);
        match tape {
            NormTape::Rms(inv) => {
                let mut dg = sc.take(d);
                nn::rmsnorm_bwd_into(dy, x, g, inv, m, d, &mut dx, &mut dg);
                if mode == GradMode::Base {
                    grads.add(sc, &format!("{name}.g"), dg);
                } else {
                    sc.give(dg);
                }
                Ok(dx)
            }
            NormTape::Ln { xhat, inv } => {
                let mut dg = sc.take(d);
                let mut db = sc.take(d);
                nn::layernorm_bwd_into(dy, g, xhat, inv, m, d, &mut dx, &mut dg, &mut db);
                if mode == GradMode::Base {
                    grads.add(sc, &format!("{name}.g"), dg);
                    grads.add(sc, &format!("{name}.b"), db);
                } else {
                    sc.give(dg);
                    sc.give(db);
                }
                Ok(dx)
            }
        }
    }

    /// Adapter-aware linear `y = x @ Wᵀ (+ scale · ((x@Aᵀ)·mask) @ Bᵀ)`.
    /// Returns `(y, p)` where `p` is the masked LoRA projection (tape).
    fn lin_fwd(
        &self,
        sc: &Scratch,
        x: &[f32],
        m: usize,
        wname: &str,
        out_dim: usize,
        in_dim: usize,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
        let mut y = sc.take(m * out_dim);
        self.matw(wname, x, m, out_dim, in_dim, &mut y)?;
        if !self.use_adapters {
            return Ok((y, None));
        }
        let Some(idx) = self.dims.mods.iter().position(|mo| mo == wname) else {
            return Ok((y, None));
        };
        let r = self.dims.r;
        let a = self.p.f(&format!("lora_a.{wname}"))?;
        let b = self.p.f(&format!("lora_b.{wname}"))?;
        let rm = self.rank_mask.context("adapter forward needs a rank mask")?;
        let rm = &rm[idx * r..(idx + 1) * r];
        let mut proj = sc.take(m * r);
        linalg::matmul_nt_into(x, a, m, in_dim, r, &mut proj);
        for row in 0..m {
            for (j, pv) in proj[row * r..(row + 1) * r].iter_mut().enumerate() {
                *pv *= rm[j];
            }
        }
        let mut yl = sc.take(m * out_dim);
        linalg::matmul_nt_into(&proj, b, m, r, out_dim, &mut yl);
        axpy(&mut y, self.dims.scale, &yl);
        sc.give(yl);
        Ok((y, Some(proj)))
    }

    /// Backward of `lin_fwd`; accumulates adapter/base grads per `mode`
    /// and returns `dx`.
    #[allow(clippy::too_many_arguments)]
    fn lin_bwd(
        &self,
        sc: &Scratch,
        dy: &[f32],
        x: &[f32],
        m: usize,
        wname: &str,
        out_dim: usize,
        in_dim: usize,
        lora_p: &HashMap<String, Vec<f32>>,
        grads: &mut Grads,
        mode: GradMode,
    ) -> Result<Vec<f32>> {
        let mut dx = sc.take(m * in_dim);
        self.matw_bwd(wname, dy, m, out_dim, in_dim, &mut dx)?;
        if let Some(proj) = lora_p.get(wname) {
            let r = self.dims.r;
            let idx = self.dims.mods.iter().position(|mo| mo == wname).unwrap();
            let a = self.p.f(&format!("lora_a.{wname}"))?;
            let b = self.p.f(&format!("lora_b.{wname}"))?;
            let rm = self.rank_mask.context("adapter backward needs a rank mask")?;
            let rm = &rm[idx * r..(idx + 1) * r];
            let scale = self.dims.scale;
            let mut dp = sc.take(m * r);
            linalg::matmul_nn_into(dy, b, m, out_dim, r, &mut dp);
            for row in 0..m {
                for (j, dpv) in dp[row * r..(row + 1) * r].iter_mut().enumerate() {
                    *dpv *= rm[j] * scale;
                }
            }
            let mut dxl = sc.take(m * in_dim);
            linalg::matmul_nn_into(&dp, a, m, r, in_dim, &mut dxl);
            add_assign(&mut dx, &dxl);
            sc.give(dxl);
            if mode == GradMode::Adapters {
                let mut da = sc.take(r * in_dim);
                linalg::matmul_tn_into(&dp, x, m, r, in_dim, &mut da);
                let mut db = sc.take(out_dim * r);
                linalg::matmul_tn_into(dy, proj, m, out_dim, r, &mut db);
                for dv in db.iter_mut() {
                    *dv *= scale;
                }
                grads.add(sc, &format!("lora_a.{wname}"), da);
                grads.add(sc, &format!("lora_b.{wname}"), db);
            }
            sc.give(dp);
        }
        if mode == GradMode::Base {
            let mut dw = sc.take(out_dim * in_dim);
            linalg::matmul_tn_into(dy, x, m, out_dim, in_dim, &mut dw);
            grads.add(sc, wname, dw);
        }
        Ok(dx)
    }

    /// RoPE rotation tables (llama): `(cos, sin)` of shape `[S, dh/2]`.
    fn rope_tables(&self, sc: &Scratch) -> (Vec<f32>, Vec<f32>) {
        let (s, half) = (self.dims.s, self.dims.dh / 2);
        let mut cos = sc.take(s * half);
        let mut sin = sc.take(s * half);
        fill_rope_tables(&mut cos, &mut sin, s, half);
        (cos, sin)
    }

    /// Apply RoPE in place over `[B, H, S, dh]` head-major data.
    fn rope_apply(&self, x: &mut [f32], cos: &[f32], sin: &[f32], backward: bool) {
        let Dims { b, s, nh, dh, .. } = self.dims;
        let half = dh / 2;
        for bh in 0..b * nh {
            for si in 0..s {
                let off = (bh * s + si) * dh;
                for j in 0..half {
                    let (c, sn) = (cos[si * half + j], sin[si * half + j]);
                    let x1 = x[off + j];
                    let x2 = x[off + half + j];
                    if backward {
                        // transpose of the rotation
                        x[off + j] = x1 * c + x2 * sn;
                        x[off + half + j] = -x1 * sn + x2 * c;
                    } else {
                        x[off + j] = x1 * c - x2 * sn;
                        x[off + half + j] = x1 * sn + x2 * c;
                    }
                }
            }
        }
    }

    /// `[M, d]` row-major → `[B, H, S, dh]` head-major.
    fn split_heads(&self, sc: &Scratch, x: &[f32]) -> Vec<f32> {
        let Dims { b, s, d, nh, dh, .. } = self.dims;
        let mut out = sc.take(b * nh * s * dh);
        for bi in 0..b {
            for si in 0..s {
                let row = &x[(bi * s + si) * d..(bi * s + si + 1) * d];
                for h in 0..nh {
                    let dst = ((bi * nh + h) * s + si) * dh;
                    out[dst..dst + dh].copy_from_slice(&row[h * dh..(h + 1) * dh]);
                }
            }
        }
        out
    }

    /// `[B, H, S, dh]` head-major → `[M, d]` row-major.
    fn merge_heads(&self, sc: &Scratch, x: &[f32]) -> Vec<f32> {
        let Dims { b, s, d, nh, dh, .. } = self.dims;
        let mut out = sc.take(b * s * d);
        for bi in 0..b {
            for h in 0..nh {
                for si in 0..s {
                    let src = ((bi * nh + h) * s + si) * dh;
                    let dst = (bi * s + si) * d + h * dh;
                    out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
                }
            }
        }
        out
    }

    fn alibi_slope(&self, h: usize) -> f32 {
        alibi_slope(h, self.dims.nh)
    }

    /// Record a calibration site: `(Σx² per feature, Gram XᵀX)`. These
    /// escape into the entry outputs, so they allocate (one-shot
    /// calibration, not the steady-state loop).
    fn record(
        stats: &mut Vec<(String, Vec<f32>, Vec<f32>)>,
        site: String,
        x: &[f32],
        m: usize,
        dim: usize,
    ) {
        let mut sumsq = vec![0.0f32; dim];
        for row in 0..m {
            for (j, v) in x[row * dim..(row + 1) * dim].iter().enumerate() {
                sumsq[j] += v * v;
            }
        }
        let gram = linalg::matmul_tn(x, x, m, dim, dim);
        stats.push((site, sumsq, gram));
    }

    /// Full forward pass with per-call buffers (fixture tests, host-path
    /// callers). The backend hot path uses [`Model::forward_scratch`].
    pub fn forward(&self, x_ids: &[i32], want_tape: bool, collect: bool) -> Result<Forward> {
        self.forward_scratch(&Scratch::new(), x_ids, want_tape, collect)
    }

    /// Full forward pass over a caller-owned scratch arena. `want_tape`
    /// caches activations for the backward pass; `collect` records
    /// calibration statistics.
    pub fn forward_scratch(
        &self,
        sc: &Scratch,
        x_ids: &[i32],
        want_tape: bool,
        collect: bool,
    ) -> Result<Forward> {
        let Dims { b, s, d, nh, dh, f, v, plen, .. } = self.dims;
        debug_assert_eq!(x_ids.len(), b * s);
        let m = b * s;
        let embed = self.p.f("embed")?;
        let mut h = sc.take(m * d);
        for (mi, tok) in x_ids.iter().enumerate() {
            let t = *tok as usize;
            debug_assert!(t < v, "token id {t} >= vocab {v}");
            h[mi * d..(mi + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
        }
        let (cos, sin) =
            if self.dims.llama { self.rope_tables(sc) } else { (Vec::new(), Vec::new()) };
        let use_prefix = self.extra == Extra::Prefix;
        let skv = if use_prefix { plen + s } else { s };
        let mut stats = Vec::new();
        let mut layers: Vec<LayerTape> = Vec::with_capacity(self.dims.n_layers);

        for i in 0..self.dims.n_layers {
            let mut lora_p = HashMap::new();
            let h_in = h;
            let (t_attn, norm1) = self.norm_fwd(sc, &h_in, &format!("layers.{i}.attn_norm"), m)?;
            if collect {
                Self::record(&mut stats, format!("{i}.attn_in"), &t_attn, m, d);
            }
            let pre = format!("layers.{i}.attn.");
            let lin3 = |name: &str, tape: &mut HashMap<String, Vec<f32>>| -> Result<Vec<f32>> {
                let wname = format!("{pre}{name}");
                let (y, p) = self.lin_fwd(sc, &t_attn, m, &wname, d, d)?;
                if let Some(p) = p {
                    tape.insert(wname, p);
                }
                Ok(y)
            };
            let qf = lin3("q", &mut lora_p)?;
            let kf = lin3("k", &mut lora_p)?;
            let vf = lin3("v", &mut lora_p)?;
            let mut q = self.split_heads(sc, &qf);
            let k_base = {
                let mut k3 = self.split_heads(sc, &kf);
                if self.dims.llama {
                    self.rope_apply(&mut k3, &cos, &sin, false);
                }
                k3
            };
            if self.dims.llama {
                self.rope_apply(&mut q, &cos, &sin, false);
            }
            let v_base = self.split_heads(sc, &vf);
            sc.give(qf);
            sc.give(kf);
            sc.give(vf);
            // assemble (optionally prefix-extended) K/V in [B,H,Skv,dh]
            let (k3, v3) = if use_prefix {
                let pk = self.p.f(&format!("prefix_k.{i}"))?; // [H, P, dh]
                let pv = self.p.f(&format!("prefix_v.{i}"))?;
                let mut kx = sc.take(b * nh * skv * dh);
                let mut vx = sc.take(b * nh * skv * dh);
                for bi in 0..b {
                    for hh in 0..nh {
                        let dst = (bi * nh + hh) * skv * dh;
                        let psrc = hh * plen * dh;
                        kx[dst..dst + plen * dh].copy_from_slice(&pk[psrc..psrc + plen * dh]);
                        vx[dst..dst + plen * dh].copy_from_slice(&pv[psrc..psrc + plen * dh]);
                        let bsrc = ((bi * nh + hh) * s) * dh;
                        kx[dst + plen * dh..dst + skv * dh]
                            .copy_from_slice(&k_base[bsrc..bsrc + s * dh]);
                        vx[dst + plen * dh..dst + skv * dh]
                            .copy_from_slice(&v_base[bsrc..bsrc + s * dh]);
                    }
                }
                sc.give(k_base);
                sc.give(v_base);
                (kx, vx)
            } else {
                (k_base, v_base)
            };
            // scores → probs → ctx
            let inv_sqrt = 1.0 / (dh as f32).sqrt();
            let mut probs = sc.take(b * nh * s * skv);
            let mut ctx = sc.take(m * d);
            for bi in 0..b {
                for hh in 0..nh {
                    let bh = bi * nh + hh;
                    let slope = if self.dims.llama { 0.0 } else { self.alibi_slope(hh) };
                    for si in 0..s {
                        let qrow = &q[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        let prow = &mut probs[(bh * s + si) * skv..(bh * s + si + 1) * skv];
                        for (t, pv) in prow.iter_mut().enumerate() {
                            let allowed = t < plen_of(use_prefix, plen)
                                || t - plen_of(use_prefix, plen) <= si;
                            if !allowed {
                                *pv = -1e30;
                                continue;
                            }
                            let krow = &k3[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            let mut sc_ = linalg::dot(qrow, krow) * inv_sqrt;
                            if !self.dims.llama {
                                let pos_k = t as f32 - plen_of(use_prefix, plen) as f32;
                                sc_ += slope * -(pos_k - si as f32).abs();
                            }
                            *pv = sc_;
                        }
                        nn::softmax_row(prow);
                        let crow = &mut ctx
                            [(bi * s + si) * d + hh * dh..(bi * s + si) * d + (hh + 1) * dh];
                        for t in 0..skv {
                            let pv = prow[t];
                            if pv == 0.0 {
                                continue;
                            }
                            let vrow = &v3[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (cv, vv) in crow.iter_mut().zip(vrow) {
                                *cv += pv * vv;
                            }
                        }
                    }
                }
            }
            if collect {
                Self::record(&mut stats, format!("{i}.o_in"), &ctx, m, d);
            }
            let (attn_out, o_p) = self.lin_fwd(sc, &ctx, m, &format!("{pre}o"), d, d)?;
            if let Some(p) = o_p {
                lora_p.insert(format!("{pre}o"), p);
            }
            let mut h_mid = sc.take(m * d);
            h_mid.copy_from_slice(&h_in);
            add_assign(&mut h_mid, &attn_out);
            sc.give(attn_out);
            let (t_mlp, norm2) = self.norm_fwd(sc, &h_mid, &format!("layers.{i}.mlp_norm"), m)?;
            if collect {
                Self::record(&mut stats, format!("{i}.mlp_in"), &t_mlp, m, d);
            }
            let mpre = format!("layers.{i}.mlp.");
            let (g_pre, u_pre, act) = if self.dims.llama {
                let (gp, gt) = self.lin_fwd(sc, &t_mlp, m, &format!("{mpre}gate"), f, d)?;
                if let Some(p) = gt {
                    lora_p.insert(format!("{mpre}gate"), p);
                }
                let (up, ut) = self.lin_fwd(sc, &t_mlp, m, &format!("{mpre}up"), f, d)?;
                if let Some(p) = ut {
                    lora_p.insert(format!("{mpre}up"), p);
                }
                let mut act = sc.take(m * f);
                for ((av, g), u) in act.iter_mut().zip(&gp).zip(&up) {
                    *av = nn::silu(*g) * u;
                }
                (gp, up, act)
            } else {
                let (up, ut) = self.lin_fwd(sc, &t_mlp, m, &format!("{mpre}up"), f, d)?;
                if let Some(p) = ut {
                    lora_p.insert(format!("{mpre}up"), p);
                }
                let mut act = sc.take(m * f);
                for (av, u) in act.iter_mut().zip(&up) {
                    *av = nn::gelu(*u);
                }
                (Vec::new(), up, act)
            };
            if collect {
                Self::record(&mut stats, format!("{i}.down_in"), &act, m, f);
            }
            let (mut out, d_p) = self.lin_fwd(sc, &act, m, &format!("{mpre}down"), d, f)?;
            if let Some(p) = d_p {
                lora_p.insert(format!("{mpre}down"), p);
            }
            // series adapter: bottleneck after the MLP output
            let (s_out_in, s_zpre, s_z) = if self.extra == Extra::Series {
                let sd = self.p.f(&format!("series_down.{i}"))?;
                let su = self.p.f(&format!("series_up.{i}"))?;
                let bn = self.dims.bn;
                let mut zpre = sc.take(m * bn);
                linalg::matmul_nt_into(&out, sd, m, d, bn, &mut zpre);
                let mut z = sc.take(m * bn);
                for (zv, zp) in z.iter_mut().zip(&zpre) {
                    *zv = zp.max(0.0);
                }
                let mut add = sc.take(m * d);
                linalg::matmul_nt_into(&z, su, m, bn, d, &mut add);
                let mut out_in = sc.take(m * d);
                out_in.copy_from_slice(&out);
                add_assign(&mut out, &add);
                sc.give(add);
                (out_in, zpre, z)
            } else {
                (Vec::new(), Vec::new(), Vec::new())
            };
            // parallel adapter: bottleneck beside the MLP
            let (p_zpre, p_z) = if self.extra == Extra::Parallel {
                let pd = self.p.f(&format!("parallel_down.{i}"))?;
                let pu = self.p.f(&format!("parallel_up.{i}"))?;
                let bn = self.dims.bn;
                let mut zpre = sc.take(m * bn);
                linalg::matmul_nt_into(&t_mlp, pd, m, d, bn, &mut zpre);
                let mut z = sc.take(m * bn);
                for (zv, zp) in z.iter_mut().zip(&zpre) {
                    *zv = zp.max(0.0);
                }
                let mut add = sc.take(m * d);
                linalg::matmul_nt_into(&z, pu, m, bn, d, &mut add);
                add_assign(&mut out, &add);
                sc.give(add);
                (zpre, z)
            } else {
                (Vec::new(), Vec::new())
            };
            h = sc.take(m * d);
            h.copy_from_slice(&h_mid);
            add_assign(&mut h, &out);
            sc.give(out);
            let tape = LayerTape {
                h_in,
                norm1,
                t_attn,
                q,
                k: k3,
                v: v3,
                probs,
                ctx,
                h_mid,
                norm2,
                t_mlp,
                g_pre,
                u_pre,
                act,
                lora_p,
                s_out_in,
                s_zpre,
                s_z,
                p_zpre,
                p_z,
            };
            if want_tape {
                layers.push(tape);
            } else {
                tape.release(sc);
            }
        }
        sc.give(cos);
        sc.give(sin);
        let h_final_in = h;
        let (t_final, norm_f) = self.norm_fwd(sc, &h_final_in, "final_norm", m)?;
        let mut logits = sc.take(m * v);
        self.matw("lm_head", &t_final, m, v, d, &mut logits)?;
        let tape = if want_tape {
            Some(Tape { layers, h_final_in, norm_f, t_final })
        } else {
            sc.give(h_final_in);
            sc.give(t_final);
            norm_f.release(sc);
            None
        };
        Ok(Forward { logits, stats, tape })
    }

    /// Masked cross-entropy loss + gradients with per-call buffers.
    pub fn loss_and_grads(
        &self,
        x_ids: &[i32],
        y_ids: &[i32],
        loss_mask: &[f32],
        mode: GradMode,
    ) -> Result<(f32, Grads)> {
        self.loss_and_grads_scratch(&Scratch::new(), x_ids, y_ids, loss_mask, mode)
    }

    /// Masked cross-entropy loss + gradients for `mode`'s parameter
    /// group, over a caller-owned scratch arena. Every tape and
    /// temporary buffer returns to the arena before this returns; only
    /// the gradient tensors themselves leave (the caller hands them
    /// back after the optimizer update).
    pub fn loss_and_grads_scratch(
        &self,
        sc: &Scratch,
        x_ids: &[i32],
        y_ids: &[i32],
        loss_mask: &[f32],
        mode: GradMode,
    ) -> Result<(f32, Grads)> {
        let mut fwd = self.forward_scratch(sc, x_ids, true, false)?;
        let Tape { mut layers, h_final_in, norm_f, t_final } =
            fwd.tape.take().expect("tape requested");
        let Dims { b, s, d, nh, dh, f, v, plen, .. } = self.dims;
        let m = b * s;
        let mut dlogits = sc.take(m * v);
        let loss = nn::softmax_xent_into(&fwd.logits, y_ids, loss_mask, m, v, &mut dlogits);
        sc.give(std::mem::take(&mut fwd.logits));
        let mut grads = Grads::default();

        if mode == GradMode::Base {
            let mut dw = sc.take(v * d);
            linalg::matmul_tn_into(&dlogits, &t_final, m, v, d, &mut dw);
            grads.add(sc, "lm_head", dw);
        }
        let mut dt_final = sc.take(m * d);
        self.matw_bwd("lm_head", &dlogits, m, v, d, &mut dt_final)?;
        sc.give(dlogits);
        let mut dh = self.norm_bwd(
            sc,
            &dt_final,
            &h_final_in,
            "final_norm",
            &norm_f,
            m,
            &mut grads,
            mode,
        )?;
        sc.give(dt_final);
        sc.give(h_final_in);
        sc.give(t_final);
        norm_f.release(sc);
        let (cos, sin) =
            if self.dims.llama { self.rope_tables(sc) } else { (Vec::new(), Vec::new()) };
        let use_prefix = self.extra == Extra::Prefix;
        let skv = if use_prefix { plen + s } else { s };

        for i in (0..self.dims.n_layers).rev() {
            let lc = layers.pop().expect("layer tape");
            let mpre = format!("layers.{i}.mlp.");
            let mut dt2 = sc.take(m * d);
            if self.extra == Extra::Parallel {
                let bn = self.dims.bn;
                let pd = self.p.f(&format!("parallel_down.{i}"))?;
                let pu = self.p.f(&format!("parallel_up.{i}"))?;
                let mut dzp = sc.take(m * bn);
                linalg::matmul_nn_into(&dh, pu, m, d, bn, &mut dzp);
                for (dz, zp) in dzp.iter_mut().zip(&lc.p_zpre) {
                    if *zp <= 0.0 {
                        *dz = 0.0;
                    }
                }
                if mode == GradMode::Parallel {
                    let mut dpu = sc.take(d * bn);
                    linalg::matmul_tn_into(&dh, &lc.p_z, m, d, bn, &mut dpu);
                    grads.add(sc, &format!("parallel_up.{i}"), dpu);
                    let mut dpd = sc.take(bn * d);
                    linalg::matmul_tn_into(&dzp, &lc.t_mlp, m, bn, d, &mut dpd);
                    grads.add(sc, &format!("parallel_down.{i}"), dpd);
                }
                let mut dtp = sc.take(m * d);
                linalg::matmul_nn_into(&dzp, pd, m, bn, d, &mut dtp);
                add_assign(&mut dt2, &dtp);
                sc.give(dtp);
                sc.give(dzp);
            }
            let mut ddo_owned: Option<Vec<f32>> = None;
            if self.extra == Extra::Series {
                let bn = self.dims.bn;
                let sd = self.p.f(&format!("series_down.{i}"))?;
                let su = self.p.f(&format!("series_up.{i}"))?;
                let mut dz = sc.take(m * bn);
                linalg::matmul_nn_into(&dh, su, m, d, bn, &mut dz);
                for (dzv, zp) in dz.iter_mut().zip(&lc.s_zpre) {
                    if *zp <= 0.0 {
                        *dzv = 0.0;
                    }
                }
                if mode == GradMode::Series {
                    let mut dsu = sc.take(d * bn);
                    linalg::matmul_tn_into(&dh, &lc.s_z, m, d, bn, &mut dsu);
                    grads.add(sc, &format!("series_up.{i}"), dsu);
                    let mut dsd = sc.take(bn * d);
                    linalg::matmul_tn_into(&dz, &lc.s_out_in, m, bn, d, &mut dsd);
                    grads.add(sc, &format!("series_down.{i}"), dsd);
                }
                let mut ddo = sc.take(m * d);
                ddo.copy_from_slice(&dh);
                let mut dsx = sc.take(m * d);
                linalg::matmul_nn_into(&dz, sd, m, bn, d, &mut dsx);
                add_assign(&mut ddo, &dsx);
                sc.give(dsx);
                sc.give(dz);
                ddo_owned = Some(ddo);
            }
            let d_down_out: &[f32] = ddo_owned.as_deref().unwrap_or(&dh);
            let dact = self.lin_bwd(
                sc,
                d_down_out,
                &lc.act,
                m,
                &format!("{mpre}down"),
                d,
                f,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            if let Some(ddo) = ddo_owned {
                sc.give(ddo);
            }
            if self.dims.llama {
                let mut dg_pre = sc.take(m * f);
                let mut du_pre = sc.take(m * f);
                for j in 0..m * f {
                    dg_pre[j] = dact[j] * lc.u_pre[j] * nn::dsilu(lc.g_pre[j]);
                    du_pre[j] = dact[j] * nn::silu(lc.g_pre[j]);
                }
                let dg = self.lin_bwd(
                    sc, &dg_pre, &lc.t_mlp, m, &format!("{mpre}gate"), f, d, &lc.lora_p,
                    &mut grads, mode,
                )?;
                add_assign(&mut dt2, &dg);
                sc.give(dg);
                let du = self.lin_bwd(
                    sc, &du_pre, &lc.t_mlp, m, &format!("{mpre}up"), f, d, &lc.lora_p, &mut grads,
                    mode,
                )?;
                add_assign(&mut dt2, &du);
                sc.give(du);
                sc.give(dg_pre);
                sc.give(du_pre);
            } else {
                let mut du_pre = sc.take(m * f);
                for j in 0..m * f {
                    du_pre[j] = dact[j] * nn::dgelu(lc.u_pre[j]);
                }
                let du = self.lin_bwd(
                    sc, &du_pre, &lc.t_mlp, m, &format!("{mpre}up"), f, d, &lc.lora_p, &mut grads,
                    mode,
                )?;
                add_assign(&mut dt2, &du);
                sc.give(du);
                sc.give(du_pre);
            }
            sc.give(dact);
            let mut dh_mid = sc.take(m * d);
            dh_mid.copy_from_slice(&dh);
            let dn2 = self.norm_bwd(
                sc,
                &dt2,
                &lc.h_mid,
                &format!("layers.{i}.mlp_norm"),
                &lc.norm2,
                m,
                &mut grads,
                mode,
            )?;
            add_assign(&mut dh_mid, &dn2);
            sc.give(dn2);
            sc.give(dt2);

            // ---- attention block ----
            let pre = format!("layers.{i}.attn.");
            let dctx = self.lin_bwd(
                sc,
                &dh_mid,
                &lc.ctx,
                m,
                &format!("{pre}o"),
                d,
                d,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            let mut dq = sc.take(b * nh * s * dh);
            let mut dkx = sc.take(b * nh * skv * dh);
            let mut dvx = sc.take(b * nh * skv * dh);
            let inv_sqrt = 1.0 / (dh as f32).sqrt();
            let mut dprow = sc.take(skv);
            let mut dsrow = sc.take(skv);
            for bi in 0..b {
                for hh in 0..nh {
                    let bh = bi * nh + hh;
                    for si in 0..s {
                        let dc = &dctx
                            [(bi * s + si) * d + hh * dh..(bi * s + si) * d + (hh + 1) * dh];
                        let prow = &lc.probs[(bh * s + si) * skv..(bh * s + si + 1) * skv];
                        for t in 0..skv {
                            let vrow = &lc.v[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            dprow[t] = linalg::dot(dc, vrow);
                            let pv = prow[t];
                            if pv != 0.0 {
                                let dvr =
                                    &mut dvx[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                                for (dvv, dcv) in dvr.iter_mut().zip(dc) {
                                    *dvv += pv * dcv;
                                }
                            }
                        }
                        nn::softmax_row_bwd(&dprow, prow, &mut dsrow);
                        let dqr = &mut dq[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        let qrow = &lc.q[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        for t in 0..skv {
                            let ds = dsrow[t] * inv_sqrt;
                            if ds == 0.0 {
                                continue;
                            }
                            let krow = &lc.k[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (dqv, kv) in dqr.iter_mut().zip(krow) {
                                *dqv += ds * kv;
                            }
                            let dkr = &mut dkx[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (dkv, qv) in dkr.iter_mut().zip(qrow) {
                                *dkv += ds * qv;
                            }
                        }
                    }
                }
            }
            sc.give(dprow);
            sc.give(dsrow);
            sc.give(dctx);
            // split off prefix grads, keep the sequence part
            let (mut dk, dv) = if use_prefix {
                if mode == GradMode::Prefix {
                    let mut dpk = sc.take(nh * plen * dh);
                    let mut dpv = sc.take(nh * plen * dh);
                    for bi in 0..b {
                        for hh in 0..nh {
                            let src = (bi * nh + hh) * skv * dh;
                            let dst = hh * plen * dh;
                            add_assign(
                                &mut dpk[dst..dst + plen * dh],
                                &dkx[src..src + plen * dh],
                            );
                            add_assign(
                                &mut dpv[dst..dst + plen * dh],
                                &dvx[src..src + plen * dh],
                            );
                        }
                    }
                    grads.add(sc, &format!("prefix_k.{i}"), dpk);
                    grads.add(sc, &format!("prefix_v.{i}"), dpv);
                }
                let mut dk = sc.take(b * nh * s * dh);
                let mut dv = sc.take(b * nh * s * dh);
                for bh in 0..b * nh {
                    let src = bh * skv * dh + plen * dh;
                    let dst = bh * s * dh;
                    dk[dst..dst + s * dh].copy_from_slice(&dkx[src..src + s * dh]);
                    dv[dst..dst + s * dh].copy_from_slice(&dvx[src..src + s * dh]);
                }
                sc.give(dkx);
                sc.give(dvx);
                (dk, dv)
            } else {
                (dkx, dvx)
            };
            if self.dims.llama {
                self.rope_apply(&mut dq, &cos, &sin, true);
                self.rope_apply(&mut dk, &cos, &sin, true);
            }
            let dqf = self.merge_heads(sc, &dq);
            let dkf = self.merge_heads(sc, &dk);
            let dvf = self.merge_heads(sc, &dv);
            sc.give(dq);
            sc.give(dk);
            sc.give(dv);
            let mut dt1 = self.lin_bwd(
                sc,
                &dqf,
                &lc.t_attn,
                m,
                &format!("{pre}q"),
                d,
                d,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            let dtk = self.lin_bwd(
                sc,
                &dkf,
                &lc.t_attn,
                m,
                &format!("{pre}k"),
                d,
                d,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            add_assign(&mut dt1, &dtk);
            sc.give(dtk);
            let dtv = self.lin_bwd(
                sc,
                &dvf,
                &lc.t_attn,
                m,
                &format!("{pre}v"),
                d,
                d,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            add_assign(&mut dt1, &dtv);
            sc.give(dtv);
            sc.give(dqf);
            sc.give(dkf);
            sc.give(dvf);
            sc.give(std::mem::replace(&mut dh, dh_mid));
            let dn1 = self.norm_bwd(
                sc,
                &dt1,
                &lc.h_in,
                &format!("layers.{i}.attn_norm"),
                &lc.norm1,
                m,
                &mut grads,
                mode,
            )?;
            add_assign(&mut dh, &dn1);
            sc.give(dn1);
            sc.give(dt1);
            lc.release(sc);
        }
        sc.give(cos);
        sc.give(sin);
        if mode == GradMode::Base {
            let mut dembed = sc.take(v * d);
            for (mi, tok) in x_ids.iter().enumerate() {
                let t = *tok as usize;
                add_assign(&mut dembed[t * d..(t + 1) * d], &dh[mi * d..(mi + 1) * d]);
            }
            grads.add(sc, "embed", dembed);
        }
        sc.give(dh);
        Ok((loss, grads))
    }
}

/// Effective prefix length of the causal window (0 when prefix is off).
#[inline]
fn plen_of(use_prefix: bool, plen: usize) -> usize {
    if use_prefix {
        plen
    } else {
        0
    }
}

/// ALiBi slope of head `h` out of `nh` — one definition shared by the
/// batch forward and the decode path, like [`fill_rope_tables`].
fn alibi_slope(h: usize, nh: usize) -> f32 {
    2.0f32.powf(-8.0 * (h + 1) as f32 / nh as f32)
}

/// Fill RoPE rotation tables of shape `[s, half]`. The one definition
/// shared by the batch forward ([`Model::rope_tables`]) and the decode
/// cache ([`DecodeState::new`]), so positional parity between the two
/// paths is structural, not a convention.
fn fill_rope_tables(cos: &mut [f32], sin: &mut [f32], s: usize, half: usize) {
    for si in 0..s {
        for j in 0..half {
            let freq = 1.0 / 10000.0f32.powf(j as f32 / half as f32);
            let ang = si as f32 * freq;
            cos[si * half + j] = ang.cos();
            sin[si * half + j] = ang.sin();
        }
    }
}

// ------------------------------------------------- KV-cached decoding
//
// The serving-path engine: instead of re-running a full `[B, S]` padded
// forward per generated token, [`DecodeModel::prefill`] runs the prompt
// once (populating per-layer K/V caches) and [`DecodeModel::decode_step`]
// advances every active sequence by one token — batched `M = active`
// matmuls through the frozen sparse base and the unmerged LoRA adapters,
// RoPE/ALiBi applied at each row's absolute position, attention reduced
// against the cached K/V with the same `linalg::dot` SIMD reductions the
// full forward uses.
//
// Numerical contract: every kernel call and accumulation loop mirrors
// [`Model::forward_scratch`] exactly — score rows are padded to the full
// `seq_len` window with `-1e30` before `softmax_row` so the softmax
// reduction sees the same lane layout, and matmul rows are
// block/partition-invariant — so prefill + decode steps reproduce the
// padded re-forward logits for the same positions (greedy decode picks
// identical tokens).
//
// [`DecodeModel`] is a *name-free binding*: weight slices, cached
// [`PreparedWeight`]s, LoRA A/B slices, and rank-mask windows are
// resolved from [`NamedTensors`] once at bind time, so the steady-state
// step does no hashing, no `format!`, and — over a warm [`Scratch`]
// arena — no heap allocation at all (`rust/tests/alloc_count.rs` pins
// this). Rebind after weights change (`ForwardSession::sync`).

/// Per-layer, per-slot K/V cache columns for incremental decoding.
///
/// Layout per layer: `[slots, heads, cap, head_dim]` row-major, where
/// `cap == seq_len` of the model configuration. Each batch slot owns a
/// column of the cache plus its own length, so continuous-batching
/// admission resets exactly the joining slot ([`DecodeState::reset`] /
/// the implicit reset in [`DecodeModel::prefill`]) and never disturbs
/// in-flight neighbors.
pub struct DecodeState {
    slots: usize,
    cap: usize,
    nh: usize,
    dh: usize,
    n_layers: usize,
    llama: bool,
    /// per layer `[slots * nh * cap * dh]` roped key rows
    kc: Vec<Vec<f32>>,
    /// per layer `[slots * nh * cap * dh]` value rows
    vc: Vec<Vec<f32>>,
    /// tokens cached per slot
    len: Vec<usize>,
    /// RoPE tables `[cap, dh/2]` (empty for ALiBi archs)
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl DecodeState {
    /// Allocate caches for `slots` concurrent sequences of `cfg`'s
    /// window length. This is the one allocating call of the decode
    /// path; steps reuse it for the decoder's lifetime.
    pub fn new(cfg: &ModelConfig, slots: usize) -> DecodeState {
        let (nh, cap) = (cfg.n_heads, cfg.seq_len);
        let dh = cfg.d_model / nh;
        let llama = cfg.arch == "llama";
        let per_layer = slots * nh * cap * dh;
        let (cos, sin) = if llama {
            let half = dh / 2;
            let mut cos = vec![0.0f32; cap * half];
            let mut sin = vec![0.0f32; cap * half];
            fill_rope_tables(&mut cos, &mut sin, cap, half);
            (cos, sin)
        } else {
            (Vec::new(), Vec::new())
        };
        DecodeState {
            slots,
            cap,
            nh,
            dh,
            n_layers: cfg.n_layers,
            llama,
            kc: (0..cfg.n_layers).map(|_| vec![0.0f32; per_layer]).collect(),
            vc: (0..cfg.n_layers).map(|_| vec![0.0f32; per_layer]).collect(),
            len: vec![0; slots],
            cos,
            sin,
        }
    }

    /// Drop `slot`'s cached context (admission of a new request).
    pub fn reset(&mut self, slot: usize) {
        self.len[slot] = 0;
    }

    /// Tokens currently cached for `slot`.
    pub fn cached_len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// Concurrent sequence capacity.
    pub fn n_slots(&self) -> usize {
        self.slots
    }

    /// Context-window capacity per slot (the config's `seq_len`).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// One adapter target's LoRA weights plus its window of the elastic
/// rank mask: A `[rank, inp]`, B `[out, rank]`, mask `[active]` with
/// `active <= rank`. Sites are ordered by the module's position in
/// `ModelConfig::adapter_modules`. A/B live behind `Arc`s so a prefix
/// sub-binding ([`AdapterBinding::prefix`]) shares its parent's
/// buffers and applies a rank-truncated window of them in place —
/// NLS's prefix nesting means truncation IS the sub-adapter.
#[derive(Clone, Debug)]
pub struct AdapterSite {
    a: Arc<Vec<f32>>,
    b: Arc<Vec<f32>>,
    mask: Vec<f32>,
    /// physical rank of the stored A/B buffers (B's row stride); the
    /// active rank window is `mask.len()`
    rank: usize,
    out: usize,
    inp: usize,
}

/// A tenant's complete sub-adapter, detached from any one decoder:
/// owned LoRA A/B copies for every adapter target plus the tenant's
/// NLS rank-mask windows. One shared [`DecodeModel`] base serves many
/// bindings — each slot of a batched [`DecodeModel::decode_step`] can
/// apply its own, so mixed-tenant batches share the base matmuls,
/// KV cache, and prepared-weight cells built in earlier PRs.
#[derive(Clone, Debug)]
pub struct AdapterBinding {
    sites: Vec<AdapterSite>,
    bytes: usize,
}

impl AdapterBinding {
    /// Resolve one tenant's sub-adapter from an entry's LoRA tensors
    /// plus that tenant's rank-mask values
    /// (`[n_modules * max_rank]`, see `nls::SearchSpace::rank_mask`).
    pub fn from_named(
        cfg: &ModelConfig,
        p: &NamedTensors,
        rank_mask: &[f32],
    ) -> Result<AdapterBinding> {
        let r = cfg.max_rank;
        let mods = &cfg.adapter_modules;
        ensure!(
            rank_mask.len() == mods.len() * r,
            "rank mask holds {} values, expected {} modules x max rank {r}",
            rank_mask.len(),
            mods.len()
        );
        let mut sites = Vec::with_capacity(mods.len());
        let mut bytes = std::mem::size_of::<AdapterBinding>();
        for (idx, name) in mods.iter().enumerate() {
            let at = p.get(&format!("lora_a.{name}"))?;
            let bt = p.get(&format!("lora_b.{name}"))?;
            ensure!(
                at.shape.len() == 2 && at.shape[0] == r,
                "adapter bind: lora_a.{name} has shape {:?}, expected [{r}, inp]",
                at.shape
            );
            ensure!(
                bt.shape.len() == 2 && bt.shape[1] == r,
                "adapter bind: lora_b.{name} has shape {:?}, expected [out, {r}]",
                bt.shape
            );
            let site = AdapterSite {
                a: Arc::new(at.f32s().to_vec()),
                b: Arc::new(bt.f32s().to_vec()),
                mask: rank_mask[idx * r..(idx + 1) * r].to_vec(),
                rank: r,
                out: bt.shape[0],
                inp: at.shape[1],
            };
            bytes += std::mem::size_of::<AdapterSite>()
                + (site.a.len() + site.b.len() + site.mask.len()) * std::mem::size_of::<f32>();
            sites.push(site);
        }
        Ok(AdapterBinding { sites, bytes })
    }

    /// Approximate resident size (owned weight copies + masks) — the
    /// unit of the serving registry's byte budget.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// A site-less binding with a synthetic byte size — registry
    /// accounting tests only (fails [`DecodeModel::check_adapter`]).
    #[doc(hidden)]
    pub fn synthetic(bytes: usize) -> AdapterBinding {
        AdapterBinding { sites: Vec::new(), bytes }
    }

    /// Number of adapter target sites.
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    /// Derive the prefix sub-binding keeping `ceil(fraction * active)`
    /// ranks (min 1) of every site's mask window — the brownout
    /// controller's degradation rung. A/B buffers are **shared**
    /// (`Arc` clones): the sub-binding reads rank-truncated windows of
    /// its parent's weights in place, so deriving one allocates only
    /// the truncated mask copies. NLS prefix nesting
    /// (`rank_mask_is_prefix`) makes the truncation a legitimate
    /// sub-adapter, not an arbitrary projection. `fraction >= 1`
    /// yields a full-window clone (still sharing buffers).
    pub fn prefix(&self, fraction: f32) -> AdapterBinding {
        let f = if fraction.is_finite() { fraction.clamp(0.0, 1.0) } else { 1.0 };
        let mut sites = Vec::with_capacity(self.sites.len());
        let mut bytes = std::mem::size_of::<AdapterBinding>();
        for s in &self.sites {
            let keep = ((f * s.mask.len() as f32).ceil() as usize).clamp(1, s.mask.len());
            let site = AdapterSite {
                a: Arc::clone(&s.a),
                b: Arc::clone(&s.b),
                mask: s.mask[..keep].to_vec(),
                rank: s.rank,
                out: s.out,
                inp: s.inp,
            };
            bytes += std::mem::size_of::<AdapterSite>()
                + site.mask.len() * std::mem::size_of::<f32>();
            sites.push(site);
        }
        AdapterBinding { sites, bytes }
    }

    /// Largest active rank window across sites — the per-slot load
    /// unit the serving fault injector's `rankdelay` kind scales by
    /// (a degraded prefix sub-binding reports a smaller value than
    /// its parent).
    pub fn active_rank(&self) -> usize {
        self.sites.iter().map(|s| s.mask.len()).max().unwrap_or(0)
    }

    /// Active over physical rank, summed across sites — `1.0` for a
    /// full binding, smaller for a prefix sub-binding; reported on
    /// degraded [`crate::serve::GenResponse`]s.
    pub fn rank_fraction(&self) -> f32 {
        let phys: usize = self.sites.iter().map(|s| s.rank).sum();
        if phys == 0 {
            return 1.0;
        }
        let act: usize = self.sites.iter().map(|s| s.mask.len()).sum();
        act as f32 / phys as f32
    }
}

/// Which adapter each row of a decode batch applies (`None` rows run
/// the bare sparse base).
#[derive(Clone, Copy)]
pub enum RowAdapters<'b> {
    /// Every row shares one binding (or none) — prefill, and
    /// single-tenant decode.
    Uniform(Option<&'b AdapterBinding>),
    /// Row `r` applies `rows[r]` — mixed-tenant decode. `Arc` so the
    /// engine's reused per-step buffer clones without allocating.
    PerRow(&'b [Option<Arc<AdapterBinding>>]),
}

/// One linear of the decode path, resolved at bind time: weight slice,
/// the resident buffer's cached [`PreparedWeight`] (CSR for pruned
/// weights), and this module's index into each tenant's
/// [`AdapterBinding`] sites if it is an adapter target.
struct BoundLinear<'a> {
    w: &'a [f32],
    pw: Option<Rc<PreparedWeight>>,
    out: usize,
    inp: usize,
    site: Option<usize>,
}

impl BoundLinear<'_> {
    /// `y = x @ Wᵀ (+ scale·((x@Aᵀ)·mask)@Bᵀ)` over `m` rows — the
    /// decode-path mirror of [`Model::lin_fwd`] (same kernels in the
    /// same order), minus the backward tape. The adapter term uses each
    /// row's own binding; rows sharing one binding batch the LoRA
    /// matmuls (the kernels are row-count invariant, so per-row and
    /// batched application are bit-identical).
    fn fwd(
        &self,
        sc: &Scratch,
        x: &[f32],
        m: usize,
        scale: f32,
        ads: &RowAdapters,
        y: &mut [f32],
    ) {
        match &self.pw {
            Some(pw) => linalg::matmul_nt_prepared_into(x, self.w, pw, m, y),
            None => linalg::matmul_nt_auto_into(x, self.w, m, self.inp, self.out, y),
        }
        let Some(site) = self.site else { return };
        match ads {
            RowAdapters::Uniform(None) => {}
            RowAdapters::Uniform(Some(b)) => {
                self.apply_lora(sc, x, 0, m, scale, &b.sites[site], y)
            }
            RowAdapters::PerRow(rows) => {
                let uniform = rows[1..].iter().all(|r| match (&rows[0], r) {
                    (None, None) => true,
                    (Some(a), Some(b)) => Arc::ptr_eq(a, b),
                    _ => false,
                });
                if uniform {
                    if let Some(b) = &rows[0] {
                        self.apply_lora(sc, x, 0, m, scale, &b.sites[site], y);
                    }
                    return;
                }
                for (r, ad) in rows.iter().enumerate() {
                    if let Some(b) = ad {
                        self.apply_lora(sc, x, r, 1, scale, &b.sites[site], y);
                    }
                }
            }
        }
    }

    /// Adapter term for rows `row0..row0+m`, all applying site `s`.
    fn apply_lora(
        &self,
        sc: &Scratch,
        x: &[f32],
        row0: usize,
        m: usize,
        scale: f32,
        s: &AdapterSite,
        y: &mut [f32],
    ) {
        let r = s.mask.len();
        let xs = &x[row0 * self.inp..(row0 + m) * self.inp];
        let ys = &mut y[row0 * self.out..(row0 + m) * self.out];
        let mut proj = sc.take(m * r);
        // A is [rank, inp] row-major, so the active window is a
        // contiguous prefix — the same slice (the whole buffer) when
        // the binding runs at full rank.
        linalg::matmul_nt_into(xs, &s.a[..r * self.inp], m, self.inp, r, &mut proj);
        for row in 0..m {
            for (j, pv) in proj[row * r..(row + 1) * r].iter_mut().enumerate() {
                *pv *= s.mask[j];
            }
        }
        let mut yl = sc.take(m * self.out);
        // B is [out, rank] row-major: full-rank bindings take the
        // plain kernel (bit-identical to pre-prefix code), truncated
        // windows read the length-r prefix of each rank-stride row.
        if r == s.rank {
            linalg::matmul_nt_into(&proj, &s.b[..], m, r, self.out, &mut yl);
        } else {
            linalg::matmul_nt_strided_into(&proj, &s.b[..], m, r, self.out, s.rank, &mut yl);
        }
        axpy(ys, scale, &yl);
        sc.give(yl);
        sc.give(proj);
    }
}

/// One decoder block's bound weights.
struct BoundLayer<'a> {
    norm1_g: &'a [f32],
    norm1_b: Option<&'a [f32]>,
    q: BoundLinear<'a>,
    k: BoundLinear<'a>,
    v: BoundLinear<'a>,
    o: BoundLinear<'a>,
    norm2_g: &'a [f32],
    norm2_b: Option<&'a [f32]>,
    gate: Option<BoundLinear<'a>>,
    up: BoundLinear<'a>,
    down: BoundLinear<'a>,
}

/// Which (slot, position) each row of a decode batch belongs to.
#[derive(Clone, Copy)]
enum Rows<'s> {
    /// prefill: one slot, contiguous positions `p0..p0+m`
    Contig { slot: usize, p0: usize },
    /// decode step: row `r` is `slots[r]` at its current cache length
    PerRow { slots: &'s [usize] },
}

impl Rows<'_> {
    #[inline]
    fn slot_pos(&self, r: usize, len: &[usize]) -> (usize, usize) {
        match *self {
            Rows::Contig { slot, p0 } => (slot, p0 + r),
            Rows::PerRow { slots } => {
                let sl = slots[r];
                (sl, len[sl])
            }
        }
    }
}

/// A forward entry bound for incremental decoding: every weight
/// resolved once (slices + prepared cells shared with the resident
/// forward path), adapters unmerged per the paper's §4.4 deployment
/// claim. Build via [`DecodeModel::bind`]; drive via
/// [`DecodeModel::prefill`] / [`DecodeModel::decode_step`].
pub struct DecodeModel<'a> {
    d: usize,
    nh: usize,
    dh: usize,
    f: usize,
    v: usize,
    cap: usize,
    llama: bool,
    scale: f32,
    embed: &'a [f32],
    layers: Vec<BoundLayer<'a>>,
    final_g: &'a [f32],
    final_b: Option<&'a [f32]>,
    lm_head: BoundLinear<'a>,
    /// `(out, inp)` of each adapter target, in `adapter_modules` order;
    /// empty when the entry runs base-only.
    site_dims: Vec<(usize, usize)>,
}

/// Resolve one linear from the named tensors, recording its adapter
/// site index when `use_adapters` and the module is an adapter target.
fn bind_linear<'a>(
    cfg: &ModelConfig,
    p: &NamedTensors<'a>,
    use_adapters: bool,
    name: &str,
    out: usize,
    inp: usize,
) -> Result<BoundLinear<'a>> {
    let w = p.f(name)?;
    ensure!(
        w.len() == out * inp,
        "decode bind: weight '{name}' has {} values, expected {out}x{inp}",
        w.len()
    );
    let pw = p.prepared(name, out, inp)?;
    let site = if use_adapters {
        cfg.adapter_modules.iter().position(|m| m == name)
    } else {
        None
    };
    Ok(BoundLinear { w, pw, out, inp, site })
}

impl<'a> DecodeModel<'a> {
    /// Resolve every weight of the plain (non-prefix/series/parallel)
    /// forward into a name-free binding. Prepared-weight cells are
    /// shared with the resident forward path, so the CSR structure of a
    /// pruned base weight is derived once per upload — never per step.
    pub fn bind(
        cfg: &ModelConfig,
        p: &NamedTensors<'a>,
        use_adapters: bool,
    ) -> Result<DecodeModel<'a>> {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let llama = cfg.arch == "llama";
        let lin = |name: String, out: usize, inp: usize| {
            bind_linear(cfg, p, use_adapters, &name, out, inp)
        };
        let norm_b = |name: String| -> Result<Option<&'a [f32]>> {
            if llama {
                Ok(None)
            } else {
                Ok(Some(p.f(&format!("{name}.b"))?))
            }
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let pre = format!("layers.{i}.");
            layers.push(BoundLayer {
                norm1_g: p.f(&format!("{pre}attn_norm.g"))?,
                norm1_b: norm_b(format!("{pre}attn_norm"))?,
                q: lin(format!("{pre}attn.q"), d, d)?,
                k: lin(format!("{pre}attn.k"), d, d)?,
                v: lin(format!("{pre}attn.v"), d, d)?,
                o: lin(format!("{pre}attn.o"), d, d)?,
                norm2_g: p.f(&format!("{pre}mlp_norm.g"))?,
                norm2_b: norm_b(format!("{pre}mlp_norm"))?,
                gate: if llama {
                    Some(lin(format!("{pre}mlp.gate"), f, d)?)
                } else {
                    None
                },
                up: lin(format!("{pre}mlp.up"), f, d)?,
                down: lin(format!("{pre}mlp.down"), d, f)?,
            });
        }
        let embed = p.f("embed")?;
        ensure!(
            embed.len() == v * d,
            "decode bind: embed has {} values, expected {v}x{d}",
            embed.len()
        );
        let lm_head = bind_linear(cfg, p, use_adapters, "lm_head", v, d)?;
        // Record each adapter target's dims so tenant bindings can be
        // shape-checked before a batched step applies them.
        let mut dims = vec![None; if use_adapters { cfg.adapter_modules.len() } else { 0 }];
        {
            let mut note = |l: &BoundLinear| {
                if let Some(i) = l.site {
                    dims[i] = Some((l.out, l.inp));
                }
            };
            for lay in &layers {
                note(&lay.q);
                note(&lay.k);
                note(&lay.v);
                note(&lay.o);
                if let Some(g) = &lay.gate {
                    note(g);
                }
                note(&lay.up);
                note(&lay.down);
            }
            note(&lm_head);
        }
        let site_dims = dims
            .into_iter()
            .enumerate()
            .map(|(i, sd)| {
                sd.with_context(|| {
                    format!(
                        "adapter module '{}' is not bound by the decode path",
                        cfg.adapter_modules[i]
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DecodeModel {
            d,
            nh: cfg.n_heads,
            dh: d / cfg.n_heads,
            f,
            v,
            cap: cfg.seq_len,
            llama,
            scale: cfg.lora_scale(),
            embed,
            layers,
            final_g: p.f("final_norm.g")?,
            final_b: norm_b("final_norm".to_string())?,
            lm_head,
            site_dims,
        })
    }

    /// Whether this binding resolved adapter target sites (i.e. the
    /// entry carries unmerged LoRA and tenant bindings can apply).
    pub fn has_adapter_sites(&self) -> bool {
        !self.site_dims.is_empty()
    }

    /// Verify a tenant binding matches this base's adapter targets
    /// (site count and per-site dims) — a mismatched binding is an
    /// error up front, not an out-of-bounds panic mid-batch.
    pub fn check_adapter(&self, b: &AdapterBinding) -> Result<()> {
        ensure!(
            !self.site_dims.is_empty(),
            "decode binding is base-only (no adapter sites); cannot apply a tenant adapter"
        );
        ensure!(
            b.sites.len() == self.site_dims.len(),
            "adapter binding has {} sites, model expects {}",
            b.sites.len(),
            self.site_dims.len()
        );
        for (i, (s, &(out, inp))) in b.sites.iter().zip(&self.site_dims).enumerate() {
            let r = s.mask.len();
            ensure!(
                s.out == out
                    && s.inp == inp
                    && s.a.len() == s.rank * inp
                    && s.b.len() == out * s.rank
                    && r >= 1
                    && r <= s.rank,
                "adapter site {i} is [{}, {}] rank {}/{} active, model expects [{out}, {inp}]",
                s.out,
                s.inp,
                r,
                s.rank
            );
        }
        Ok(())
    }

    /// Vocabulary size (logits row width).
    pub fn vocab(&self) -> usize {
        self.v
    }

    /// Context-window capacity (the config's `seq_len`).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    fn check_state(&self, st: &DecodeState) -> Result<()> {
        ensure!(
            st.cap == self.cap
                && st.nh == self.nh
                && st.dh == self.dh
                && st.n_layers == self.layers.len()
                && st.llama == self.llama,
            "decode state was built for a different model configuration"
        );
        Ok(())
    }

    fn embed_rows(&self, tokens: &[i32], h: &mut [f32]) -> Result<()> {
        let d = self.d;
        for (mi, tok) in tokens.iter().enumerate() {
            ensure!(
                *tok >= 0 && (*tok as usize) < self.v,
                "token id {tok} outside vocab {}",
                self.v
            );
            let t = *tok as usize;
            h[mi * d..(mi + 1) * d].copy_from_slice(&self.embed[t * d..(t + 1) * d]);
        }
        Ok(())
    }

    /// Row-wise norm over `m` rows (RMSNorm or LayerNorm per arch).
    fn norm_rows(
        &self,
        sc: &Scratch,
        x: &[f32],
        g: &[f32],
        b: Option<&[f32]>,
        m: usize,
    ) -> Vec<f32> {
        let d = self.d;
        let mut y = sc.take(m * d);
        let mut inv = sc.take(m);
        match b {
            None => nn::rmsnorm_into(x, g, m, d, &mut y, &mut inv),
            Some(bb) => {
                let mut xhat = sc.take(m * d);
                nn::layernorm_into(x, g, bb, m, d, &mut y, &mut xhat, &mut inv);
                sc.give(xhat);
            }
        }
        sc.give(inv);
        y
    }

    /// In-place RoPE rotation of one head slice at absolute `pos`
    /// (forward branch of [`Model::rope_apply`], same table values).
    #[inline]
    fn rope_rot(&self, cos: &[f32], sin: &[f32], x: &mut [f32], pos: usize) {
        let half = self.dh / 2;
        for j in 0..half {
            let (c, sn) = (cos[pos * half + j], sin[pos * half + j]);
            let x1 = x[j];
            let x2 = x[half + j];
            x[j] = x1 * c - x2 * sn;
            x[half + j] = x1 * sn + x2 * c;
        }
    }

    fn alibi_slope(&self, h: usize) -> f32 {
        alibi_slope(h, self.nh)
    }

    /// One decoder block over `m` rows: project Q/K/V, append this
    /// step's K/V to each row's cache column at its absolute position,
    /// attend against the cached context, then the MLP. Consumes `h`,
    /// returns the next hidden state (both arena-owned).
    fn block(
        &self,
        sc: &Scratch,
        st: &mut DecodeState,
        li: usize,
        rows: Rows,
        ads: &RowAdapters,
        h: Vec<f32>,
        m: usize,
    ) -> Vec<f32> {
        let (d, nh, dh, cap) = (self.d, self.nh, self.dh, self.cap);
        let lay = &self.layers[li];
        let t1 = self.norm_rows(sc, &h, lay.norm1_g, lay.norm1_b, m);
        let mut q = sc.take(m * d);
        lay.q.fwd(sc, &t1, m, self.scale, ads, &mut q);
        let mut kk = sc.take(m * d);
        lay.k.fwd(sc, &t1, m, self.scale, ads, &mut kk);
        let mut vv = sc.take(m * d);
        lay.v.fwd(sc, &t1, m, self.scale, ads, &mut vv);
        sc.give(t1);
        // split borrows: cache planes are written, lengths/tables read
        let DecodeState { kc, vc, len, cos, sin, .. } = st;
        let (kcl, vcl) = (&mut kc[li], &mut vc[li]);
        for r in 0..m {
            let (sl, pos) = rows.slot_pos(r, len);
            for hh in 0..nh {
                let ks = &mut kk[r * d + hh * dh..r * d + (hh + 1) * dh];
                if self.llama {
                    self.rope_rot(cos, sin, ks, pos);
                }
                let dst = ((sl * nh + hh) * cap + pos) * dh;
                kcl[dst..dst + dh].copy_from_slice(ks);
                vcl[dst..dst + dh].copy_from_slice(&vv[r * d + hh * dh..r * d + (hh + 1) * dh]);
                let qs = &mut q[r * d + hh * dh..r * d + (hh + 1) * dh];
                if self.llama {
                    self.rope_rot(cos, sin, qs, pos);
                }
            }
        }
        sc.give(kk);
        sc.give(vv);
        // attention against the cached K/V: score rows padded to the
        // full window with -1e30 (same softmax lane layout as the
        // padded re-forward), reductions via the SIMD linalg::dot
        let inv_sqrt = 1.0 / (dh as f32).sqrt();
        let mut ctx = sc.take(m * d);
        let mut srow = sc.take(cap);
        let DecodeState { kc, vc, len, .. } = st;
        let (kcl, vcl) = (&kc[li], &vc[li]);
        for r in 0..m {
            let (sl, pos) = rows.slot_pos(r, len);
            for hh in 0..nh {
                let qrow = &q[r * d + hh * dh..r * d + (hh + 1) * dh];
                let slope = if self.llama { 0.0 } else { self.alibi_slope(hh) };
                for (t, sv) in srow.iter_mut().enumerate() {
                    if t > pos {
                        *sv = -1e30;
                        continue;
                    }
                    let kof = ((sl * nh + hh) * cap + t) * dh;
                    let mut sc_ = linalg::dot(qrow, &kcl[kof..kof + dh]) * inv_sqrt;
                    if !self.llama {
                        sc_ += slope * -(t as f32 - pos as f32).abs();
                    }
                    *sv = sc_;
                }
                nn::softmax_row(&mut srow);
                let crow = &mut ctx[r * d + hh * dh..r * d + (hh + 1) * dh];
                for (t, pv) in srow.iter().enumerate() {
                    if *pv == 0.0 {
                        continue;
                    }
                    let vof = ((sl * nh + hh) * cap + t) * dh;
                    for (cv, vv2) in crow.iter_mut().zip(&vcl[vof..vof + dh]) {
                        *cv += pv * vv2;
                    }
                }
            }
        }
        sc.give(srow);
        sc.give(q);
        let mut attn = sc.take(m * d);
        lay.o.fwd(sc, &ctx, m, self.scale, ads, &mut attn);
        sc.give(ctx);
        // residual adds run in place: decode keeps no backward tape, so
        // `h` itself becomes h_mid and then the block output (same
        // elementwise adds as the forward, no extra copies)
        let mut h = h;
        add_assign(&mut h, &attn);
        sc.give(attn);
        let t2 = self.norm_rows(sc, &h, lay.norm2_g, lay.norm2_b, m);
        let mut act = sc.take(m * self.f);
        match &lay.gate {
            Some(gate) => {
                let mut gp = sc.take(m * self.f);
                gate.fwd(sc, &t2, m, self.scale, ads, &mut gp);
                let mut up = sc.take(m * self.f);
                lay.up.fwd(sc, &t2, m, self.scale, ads, &mut up);
                for ((av, g), u) in act.iter_mut().zip(&gp).zip(&up) {
                    *av = nn::silu(*g) * u;
                }
                sc.give(gp);
                sc.give(up);
            }
            None => {
                let mut up = sc.take(m * self.f);
                lay.up.fwd(sc, &t2, m, self.scale, ads, &mut up);
                for (av, u) in act.iter_mut().zip(&up) {
                    *av = nn::gelu(*u);
                }
                sc.give(up);
            }
        }
        sc.give(t2);
        let mut out = sc.take(m * d);
        lay.down.fwd(sc, &act, m, self.scale, ads, &mut out);
        sc.give(act);
        add_assign(&mut h, &out);
        sc.give(out);
        h
    }

    /// Run `tokens` (a full prompt) through the model, filling `slot`'s
    /// cache column, and write the **final position's** logits (the
    /// next-token distribution) into `logits` (`[vocab]`). Any previous
    /// context in the slot is discarded; other slots are untouched.
    /// `adapter` is the slot's tenant binding (`None` = bare base).
    pub fn prefill(
        &self,
        sc: &Scratch,
        st: &mut DecodeState,
        slot: usize,
        tokens: &[i32],
        adapter: Option<&AdapterBinding>,
        logits: &mut [f32],
    ) -> Result<()> {
        self.check_state(st)?;
        if let Some(b) = adapter {
            self.check_adapter(b)?;
        }
        ensure!(slot < st.slots, "slot {slot} out of range ({} slots)", st.slots);
        ensure!(!tokens.is_empty(), "prefill needs at least one token");
        ensure!(
            tokens.len() <= self.cap,
            "prompt of {} tokens exceeds the {}-token window",
            tokens.len(),
            self.cap
        );
        ensure!(
            logits.len() == self.v,
            "prefill logits buffer holds {} values, expected vocab {}",
            logits.len(),
            self.v
        );
        st.reset(slot);
        let ads = RowAdapters::Uniform(adapter);
        let (m, d) = (tokens.len(), self.d);
        let mut h = sc.take(m * d);
        self.embed_rows(tokens, &mut h)?;
        for li in 0..self.layers.len() {
            h = self.block(sc, st, li, Rows::Contig { slot, p0: 0 }, &ads, h, m);
        }
        let tf = self.norm_rows(sc, &h[(m - 1) * d..m * d], self.final_g, self.final_b, 1);
        self.lm_head.fwd(sc, &tf, 1, self.scale, &ads, logits);
        sc.give(tf);
        sc.give(h);
        st.len[slot] = m;
        Ok(())
    }

    /// Advance the strictly-ascending active `slots` by one token each
    /// (`tokens[r]` is appended to `slots[r]`'s context) and write each
    /// row's next-token logits into `logits` (`[slots.len(), vocab]`).
    /// `adapters` selects each row's tenant binding; a mixed batch is
    /// bit-identical to running each row in its own decoder (the
    /// matmul kernels are row-count invariant). Allocation-free once
    /// the arena is warm.
    pub fn decode_step(
        &self,
        sc: &Scratch,
        st: &mut DecodeState,
        slots: &[usize],
        tokens: &[i32],
        adapters: RowAdapters,
        logits: &mut [f32],
    ) -> Result<()> {
        self.check_state(st)?;
        let m = slots.len();
        ensure!(m > 0, "decode step needs at least one active slot");
        ensure!(
            tokens.len() == m,
            "decode step got {} tokens for {m} slots",
            tokens.len()
        );
        ensure!(
            logits.len() == m * self.v,
            "decode logits buffer holds {} values, expected {m}x{}",
            logits.len(),
            self.v
        );
        match &adapters {
            RowAdapters::Uniform(Some(b)) => self.check_adapter(b)?,
            RowAdapters::Uniform(None) => {}
            RowAdapters::PerRow(rows) => {
                ensure!(
                    rows.len() == m,
                    "decode step got {} row adapters for {m} slots",
                    rows.len()
                );
                for b in rows.iter().flatten() {
                    self.check_adapter(b)?;
                }
            }
        }
        for (i, &sl) in slots.iter().enumerate() {
            ensure!(sl < st.slots, "slot {sl} out of range ({} slots)", st.slots);
            ensure!(
                i == 0 || slots[i - 1] < sl,
                "decode slots must be strictly ascending"
            );
            ensure!(
                st.len[sl] < self.cap,
                "slot {sl} context window is full ({} tokens)",
                self.cap
            );
        }
        let d = self.d;
        let mut h = sc.take(m * d);
        self.embed_rows(tokens, &mut h)?;
        for li in 0..self.layers.len() {
            h = self.block(sc, st, li, Rows::PerRow { slots }, &adapters, h, m);
        }
        let tf = self.norm_rows(sc, &h, self.final_g, self.final_b, m);
        self.lm_head.fwd(sc, &tf, m, self.scale, &adapters, logits);
        sc.give(tf);
        sc.give(h);
        // Failure atomicity the serving layer's recovery depends on:
        // every validation above runs before any compute, and sequence
        // lengths advance only here, after all compute succeeded. K/V
        // writes for a step that errors out land at positions >= len
        // and are never read — the next prefill/step overwrites them —
        // so a failed step leaves each slot exactly at its pre-step
        // position and `prefill` can rebuild any column from the
        // token history alone (see `serve::StepEngine::recover_step`).
        for &sl in slots {
            st.len[sl] += 1;
        }
        Ok(())
    }
}

/// Whether a logits row is safe to trust: all values finite. NaN/±inf
/// anywhere in a row means that slot's KV column may be poisoned (a
/// numeric blow-up propagates forward through the cache), so the
/// serving layer quarantines the slot instead of sampling from it.
/// SIMD-mode independent — it reads the already-materialized row.
#[inline]
pub fn logits_row_finite(row: &[f32]) -> bool {
    row.iter().all(|x| x.is_finite())
}

// ------------------------------------------------- fused LoRA linear
//
// The L1 `lora_linear_ref` contract, standalone (used by the parity
// fixtures in rust/tests/parity.rs; the model hot path runs the same
// math through `lin_fwd`/`lin_bwd` over the scratch arena):
//   Y = X @ Wᵀ + ((X @ Aᵀ)·mask) @ Bᵀ · scale

/// Forward; returns `(y, p)` where `p = (x@Aᵀ)·mask` is the tape entry
/// the backward pass needs. The base matmul is sparsity-aware (skips the
/// {0,1}-masked zeros of a pruned `w`).
#[allow(clippy::too_many_arguments)]
pub fn lora_linear(
    x: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    rank_mask: &[f32],
    scale: f32,
    m: usize,
    k_in: usize,
    r: usize,
    n_out: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut y = linalg::matmul_nt_auto(x, w, m, k_in, n_out);
    let mut proj = linalg::matmul_nt(x, a, m, k_in, r);
    for row in 0..m {
        for (j, pv) in proj[row * r..(row + 1) * r].iter_mut().enumerate() {
            *pv *= rank_mask[j];
        }
    }
    let yl = linalg::matmul_nt(&proj, b, m, r, n_out);
    axpy(&mut y, scale, &yl);
    (y, proj)
}

/// Backward: `(dx, da, db)` with W frozen (`kernels/ref.py`
/// `lora_linear_bwd_ref`). `proj` is the forward's tape entry.
#[allow(clippy::too_many_arguments)]
pub fn lora_linear_bwd(
    dy: &[f32],
    x: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    rank_mask: &[f32],
    scale: f32,
    proj: &[f32],
    m: usize,
    k_in: usize,
    r: usize,
    n_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dp = linalg::matmul_nn(dy, b, m, n_out, r);
    for row in 0..m {
        for (j, dpv) in dp[row * r..(row + 1) * r].iter_mut().enumerate() {
            *dpv *= rank_mask[j] * scale;
        }
    }
    let mut dx = linalg::matmul_nn(dy, w, m, n_out, k_in);
    add_assign(&mut dx, &linalg::matmul_nn(&dp, a, m, r, k_in));
    let da = linalg::matmul_tn(&dp, x, m, r, k_in);
    let mut db = linalg::matmul_tn(dy, proj, m, n_out, r);
    for dv in db.iter_mut() {
        *dv *= scale;
    }
    (dx, da, db)
}
