//! Native decoder forward/backward: the pure-Rust implementation of the
//! L2 model (`python/compile/model.py`) that the native backend executes.
//!
//! One [`Model`] handles every entry-point variant: llama-sim (RMSNorm,
//! RoPE, SwiGLU) and mpt-sim (LayerNorm, ALiBi, GELU), elastic-LoRA
//! adapters gated by a rank mask, the prefix/series/parallel PEFT
//! baselines, Wanda/SparseGPT calibration-statistics collection, and the
//! hand-derived backward pass for each trainable group (adapters, full
//! base, prefix, series, parallel).
//!
//! The backward formulas are validated two ways: golden fixtures from
//! `python/compile/fixtures.py` pin the numerics against `jax.grad` in
//! `rust/tests/parity.rs`, and finite-difference checks cover the local
//! vjps in `ops::nn`. Accumulation order differs from XLA, so agreement
//! is to f32 round-off, not bit-exact.

use crate::model::ModelConfig;
use crate::ops::linalg::{self, add_assign, axpy};
use crate::ops::nn;
use crate::tensor::HostTensor;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// Name → tensor view over one entry point's positional inputs.
#[derive(Default)]
pub struct NamedTensors<'a> {
    map: HashMap<&'a str, &'a HostTensor>,
}

impl<'a> NamedTensors<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &'a str, t: &'a HostTensor) {
        self.map.insert(name, t);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Result<&'a HostTensor> {
        self.map
            .get(name)
            .copied()
            .with_context(|| format!("native entry input '{name}' missing"))
    }

    pub fn f(&self, name: &str) -> Result<&'a [f32]> {
        Ok(self.get(name)?.f32s())
    }
}

/// Model dimensions resolved for one batch.
#[derive(Clone, Debug)]
pub struct Dims {
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub nh: usize,
    pub dh: usize,
    pub f: usize,
    pub v: usize,
    pub r: usize,
    pub n_layers: usize,
    pub llama: bool,
    pub plen: usize,
    pub bn: usize,
    pub scale: f32,
    pub mods: Vec<String>,
}

impl Dims {
    pub fn from_config(cfg: &ModelConfig, batch: usize) -> Dims {
        Dims {
            b: batch,
            s: cfg.seq_len,
            d: cfg.d_model,
            nh: cfg.n_heads,
            dh: cfg.d_model / cfg.n_heads,
            f: cfg.d_ff,
            v: cfg.vocab,
            r: cfg.max_rank,
            n_layers: cfg.n_layers,
            llama: cfg.arch == "llama",
            plen: cfg.prefix_len,
            bn: cfg.bottleneck,
            scale: cfg.lora_scale(),
            mods: cfg.adapter_modules.clone(),
        }
    }
}

/// Which PEFT baseline (if any) is active in the forward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Extra {
    None,
    Prefix,
    Series,
    Parallel,
}

/// Which parameter group the backward pass produces gradients for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMode {
    Adapters,
    Base,
    Prefix,
    Series,
    Parallel,
}

/// Accumulating gradient store keyed by parameter name.
#[derive(Default)]
pub struct Grads {
    pub map: HashMap<String, Vec<f32>>,
}

impl Grads {
    fn add(&mut self, name: &str, g: Vec<f32>) {
        match self.map.get_mut(name) {
            Some(acc) => add_assign(acc, &g),
            None => {
                self.map.insert(name.to_string(), g);
            }
        }
    }

    pub fn take(&mut self, name: &str, numel: usize) -> Vec<f32> {
        self.map.remove(name).unwrap_or_else(|| vec![0.0; numel])
    }
}

enum NormTape {
    /// cached 1/rms per row (llama)
    Rms(Vec<f32>),
    /// cached normalized input + 1/σ per row (mpt)
    Ln { xhat: Vec<f32>, inv: Vec<f32> },
}

struct LayerTape {
    h_in: Vec<f32>,
    norm1: NormTape,
    t_attn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>,
    ctx: Vec<f32>,
    h_mid: Vec<f32>,
    norm2: NormTape,
    t_mlp: Vec<f32>,
    g_pre: Vec<f32>,
    u_pre: Vec<f32>,
    act: Vec<f32>,
    lora_p: HashMap<String, Vec<f32>>,
    s_out_in: Vec<f32>,
    s_zpre: Vec<f32>,
    s_z: Vec<f32>,
    p_zpre: Vec<f32>,
    p_z: Vec<f32>,
}

struct Tape {
    layers: Vec<LayerTape>,
    h_final_in: Vec<f32>,
    norm_f: NormTape,
    t_final: Vec<f32>,
}

/// Forward output: logits plus (optionally) calibration stats and the
/// activation tape for the backward pass.
pub struct Forward {
    /// `[B, S, V]` row-major
    pub logits: Vec<f32>,
    /// per-site (Σx², Gram) in `calib_sites` order
    pub stats: Vec<(String, Vec<f32>, Vec<f32>)>,
    tape: Option<Tape>,
}

/// One forward/backward construction over resolved named tensors.
pub struct Model<'a> {
    pub dims: Dims,
    pub p: &'a NamedTensors<'a>,
    pub use_adapters: bool,
    pub rank_mask: Option<&'a [f32]>,
    pub extra: Extra,
}

impl<'a> Model<'a> {
    fn norm_fwd(&self, x: &[f32], name: &str, m: usize) -> Result<(Vec<f32>, NormTape)> {
        let d = self.dims.d;
        let g = self.p.f(&format!("{name}.g"))?;
        if self.dims.llama {
            let (y, inv) = nn::rmsnorm(x, g, m, d);
            Ok((y, NormTape::Rms(inv)))
        } else {
            let b = self.p.f(&format!("{name}.b"))?;
            let (y, xhat, inv) = nn::layernorm(x, g, b, m, d);
            Ok((y, NormTape::Ln { xhat, inv }))
        }
    }

    fn norm_bwd(
        &self,
        dy: &[f32],
        x: &[f32],
        name: &str,
        tape: &NormTape,
        m: usize,
        grads: &mut Grads,
        mode: GradMode,
    ) -> Result<Vec<f32>> {
        let d = self.dims.d;
        let g = self.p.f(&format!("{name}.g"))?;
        match tape {
            NormTape::Rms(inv) => {
                let (dx, dg) = nn::rmsnorm_bwd(dy, x, g, inv, m, d);
                if mode == GradMode::Base {
                    grads.add(&format!("{name}.g"), dg);
                }
                Ok(dx)
            }
            NormTape::Ln { xhat, inv } => {
                let (dx, dg, db) = nn::layernorm_bwd(dy, g, xhat, inv, m, d);
                if mode == GradMode::Base {
                    grads.add(&format!("{name}.g"), dg);
                    grads.add(&format!("{name}.b"), db);
                }
                Ok(dx)
            }
        }
    }

    /// Adapter-aware linear `y = x @ Wᵀ (+ scale · ((x@Aᵀ)·mask) @ Bᵀ)`.
    /// Returns `(y, p)` where `p` is the masked LoRA projection (tape).
    fn lin_fwd(
        &self,
        x: &[f32],
        m: usize,
        wname: &str,
        out_dim: usize,
        in_dim: usize,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
        let w = self.p.f(wname)?;
        if !self.use_adapters {
            return Ok((linalg::matmul_nt_auto(x, w, m, in_dim, out_dim), None));
        }
        let Some(idx) = self.dims.mods.iter().position(|mo| mo == wname) else {
            return Ok((linalg::matmul_nt_auto(x, w, m, in_dim, out_dim), None));
        };
        let r = self.dims.r;
        let a = self.p.f(&format!("lora_a.{wname}"))?;
        let b = self.p.f(&format!("lora_b.{wname}"))?;
        let rm = self.rank_mask.context("adapter forward needs a rank mask")?;
        let rm = &rm[idx * r..(idx + 1) * r];
        let (y, proj) = lora_linear(x, w, a, b, rm, self.dims.scale, m, in_dim, r, out_dim);
        Ok((y, Some(proj)))
    }

    /// Backward of `lin_fwd`; accumulates adapter/base grads per `mode`
    /// and returns `dx`.
    #[allow(clippy::too_many_arguments)]
    fn lin_bwd(
        &self,
        dy: &[f32],
        x: &[f32],
        m: usize,
        wname: &str,
        out_dim: usize,
        in_dim: usize,
        lora_p: &HashMap<String, Vec<f32>>,
        grads: &mut Grads,
        mode: GradMode,
    ) -> Result<Vec<f32>> {
        let w = self.p.f(wname)?;
        let dx = if let Some(proj) = lora_p.get(wname) {
            let r = self.dims.r;
            let idx = self.dims.mods.iter().position(|mo| mo == wname).unwrap();
            let a = self.p.f(&format!("lora_a.{wname}"))?;
            let b = self.p.f(&format!("lora_b.{wname}"))?;
            let rm = self.rank_mask.context("adapter backward needs a rank mask")?;
            let rm = &rm[idx * r..(idx + 1) * r];
            let (dx, da, db) =
                lora_linear_bwd(dy, x, w, a, b, rm, self.dims.scale, proj, m, in_dim, r, out_dim);
            if mode == GradMode::Adapters {
                grads.add(&format!("lora_a.{wname}"), da);
                grads.add(&format!("lora_b.{wname}"), db);
            }
            dx
        } else {
            linalg::matmul_nn(dy, w, m, out_dim, in_dim)
        };
        if mode == GradMode::Base {
            grads.add(wname, linalg::matmul_tn(dy, x, m, out_dim, in_dim));
        }
        Ok(dx)
    }

    /// RoPE rotation tables (llama): `(cos, sin)` of shape `[S, dh/2]`.
    fn rope_tables(&self) -> (Vec<f32>, Vec<f32>) {
        let (s, half) = (self.dims.s, self.dims.dh / 2);
        let mut cos = vec![0.0f32; s * half];
        let mut sin = vec![0.0f32; s * half];
        for si in 0..s {
            for j in 0..half {
                let freq = 1.0 / 10000.0f32.powf(j as f32 / half as f32);
                let ang = si as f32 * freq;
                cos[si * half + j] = ang.cos();
                sin[si * half + j] = ang.sin();
            }
        }
        (cos, sin)
    }

    /// Apply RoPE in place over `[B, H, S, dh]` head-major data.
    fn rope_apply(&self, x: &mut [f32], cos: &[f32], sin: &[f32], backward: bool) {
        let Dims { b, s, nh, dh, .. } = self.dims;
        let half = dh / 2;
        for bh in 0..b * nh {
            for si in 0..s {
                let off = (bh * s + si) * dh;
                for j in 0..half {
                    let (c, sn) = (cos[si * half + j], sin[si * half + j]);
                    let x1 = x[off + j];
                    let x2 = x[off + half + j];
                    if backward {
                        // transpose of the rotation
                        x[off + j] = x1 * c + x2 * sn;
                        x[off + half + j] = -x1 * sn + x2 * c;
                    } else {
                        x[off + j] = x1 * c - x2 * sn;
                        x[off + half + j] = x1 * sn + x2 * c;
                    }
                }
            }
        }
    }

    /// `[M, d]` row-major → `[B, H, S, dh]` head-major.
    fn split_heads(&self, x: &[f32]) -> Vec<f32> {
        let Dims { b, s, d, nh, dh, .. } = self.dims;
        let mut out = vec![0.0f32; b * nh * s * dh];
        for bi in 0..b {
            for si in 0..s {
                let row = &x[(bi * s + si) * d..(bi * s + si + 1) * d];
                for h in 0..nh {
                    let dst = ((bi * nh + h) * s + si) * dh;
                    out[dst..dst + dh].copy_from_slice(&row[h * dh..(h + 1) * dh]);
                }
            }
        }
        out
    }

    /// `[B, H, S, dh]` head-major → `[M, d]` row-major.
    fn merge_heads(&self, x: &[f32]) -> Vec<f32> {
        let Dims { b, s, d, nh, dh, .. } = self.dims;
        let mut out = vec![0.0f32; b * s * d];
        for bi in 0..b {
            for h in 0..nh {
                for si in 0..s {
                    let src = ((bi * nh + h) * s + si) * dh;
                    let dst = (bi * s + si) * d + h * dh;
                    out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
                }
            }
        }
        out
    }

    fn alibi_slope(&self, h: usize) -> f32 {
        2.0f32.powf(-8.0 * (h + 1) as f32 / self.dims.nh as f32)
    }

    /// Record a calibration site: `(Σx² per feature, Gram XᵀX)`.
    fn record(
        stats: &mut Vec<(String, Vec<f32>, Vec<f32>)>,
        site: String,
        x: &[f32],
        m: usize,
        dim: usize,
    ) {
        let mut sumsq = vec![0.0f32; dim];
        for row in 0..m {
            for (j, v) in x[row * dim..(row + 1) * dim].iter().enumerate() {
                sumsq[j] += v * v;
            }
        }
        let gram = linalg::matmul_tn(x, x, m, dim, dim);
        stats.push((site, sumsq, gram));
    }

    /// Full forward pass. `want_tape` caches activations for
    /// [`Model::backward`]; `collect` records calibration statistics.
    pub fn forward(&self, x_ids: &[i32], want_tape: bool, collect: bool) -> Result<Forward> {
        let Dims { b, s, d, nh, dh, f, v, plen, .. } = self.dims;
        debug_assert_eq!(x_ids.len(), b * s);
        let m = b * s;
        let embed = self.p.f("embed")?;
        let mut h = vec![0.0f32; m * d];
        for (mi, tok) in x_ids.iter().enumerate() {
            let t = *tok as usize;
            debug_assert!(t < v, "token id {t} >= vocab {v}");
            h[mi * d..(mi + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
        }
        let (cos, sin) = if self.dims.llama { self.rope_tables() } else { (Vec::new(), Vec::new()) };
        let use_prefix = self.extra == Extra::Prefix;
        let skv = if use_prefix { plen + s } else { s };
        let mut stats = Vec::new();
        let mut layers: Vec<LayerTape> = Vec::with_capacity(self.dims.n_layers);

        for i in 0..self.dims.n_layers {
            let mut lora_p = HashMap::new();
            let h_in = h.clone();
            let (t_attn, norm1) = self.norm_fwd(&h_in, &format!("layers.{i}.attn_norm"), m)?;
            if collect {
                Self::record(&mut stats, format!("{i}.attn_in"), &t_attn, m, d);
            }
            let pre = format!("layers.{i}.attn.");
            let lin3 = |name: &str, tape: &mut HashMap<String, Vec<f32>>| -> Result<Vec<f32>> {
                let wname = format!("{pre}{name}");
                let (y, p) = self.lin_fwd(&t_attn, m, &wname, d, d)?;
                if let Some(p) = p {
                    tape.insert(wname, p);
                }
                Ok(y)
            };
            let qf = lin3("q", &mut lora_p)?;
            let kf = lin3("k", &mut lora_p)?;
            let vf = lin3("v", &mut lora_p)?;
            let mut q = self.split_heads(&qf);
            let k_base = {
                let mut k3 = self.split_heads(&kf);
                if self.dims.llama {
                    self.rope_apply(&mut k3, &cos, &sin, false);
                }
                k3
            };
            if self.dims.llama {
                self.rope_apply(&mut q, &cos, &sin, false);
            }
            let v_base = self.split_heads(&vf);
            // assemble (optionally prefix-extended) K/V in [B,H,Skv,dh]
            let (k3, v3) = if use_prefix {
                let pk = self.p.f(&format!("prefix_k.{i}"))?; // [H, P, dh]
                let pv = self.p.f(&format!("prefix_v.{i}"))?;
                let mut kx = vec![0.0f32; b * nh * skv * dh];
                let mut vx = vec![0.0f32; b * nh * skv * dh];
                for bi in 0..b {
                    for hh in 0..nh {
                        let dst = (bi * nh + hh) * skv * dh;
                        let psrc = hh * plen * dh;
                        kx[dst..dst + plen * dh].copy_from_slice(&pk[psrc..psrc + plen * dh]);
                        vx[dst..dst + plen * dh].copy_from_slice(&pv[psrc..psrc + plen * dh]);
                        let bsrc = ((bi * nh + hh) * s) * dh;
                        kx[dst + plen * dh..dst + skv * dh]
                            .copy_from_slice(&k_base[bsrc..bsrc + s * dh]);
                        vx[dst + plen * dh..dst + skv * dh]
                            .copy_from_slice(&v_base[bsrc..bsrc + s * dh]);
                    }
                }
                (kx, vx)
            } else {
                (k_base, v_base)
            };
            // scores → probs → ctx
            let inv_sqrt = 1.0 / (dh as f32).sqrt();
            let mut probs = vec![0.0f32; b * nh * s * skv];
            let mut ctx = vec![0.0f32; m * d];
            for bi in 0..b {
                for hh in 0..nh {
                    let bh = bi * nh + hh;
                    let slope = if self.dims.llama { 0.0 } else { self.alibi_slope(hh) };
                    for si in 0..s {
                        let qrow = &q[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        let prow = &mut probs[(bh * s + si) * skv..(bh * s + si + 1) * skv];
                        for t in 0..skv {
                            let allowed = t < plen_of(use_prefix, plen) || t - plen_of(use_prefix, plen) <= si;
                            if !allowed {
                                prow[t] = -1e30;
                                continue;
                            }
                            let krow = &k3[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            let mut sc = linalg::dot(qrow, krow) * inv_sqrt;
                            if !self.dims.llama {
                                let pos_k = t as f32 - plen_of(use_prefix, plen) as f32;
                                sc += slope * -(pos_k - si as f32).abs();
                            }
                            prow[t] = sc;
                        }
                        nn::softmax_row(prow);
                        let crow = &mut ctx[(bi * s + si) * d + hh * dh..(bi * s + si) * d + (hh + 1) * dh];
                        for t in 0..skv {
                            let pv = prow[t];
                            if pv == 0.0 {
                                continue;
                            }
                            let vrow = &v3[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (cv, vv) in crow.iter_mut().zip(vrow) {
                                *cv += pv * vv;
                            }
                        }
                    }
                }
            }
            if collect {
                Self::record(&mut stats, format!("{i}.o_in"), &ctx, m, d);
            }
            let (attn_out, o_p) = self.lin_fwd(&ctx, m, &format!("{pre}o"), d, d)?;
            if let Some(p) = o_p {
                lora_p.insert(format!("{pre}o"), p);
            }
            let mut h_mid = h_in.clone();
            add_assign(&mut h_mid, &attn_out);
            let (t_mlp, norm2) = self.norm_fwd(&h_mid, &format!("layers.{i}.mlp_norm"), m)?;
            if collect {
                Self::record(&mut stats, format!("{i}.mlp_in"), &t_mlp, m, d);
            }
            let mpre = format!("layers.{i}.mlp.");
            let (g_pre, u_pre, act) = if self.dims.llama {
                let (gp, gt) = self.lin_fwd(&t_mlp, m, &format!("{mpre}gate"), f, d)?;
                if let Some(p) = gt {
                    lora_p.insert(format!("{mpre}gate"), p);
                }
                let (up, ut) = self.lin_fwd(&t_mlp, m, &format!("{mpre}up"), f, d)?;
                if let Some(p) = ut {
                    lora_p.insert(format!("{mpre}up"), p);
                }
                let act: Vec<f32> = gp.iter().zip(&up).map(|(g, u)| nn::silu(*g) * u).collect();
                (gp, up, act)
            } else {
                let (up, ut) = self.lin_fwd(&t_mlp, m, &format!("{mpre}up"), f, d)?;
                if let Some(p) = ut {
                    lora_p.insert(format!("{mpre}up"), p);
                }
                let act: Vec<f32> = up.iter().map(|u| nn::gelu(*u)).collect();
                (Vec::new(), up, act)
            };
            if collect {
                Self::record(&mut stats, format!("{i}.down_in"), &act, m, f);
            }
            let (mut out, d_p) = self.lin_fwd(&act, m, &format!("{mpre}down"), d, f)?;
            if let Some(p) = d_p {
                lora_p.insert(format!("{mpre}down"), p);
            }
            // series adapter: bottleneck after the MLP output
            let (s_out_in, s_zpre, s_z) = if self.extra == Extra::Series {
                let sd = self.p.f(&format!("series_down.{i}"))?;
                let su = self.p.f(&format!("series_up.{i}"))?;
                let bn = self.dims.bn;
                let zpre = linalg::matmul_nt(&out, sd, m, d, bn);
                let z: Vec<f32> = zpre.iter().map(|x| x.max(0.0)).collect();
                let add = linalg::matmul_nt(&z, su, m, bn, d);
                let out_in = out.clone();
                add_assign(&mut out, &add);
                (out_in, zpre, z)
            } else {
                (Vec::new(), Vec::new(), Vec::new())
            };
            // parallel adapter: bottleneck beside the MLP
            let (p_zpre, p_z) = if self.extra == Extra::Parallel {
                let pd = self.p.f(&format!("parallel_down.{i}"))?;
                let pu = self.p.f(&format!("parallel_up.{i}"))?;
                let bn = self.dims.bn;
                let zpre = linalg::matmul_nt(&t_mlp, pd, m, d, bn);
                let z: Vec<f32> = zpre.iter().map(|x| x.max(0.0)).collect();
                let add = linalg::matmul_nt(&z, pu, m, bn, d);
                add_assign(&mut out, &add);
                (zpre, z)
            } else {
                (Vec::new(), Vec::new())
            };
            h = h_mid.clone();
            add_assign(&mut h, &out);
            if want_tape {
                layers.push(LayerTape {
                    h_in,
                    norm1,
                    t_attn,
                    q,
                    k: k3,
                    v: v3,
                    probs,
                    ctx,
                    h_mid,
                    norm2,
                    t_mlp,
                    g_pre,
                    u_pre,
                    act,
                    lora_p,
                    s_out_in,
                    s_zpre,
                    s_z,
                    p_zpre,
                    p_z,
                });
            }
        }
        let h_final_in = h;
        let (t_final, norm_f) = self.norm_fwd(&h_final_in, "final_norm", m)?;
        let lm_head = self.p.f("lm_head")?;
        let logits = linalg::matmul_nt(&t_final, lm_head, m, d, v);
        let tape = if want_tape {
            Some(Tape { layers, h_final_in, norm_f, t_final })
        } else {
            None
        };
        Ok(Forward { logits, stats, tape })
    }

    /// Masked cross-entropy loss + gradients for `mode`'s parameter group.
    pub fn loss_and_grads(
        &self,
        x_ids: &[i32],
        y_ids: &[i32],
        loss_mask: &[f32],
        mode: GradMode,
    ) -> Result<(f32, Grads)> {
        let fwd = self.forward(x_ids, true, false)?;
        let tape = fwd.tape.as_ref().unwrap();
        let Dims { b, s, d, nh, dh, f, v, plen, .. } = self.dims;
        let m = b * s;
        let (loss, dlogits) = nn::softmax_xent(&fwd.logits, y_ids, loss_mask, m, v);
        let mut grads = Grads::default();

        let lm_head = self.p.f("lm_head")?;
        if mode == GradMode::Base {
            grads.add("lm_head", linalg::matmul_tn(&dlogits, &tape.t_final, m, v, d));
        }
        let dt_final = linalg::matmul_nn(&dlogits, lm_head, m, v, d);
        let mut dh = self.norm_bwd(
            &dt_final,
            &tape.h_final_in,
            "final_norm",
            &tape.norm_f,
            m,
            &mut grads,
            mode,
        )?;
        let (cos, sin) = if self.dims.llama { self.rope_tables() } else { (Vec::new(), Vec::new()) };
        let use_prefix = self.extra == Extra::Prefix;
        let skv = if use_prefix { plen + s } else { s };

        for i in (0..self.dims.n_layers).rev() {
            let lc = &tape.layers[i];
            let mpre = format!("layers.{i}.mlp.");
            let dout = dh.clone();
            let mut dt2 = vec![0.0f32; m * d];
            if self.extra == Extra::Parallel {
                let bn = self.dims.bn;
                let pd = self.p.f(&format!("parallel_down.{i}"))?;
                let pu = self.p.f(&format!("parallel_up.{i}"))?;
                let mut dzp = linalg::matmul_nn(&dout, pu, m, d, bn);
                for (dz, zp) in dzp.iter_mut().zip(&lc.p_zpre) {
                    if *zp <= 0.0 {
                        *dz = 0.0;
                    }
                }
                if mode == GradMode::Parallel {
                    grads.add(&format!("parallel_up.{i}"), linalg::matmul_tn(&dout, &lc.p_z, m, d, bn));
                    grads.add(
                        &format!("parallel_down.{i}"),
                        linalg::matmul_tn(&dzp, &lc.t_mlp, m, bn, d),
                    );
                }
                add_assign(&mut dt2, &linalg::matmul_nn(&dzp, pd, m, bn, d));
            }
            let d_down_out = if self.extra == Extra::Series {
                let bn = self.dims.bn;
                let sd = self.p.f(&format!("series_down.{i}"))?;
                let su = self.p.f(&format!("series_up.{i}"))?;
                let mut dz = linalg::matmul_nn(&dout, su, m, d, bn);
                for (dzv, zp) in dz.iter_mut().zip(&lc.s_zpre) {
                    if *zp <= 0.0 {
                        *dzv = 0.0;
                    }
                }
                if mode == GradMode::Series {
                    grads.add(&format!("series_up.{i}"), linalg::matmul_tn(&dout, &lc.s_z, m, d, bn));
                    grads.add(
                        &format!("series_down.{i}"),
                        linalg::matmul_tn(&dz, &lc.s_out_in, m, bn, d),
                    );
                }
                let mut ddo = dout.clone();
                add_assign(&mut ddo, &linalg::matmul_nn(&dz, sd, m, bn, d));
                ddo
            } else {
                dout
            };
            let dact = self.lin_bwd(
                &d_down_out,
                &lc.act,
                m,
                &format!("{mpre}down"),
                d,
                f,
                &lc.lora_p,
                &mut grads,
                mode,
            )?;
            if self.dims.llama {
                let mut dg_pre = vec![0.0f32; m * f];
                let mut du_pre = vec![0.0f32; m * f];
                for j in 0..m * f {
                    dg_pre[j] = dact[j] * lc.u_pre[j] * nn::dsilu(lc.g_pre[j]);
                    du_pre[j] = dact[j] * nn::silu(lc.g_pre[j]);
                }
                add_assign(
                    &mut dt2,
                    &self.lin_bwd(&dg_pre, &lc.t_mlp, m, &format!("{mpre}gate"), f, d, &lc.lora_p, &mut grads, mode)?,
                );
                add_assign(
                    &mut dt2,
                    &self.lin_bwd(&du_pre, &lc.t_mlp, m, &format!("{mpre}up"), f, d, &lc.lora_p, &mut grads, mode)?,
                );
            } else {
                let mut du_pre = vec![0.0f32; m * f];
                for j in 0..m * f {
                    du_pre[j] = dact[j] * nn::dgelu(lc.u_pre[j]);
                }
                add_assign(
                    &mut dt2,
                    &self.lin_bwd(&du_pre, &lc.t_mlp, m, &format!("{mpre}up"), f, d, &lc.lora_p, &mut grads, mode)?,
                );
            }
            let mut dh_mid = dh.clone();
            add_assign(
                &mut dh_mid,
                &self.norm_bwd(&dt2, &lc.h_mid, &format!("layers.{i}.mlp_norm"), &lc.norm2, m, &mut grads, mode)?,
            );

            // ---- attention block ----
            let pre = format!("layers.{i}.attn.");
            let dctx = self.lin_bwd(&dh_mid, &lc.ctx, m, &format!("{pre}o"), d, d, &lc.lora_p, &mut grads, mode)?;
            let mut dq = vec![0.0f32; b * nh * s * dh];
            let mut dkx = vec![0.0f32; b * nh * skv * dh];
            let mut dvx = vec![0.0f32; b * nh * skv * dh];
            let inv_sqrt = 1.0 / (dh as f32).sqrt();
            let mut dprow = vec![0.0f32; skv];
            let mut dsrow = vec![0.0f32; skv];
            for bi in 0..b {
                for hh in 0..nh {
                    let bh = bi * nh + hh;
                    for si in 0..s {
                        let dc = &dctx[(bi * s + si) * d + hh * dh..(bi * s + si) * d + (hh + 1) * dh];
                        let prow = &lc.probs[(bh * s + si) * skv..(bh * s + si + 1) * skv];
                        for t in 0..skv {
                            let vrow = &lc.v[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            dprow[t] = linalg::dot(dc, vrow);
                            let pv = prow[t];
                            if pv != 0.0 {
                                let dvr = &mut dvx[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                                for (dvv, dcv) in dvr.iter_mut().zip(dc) {
                                    *dvv += pv * dcv;
                                }
                            }
                        }
                        nn::softmax_row_bwd(&dprow, prow, &mut dsrow);
                        let dqr = &mut dq[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        let qrow = &lc.q[(bh * s + si) * dh..(bh * s + si + 1) * dh];
                        for t in 0..skv {
                            let ds = dsrow[t] * inv_sqrt;
                            if ds == 0.0 {
                                continue;
                            }
                            let krow = &lc.k[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (dqv, kv) in dqr.iter_mut().zip(krow) {
                                *dqv += ds * kv;
                            }
                            let dkr = &mut dkx[(bh * skv + t) * dh..(bh * skv + t + 1) * dh];
                            for (dkv, qv) in dkr.iter_mut().zip(qrow) {
                                *dkv += ds * qv;
                            }
                        }
                    }
                }
            }
            // split off prefix grads, keep the sequence part
            let (mut dk, dv) = if use_prefix {
                if mode == GradMode::Prefix {
                    let mut dpk = vec![0.0f32; nh * plen * dh];
                    let mut dpv = vec![0.0f32; nh * plen * dh];
                    for bi in 0..b {
                        for hh in 0..nh {
                            let src = (bi * nh + hh) * skv * dh;
                            let dst = hh * plen * dh;
                            add_assign(
                                &mut dpk[dst..dst + plen * dh],
                                &dkx[src..src + plen * dh],
                            );
                            add_assign(
                                &mut dpv[dst..dst + plen * dh],
                                &dvx[src..src + plen * dh],
                            );
                        }
                    }
                    grads.add(&format!("prefix_k.{i}"), dpk);
                    grads.add(&format!("prefix_v.{i}"), dpv);
                }
                let mut dk = vec![0.0f32; b * nh * s * dh];
                let mut dv = vec![0.0f32; b * nh * s * dh];
                for bh in 0..b * nh {
                    let src = bh * skv * dh + plen * dh;
                    let dst = bh * s * dh;
                    dk[dst..dst + s * dh].copy_from_slice(&dkx[src..src + s * dh]);
                    dv[dst..dst + s * dh].copy_from_slice(&dvx[src..src + s * dh]);
                }
                (dk, dv)
            } else {
                (dkx, dvx)
            };
            if self.dims.llama {
                self.rope_apply(&mut dq, &cos, &sin, true);
                self.rope_apply(&mut dk, &cos, &sin, true);
            }
            let dqf = self.merge_heads(&dq);
            let dkf = self.merge_heads(&dk);
            let dvf = self.merge_heads(&dv);
            let mut dt1 =
                self.lin_bwd(&dqf, &lc.t_attn, m, &format!("{pre}q"), d, d, &lc.lora_p, &mut grads, mode)?;
            add_assign(
                &mut dt1,
                &self.lin_bwd(&dkf, &lc.t_attn, m, &format!("{pre}k"), d, d, &lc.lora_p, &mut grads, mode)?,
            );
            add_assign(
                &mut dt1,
                &self.lin_bwd(&dvf, &lc.t_attn, m, &format!("{pre}v"), d, d, &lc.lora_p, &mut grads, mode)?,
            );
            dh = dh_mid;
            add_assign(
                &mut dh,
                &self.norm_bwd(&dt1, &lc.h_in, &format!("layers.{i}.attn_norm"), &lc.norm1, m, &mut grads, mode)?,
            );
        }
        if mode == GradMode::Base {
            let mut dembed = vec![0.0f32; v * d];
            for (mi, tok) in x_ids.iter().enumerate() {
                let t = *tok as usize;
                add_assign(&mut dembed[t * d..(t + 1) * d], &dh[mi * d..(mi + 1) * d]);
            }
            grads.add("embed", dembed);
        }
        Ok((loss, grads))
    }
}

/// Effective prefix length of the causal window (0 when prefix is off).
#[inline]
fn plen_of(use_prefix: bool, plen: usize) -> usize {
    if use_prefix {
        plen
    } else {
        0
    }
}

// ------------------------------------------------- fused LoRA linear
//
// The L1 `lora_linear_ref` contract, standalone (used by `Model` and
// pinned against golden fixtures in rust/tests/parity.rs):
//   Y = X @ Wᵀ + ((X @ Aᵀ)·mask) @ Bᵀ · scale

/// Forward; returns `(y, p)` where `p = (x@Aᵀ)·mask` is the tape entry
/// the backward pass needs. The base matmul is sparsity-aware (skips the
/// {0,1}-masked zeros of a pruned `w`).
#[allow(clippy::too_many_arguments)]
pub fn lora_linear(
    x: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    rank_mask: &[f32],
    scale: f32,
    m: usize,
    k_in: usize,
    r: usize,
    n_out: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut y = linalg::matmul_nt_auto(x, w, m, k_in, n_out);
    let mut proj = linalg::matmul_nt(x, a, m, k_in, r);
    for row in 0..m {
        for (j, pv) in proj[row * r..(row + 1) * r].iter_mut().enumerate() {
            *pv *= rank_mask[j];
        }
    }
    let yl = linalg::matmul_nt(&proj, b, m, r, n_out);
    axpy(&mut y, scale, &yl);
    (y, proj)
}

/// Backward: `(dx, da, db)` with W frozen (`kernels/ref.py`
/// `lora_linear_bwd_ref`). `proj` is the forward's tape entry.
#[allow(clippy::too_many_arguments)]
pub fn lora_linear_bwd(
    dy: &[f32],
    x: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    rank_mask: &[f32],
    scale: f32,
    proj: &[f32],
    m: usize,
    k_in: usize,
    r: usize,
    n_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dp = linalg::matmul_nn(dy, b, m, n_out, r);
    for row in 0..m {
        for (j, dpv) in dp[row * r..(row + 1) * r].iter_mut().enumerate() {
            *dpv *= rank_mask[j] * scale;
        }
    }
    let mut dx = linalg::matmul_nn(dy, w, m, n_out, k_in);
    add_assign(&mut dx, &linalg::matmul_nn(&dp, a, m, r, k_in));
    let da = linalg::matmul_tn(&dp, x, m, r, k_in);
    let mut db = linalg::matmul_tn(dy, proj, m, n_out, r);
    for dv in db.iter_mut() {
        *dv *= scale;
    }
    (dx, da, db)
}
