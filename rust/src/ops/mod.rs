//! Pure-Rust compute kernels for the native CPU backend.
//!
//! These implement the same math the AOT'd XLA artifacts execute —
//! tiled, threaded, sparsity-aware matmuls over prepared weights
//! ([`linalg`]), norm/activation/loss primitives with hand-derived
//! backward passes ([`nn`]), the full decoder forward/backward over a
//! reusable scratch arena ([`model`], [`scratch`]), and the Wanda /
//! magnitude / SparseGPT-lite prune ops ([`prune`]).
//!
//! # Kernel architecture
//!
//! The hot path is layered so each concern stays independent and every
//! layer is deterministic on its own:
//!
//! 1. **Element kernels** (`linalg`): dense dots, CSR/CSC gather dots,
//!    and the `reduce_*` row reductions, each in two gated forms — an
//!    8-lane SIMD shape (explicit `f32x8`-style accumulators with a
//!    scalar tail and a fixed combine tree, which LLVM autovectorizes)
//!    and the pre-SIMD scalar form (`SHEARS_SIMD=off`). Within a mode,
//!    blocked and unblocked paths agree **bitwise** per element.
//! 2. **Representation** (`linalg::PreparedWeight`): one scan per
//!    resident buffer picks register-blocked dense vs CSR (> 30%
//!    zeros); sparse weights lazily add a CSC (column-major) companion
//!    on the first backward, so `dx = dy @ W` skips zeros too.
//!    Invalidation is by `ParamStore` generation via buffer re-upload.
//! 3. **Dispatch** (`linalg` worker pool): contiguous output-row ranges
//!    are claimed by persistent parked workers (`SHEARS_NUM_THREADS`
//!    sized, `SHEARS_POOL=off` falls back to per-call `thread::scope`).
//!    Partitioning never splits the reduction inside an element, so
//!    results are bit-identical at any thread count and under either
//!    dispatch mechanism.
//! 4. **Memory** (`scratch`): all intermediates come from a
//!    capacity-bucketed arena owned by the backend; steady-state
//!    forward/train steps allocate nothing per matmul.
//! 5. **Serving** (`model::DecodeModel` + `model::DecodeState`): the
//!    KV-cached incremental decode path — a name-free binding of a
//!    forward entry over per-slot cache columns, mirroring the batch
//!    forward kernel-for-kernel so prefill + one-token steps reproduce
//!    the padded re-forward logits at O(1) cost per token (warm steps
//!    are allocation-free).
//!
//! Numerics are pinned against the L1 reference (`kernels/ref.py`) by
//! the golden-fixture suite in `rust/tests/parity.rs` (including the
//! forced-sparse CSR/CSC paths against `jax.grad`); the backend that
//! marshals manifest entry points onto these kernels lives in
//! [`crate::runtime::native`].

pub mod linalg;
pub mod model;
pub mod nn;
pub mod prune;
pub mod scratch;

pub use linalg::PreparedWeight;
pub use model::{
    lora_linear, lora_linear_bwd, AdapterBinding, DecodeModel, DecodeState, Dims, Extra, Forward,
    GradMode, Grads, Model, NamedTensors, PreparedCell, RowAdapters,
};
pub use scratch::Scratch;
