//! Pure-Rust compute kernels for the native CPU backend.
//!
//! These implement the same math the AOT'd XLA artifacts execute —
//! tiled, threaded, sparsity-aware matmuls over prepared weights
//! ([`linalg`]), norm/activation/loss primitives with hand-derived
//! backward passes ([`nn`]), the full decoder forward/backward over a
//! reusable scratch arena ([`model`], [`scratch`]), and the Wanda /
//! magnitude / SparseGPT-lite prune ops ([`prune`]).
//!
//! Numerics are pinned against the L1 reference (`kernels/ref.py`) by
//! the golden-fixture suite in `rust/tests/parity.rs`; the backend that
//! marshals manifest entry points onto these kernels lives in
//! [`crate::runtime::native`].

pub mod linalg;
pub mod model;
pub mod nn;
pub mod prune;
pub mod scratch;

pub use linalg::PreparedWeight;
pub use model::{
    lora_linear, lora_linear_bwd, Dims, Extra, Forward, GradMode, Grads, Model, NamedTensors,
    PreparedCell,
};
pub use scratch::Scratch;
