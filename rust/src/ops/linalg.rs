//! Dense + sparsity-aware matmul primitives for the native CPU backend.
//!
//! Weight convention matches the whole stack (`kernels/ref.py`): weights
//! are `[out, in]` row-major, activations `[M, K]` row-major, so the hot
//! product `Y = X @ Wᵀ` is a grid of contiguous-row dot products — the
//! cache-friendly layout that needs no transposition.
//!
//! Four levers make this the kernel engine (ISSUE 2 + ISSUE 3):
//!
//! * **[`PreparedWeight`]** — the §3.1 sparsity lever. A frozen weight is
//!   scanned **once** into either a dense marker or a CSR gather
//!   (`row_start`/`idx`/`val`) when it is past [`SPARSE_THRESHOLD`]
//!   zeros; every subsequent matmul skips the zeros without re-deriving
//!   the structure. Since ISSUE 3 a CSR weight also lazily caches a
//!   **CSC (column-major) companion** ([`CscView`]) so the backward
//!   `dx = dy @ W` ([`matmul_nn_prepared_into`]) is sparsity-aware too —
//!   the step that turns 50% sparsity into a training-time speedup, not
//!   just a forward one. The per-call gather survives only as the
//!   fallback for unprepared host tensors ([`matmul_nt_auto`]).
//! * **SIMD-shaped microkernels** — every reduction (dense dots, CSR/CSC
//!   gathers, the `nn.rs` norm/softmax sums through the `reduce_*`
//!   helpers) runs over **8 explicit accumulator lanes** with a scalar
//!   tail and a fixed combine tree: the safe-Rust shape LLVM turns into
//!   `f32x8` vector code. `SHEARS_SIMD=off` ([`set_simd_enabled`])
//!   selects the pre-SIMD scalar kernels instead; each mode is
//!   bit-stable and thread-invariant on its own, and the two agree to
//!   f32 round-off (elementwise kernels like [`axpy`] are bit-identical
//!   across modes — only reduction order differs).
//! * **Register-blocked tiles** — [`matmul_nt_into`] processes x-rows in
//!   blocks of [`MR`], streaming each weight row once per block instead
//!   of once per row (a 4× cut in weight traffic). Per output element
//!   the accumulation order is *identical* to the unblocked [`dot`]
//!   (same lanes, same combine), so blocked and unblocked paths agree
//!   bitwise within a SIMD mode.
//! * **Persistent worker pool** — kernels dispatch contiguous output-row
//!   ranges to parked worker threads ([`pool`]) instead of spawning a
//!   `std::thread::scope` per call, so small matmuls (the M=1 serving
//!   decode shape, sub-adapter search) stop paying spawn cost.
//!   `SHEARS_NUM_THREADS` / [`set_num_threads`] still size the dispatch
//!   (resizes between dispatches are safe: sizing is read per dispatch
//!   and the pool only grows, under its own lock); `SHEARS_POOL=off`
//!   ([`set_pool_enabled`]) restores the scoped per-call dispatch.
//!   Partitioning only splits *rows between* workers, never the
//!   reduction *within* an element, so results are bit-identical for
//!   every thread count and either dispatch mechanism, and the golden
//!   parity fixtures are unaffected.
//!
//! The `_into` variants write into caller-provided buffers (the
//! [`crate::ops::scratch::Scratch`] arena in the model hot path) so
//! steady-state forward/train loops do not allocate per matmul.

/// Fraction of zeros in a weight above which the CSR gather path wins.
pub const SPARSE_THRESHOLD: f64 = 0.3;

/// x-row register block for the dense kernel.
const MR: usize = 4;

/// Accumulator lanes in the SIMD-shaped kernels (the AVX2 `f32x8`
/// width; also two NEON `f32x4`s).
const LANES: usize = 8;

use std::cell::OnceCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimum multiply-accumulate ops per worker before handing work to
/// the pool (amortizes wake/claim overhead; with the scoped fallback it
/// amortizes spawns, as before).
const DEFAULT_PAR_MIN_WORK: usize = 1 << 17;

// ORDERING(PAR_MIN_WORK): config — set once at startup/test setup;
// kernels snapshot it per dispatch, no cross-thread publication duty.
static PAR_MIN_WORK: AtomicUsize = AtomicUsize::new(DEFAULT_PAR_MIN_WORK);

/// Lower the fork threshold so tiny shapes still take the threaded
/// path — test/bench hook; production code leaves the default.
/// `0` restores the default threshold.
#[doc(hidden)]
pub fn set_par_min_work(w: usize) {
    let w = if w == 0 { DEFAULT_PAR_MIN_WORK } else { w };
    PAR_MIN_WORK.store(w, Ordering::Relaxed);
}

/// 0 = uninitialized; resolved lazily from `SHEARS_NUM_THREADS` or the
/// machine's available parallelism.
// ORDERING(NUM_THREADS): config — sizing knob read per dispatch;
// results are partition-invariant so staleness is benign.
static NUM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Worker count for the kernel dispatchers. Resolution order:
/// [`set_num_threads`] override > `SHEARS_NUM_THREADS` env var >
/// `std::thread::available_parallelism()`.
pub fn num_threads() -> usize {
    let n = NUM_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::env::var("SHEARS_NUM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|n| *n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    let n = n.clamp(1, 64);
    NUM_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (tests, CLI `--threads`). Values are
/// clamped to `[1, 64]`; `0` falls back to env/auto resolution on the
/// next [`num_threads`] call. Thread count never changes results, only
/// speed — and it never touches the live pool: each dispatch reads the
/// count once and the pool grows lazily under its own lock, so calling
/// this between (or even during) dispatches cannot race a running job.
pub fn set_num_threads(n: usize) {
    let n = if n == 0 { 0 } else { n.clamp(1, 64) };
    NUM_THREADS.store(n, Ordering::Relaxed);
}

// ------------------------------------------------------- feature gates

/// 0 = resolve from env, 1 = on, 2 = off.
// ORDERING(SIMD_MODE): config — mode latch resolved once from env;
// both modes are bit-identical, so ordering carries no correctness.
static SIMD_MODE: AtomicUsize = AtomicUsize::new(0);

/// Whether the 8-lane SIMD-shaped kernels are active (default) or the
/// pre-SIMD scalar kernels (`SHEARS_SIMD=off|0|false`). Both modes are
/// deterministic and thread-invariant; they differ at f32 round-off in
/// reductions only.
pub fn simd_enabled() -> bool {
    match SIMD_MODE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let off = std::env::var("SHEARS_SIMD")
                .map(|v| matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false"))
                .unwrap_or(false);
            SIMD_MODE.store(if off { 2 } else { 1 }, Ordering::Relaxed);
            !off
        }
    }
}

/// Force the SIMD mode (tests, benches). Overrides `SHEARS_SIMD`.
pub fn set_simd_enabled(on: bool) {
    SIMD_MODE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// 0 = resolve from env, 1 = on, 2 = off.
// ORDERING(POOL_MODE): config — dispatch-strategy latch; pool and
// scoped dispatch produce identical results.
static POOL_MODE: AtomicUsize = AtomicUsize::new(0);

/// Whether multi-threaded dispatch uses the persistent worker pool
/// (default) or a per-call `std::thread::scope`
/// (`SHEARS_POOL=off|0|false|scope`). Results are bit-identical either
/// way — this is purely a wall-clock / debugging lever.
pub fn pool_enabled() -> bool {
    match POOL_MODE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let off = std::env::var("SHEARS_POOL")
                .map(|v| matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false" | "scope"))
                .unwrap_or(false);
            POOL_MODE.store(if off { 2 } else { 1 }, Ordering::Relaxed);
            !off
        }
    }
}

/// Force the dispatch mechanism (tests, benches). Overrides `SHEARS_POOL`.
pub fn set_pool_enabled(on: bool) {
    POOL_MODE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

// ----------------------------------------------------------- dot cores

/// Fixed combine tree over the 8 lane partials — shared by every laned
/// reduction so equal lane contents always produce equal bits.
#[inline]
fn hsum(s: &[f32; LANES]) -> f32 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

/// 8-lane dot: lane `l` accumulates elements `j ≡ l (mod 8)` of the
/// chunked prefix, the tail is sequential, combine via [`hsum`].
#[inline]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let mut s = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (av, bv) in ac.by_ref().zip(bc.by_ref()) {
        for l in 0..LANES {
            s[l] += av[l] * bv[l];
        }
    }
    let mut tail = 0.0f32;
    for (av, bv) in ac.remainder().iter().zip(bc.remainder()) {
        tail += av * bv;
    }
    hsum(&s) + tail
}

/// Pre-SIMD dot (4-way partial sums) — the `SHEARS_SIMD=off` kernel.
#[inline]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..a.len() {
        tail += a[j] * b[j];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Dot product of two equal-length slices (mode-gated).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if simd_enabled() {
        dot_lanes(a, b)
    } else {
        dot_scalar(a, b)
    }
}

/// Four 8-lane dots sharing one streamed `w` row. Per row the lane
/// assignment and combine order are exactly those of [`dot_lanes`], so
/// a row computed here is bit-identical to the unblocked path.
#[inline]
fn dot4_lanes(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], w: &[f32]) -> [f32; 4] {
    let k = w.len();
    let chunks = k / LANES;
    let mut s = [[0.0f32; LANES]; MR];
    for i in 0..chunks {
        let j = i * LANES;
        let wv = &w[j..j + LANES];
        for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
            let xv = &xr[j..j + LANES];
            for l in 0..LANES {
                s[r][l] += xv[l] * wv[l];
            }
        }
    }
    let mut out = [0.0f32; MR];
    for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
        let mut tail = 0.0f32;
        for j in chunks * LANES..k {
            tail += xr[j] * w[j];
        }
        out[r] = hsum(&s[r]) + tail;
    }
    out
}

/// Pre-SIMD blocked dot: per row the partial sums and combine order are
/// exactly those of [`dot_scalar`].
#[inline]
fn dot4_scalar(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], w: &[f32]) -> [f32; 4] {
    let k = w.len();
    let chunks = k / 4;
    let mut s = [[0.0f32; 4]; 4];
    for i in 0..chunks {
        let j = i * 4;
        for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
            s[r][0] += xr[j] * w[j];
            s[r][1] += xr[j + 1] * w[j + 1];
            s[r][2] += xr[j + 2] * w[j + 2];
            s[r][3] += xr[j + 3] * w[j + 3];
        }
    }
    let mut out = [0.0f32; 4];
    for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
        let mut tail = 0.0f32;
        for j in chunks * 4..k {
            tail += xr[j] * w[j];
        }
        out[r] = (s[r][0] + s[r][1]) + (s[r][2] + s[r][3]) + tail;
    }
    out
}

/// Four dot products sharing one streamed `w` row; per row bit-identical
/// to [`dot`] in the same SIMD mode.
#[inline]
fn dot4(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], w: &[f32]) -> [f32; 4] {
    if simd_enabled() {
        dot4_lanes(x0, x1, x2, x3, w)
    } else {
        dot4_scalar(x0, x1, x2, x3, w)
    }
}

/// Sequential gather dot over one compressed (index, value) run — the
/// pre-SIMD CSR/CSC element kernel.
#[inline]
fn gather_dot_scalar(x: &[f32], idx: &[u32], val: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (ki, wv) in idx.iter().zip(val) {
        acc += x[*ki as usize] * wv;
    }
    acc
}

/// 8-lane gather dot (lane assignment/combine as [`dot_lanes`]).
#[inline]
fn gather_dot_lanes(x: &[f32], idx: &[u32], val: &[f32]) -> f32 {
    let mut s = [0.0f32; LANES];
    let mut ic = idx.chunks_exact(LANES);
    let mut vc = val.chunks_exact(LANES);
    for (iv, vv) in ic.by_ref().zip(vc.by_ref()) {
        for l in 0..LANES {
            s[l] += x[iv[l] as usize] * vv[l];
        }
    }
    let mut tail = 0.0f32;
    for (ki, wv) in ic.remainder().iter().zip(vc.remainder()) {
        tail += x[*ki as usize] * wv;
    }
    hsum(&s) + tail
}

/// Gather dot over a compressed run (mode-gated): one element of a
/// CSR forward or CSC backward matmul.
#[inline]
fn gather_dot(x: &[f32], idx: &[u32], val: &[f32]) -> f32 {
    if simd_enabled() {
        gather_dot_lanes(x, idx, val)
    } else {
        gather_dot_scalar(x, idx, val)
    }
}

/// Four gather dots sharing one streamed (index, value) run; per row
/// bit-identical to [`gather_dot`] in the same SIMD mode.
#[inline]
fn gather_dot4(
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
    idx: &[u32],
    val: &[f32],
) -> [f32; 4] {
    if simd_enabled() {
        let mut s = [[0.0f32; LANES]; MR];
        let mut ic = idx.chunks_exact(LANES);
        let mut vc = val.chunks_exact(LANES);
        for (iv, vv) in ic.by_ref().zip(vc.by_ref()) {
            for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
                for l in 0..LANES {
                    s[r][l] += xr[iv[l] as usize] * vv[l];
                }
            }
        }
        let (ir, vr) = (ic.remainder(), vc.remainder());
        let mut out = [0.0f32; MR];
        for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
            let mut tail = 0.0f32;
            for (ki, wv) in ir.iter().zip(vr) {
                tail += xr[*ki as usize] * wv;
            }
            out[r] = hsum(&s[r]) + tail;
        }
        out
    } else {
        let mut acc = [0.0f32; MR];
        for (ki, wv) in idx.iter().zip(val) {
            let ki = *ki as usize;
            acc[0] += x0[ki] * wv;
            acc[1] += x1[ki] * wv;
            acc[2] += x2[ki] * wv;
            acc[3] += x3[ki] * wv;
        }
        acc
    }
}

// ------------------------------------------------- reduction helpers
//
// Row-level reductions for the `nn.rs` norm / softmax / cross-entropy
// paths. Each has an 8-lane form (fixed [`hsum`] combine) and a plain
// sequential fallback matching the pre-SIMD accumulation order exactly,
// selected by [`simd_enabled`]. Both modes are bit-stable; they differ
// only at f32 round-off.

/// Generic laned reduction over `term(j)` for `j in 0..len`.
#[inline]
fn lane_reduce(len: usize, mut term: impl FnMut(usize) -> f32) -> f32 {
    let chunks = len / LANES;
    let mut s = [0.0f32; LANES];
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            s[l] += term(j + l);
        }
    }
    let mut tail = 0.0f32;
    for j in chunks * LANES..len {
        tail += term(j);
    }
    hsum(&s) + tail
}

/// `Σ x` (softmax normalizer over exp'd rows).
#[inline]
pub fn reduce_sum(x: &[f32]) -> f32 {
    if simd_enabled() {
        lane_reduce(x.len(), |j| x[j])
    } else {
        x.iter().sum()
    }
}

/// `Σ x²` (RMSNorm mean square).
#[inline]
pub fn reduce_sum_sq(x: &[f32]) -> f32 {
    if simd_enabled() {
        lane_reduce(x.len(), |j| x[j] * x[j])
    } else {
        x.iter().map(|v| v * v).sum()
    }
}

/// `Σ (x − mu)²` (LayerNorm variance numerator).
#[inline]
pub fn reduce_sq_dev(x: &[f32], mu: f32) -> f32 {
    if simd_enabled() {
        lane_reduce(x.len(), |j| (x[j] - mu) * (x[j] - mu))
    } else {
        x.iter().map(|v| (v - mu) * (v - mu)).sum()
    }
}

/// `Σ a·b` with a *sequential* scalar fallback (the nn.rs reduction
/// shape; the matmul [`dot`] keeps its own 4-way scalar fallback).
#[inline]
pub fn reduce_dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if simd_enabled() {
        lane_reduce(a.len(), |j| a[j] * b[j])
    } else {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }
}

/// `Σ (a·b)·c` (norm backward mixed terms).
#[inline]
pub fn reduce_dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    if simd_enabled() {
        lane_reduce(a.len(), |j| a[j] * b[j] * c[j])
    } else {
        let mut acc = 0.0f32;
        for j in 0..a.len() {
            acc += a[j] * b[j] * c[j];
        }
        acc
    }
}

/// `Σ exp(x − shift)` (log-sum-exp inner sum).
#[inline]
pub fn reduce_sum_exp(x: &[f32], shift: f32) -> f32 {
    if simd_enabled() {
        lane_reduce(x.len(), |j| (x[j] - shift).exp())
    } else {
        x.iter().map(|v| (v - shift).exp()).sum()
    }
}

// --------------------------------------------------------- threading

/// Shareable base pointer for handing disjoint row chunks of one output
/// buffer to pool workers.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer names row ranges of a single `&mut [f32]` whose
// borrow outlives the dispatch, and `chunked_rows` hands each worker a
// disjoint range — no two threads ever touch the same element.
unsafe impl Send for SendPtr {}
// SAFETY: workers only dereference their own disjoint range (above),
// so shared `&SendPtr` access never aliases a mutation.
unsafe impl Sync for SendPtr {}

/// Split `y` into contiguous row ranges and run `f(row_lo, row_hi,
/// rows_slice)` on each, dispatching ranges to the persistent worker
/// pool when `rows * work_per_row` is large enough to be worth it.
/// Determinism: the partition depends only on `(rows, threads)` and `f`
/// computes each output element identically whatever the partition, so
/// neither the thread count nor the dispatch mechanism (pool, scoped
/// fallback, inline) ever changes results.
fn parallel_rows<F>(y: &mut [f32], rows: usize, row_len: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    // hard assert: the raw-pointer chunking below relies on this bound
    // even in release builds (the unsafe block's SAFETY argument)
    assert_eq!(y.len(), rows * row_len, "parallel_rows: output length mismatch");
    let total = rows.saturating_mul(work_per_row);
    let min_work = PAR_MIN_WORK.load(Ordering::Relaxed);
    let threads = num_threads().min(rows).min((total / min_work).max(1));
    if threads <= 1 || row_len == 0 {
        f(0, rows, y);
        return;
    }
    let chunk = rows.div_ceil(threads);
    let n_chunks = rows.div_ceil(chunk);
    let base = SendPtr(y.as_mut_ptr());
    let run_chunk = move |ci: usize| {
        let lo = ci * chunk;
        let hi = rows.min(lo + chunk);
        // SAFETY: chunk ranges [lo, hi) are disjoint across `ci` and lie
        // inside `y`, so every invocation gets an exclusive sub-slice;
        // both dispatchers guarantee all invocations finish before
        // `parallel_rows` returns, bounding the borrow of `y`.
        let rows_slice = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(lo * row_len), (hi - lo) * row_len)
        };
        f(lo, hi, rows_slice);
    };
    if pool_enabled() {
        pool::run(n_chunks, &run_chunk);
    } else {
        scope_run(n_chunks, &run_chunk);
    }
}

/// Per-call `thread::scope` dispatch — the pre-pool mechanism, kept as
/// the `SHEARS_POOL=off` escape hatch, the pool's busy/nested fallback,
/// and the bench baseline for the spawn-cost comparison. Bit-identical
/// to the pool path (same partition, same per-chunk work).
fn scope_run(n_chunks: usize, job: &(dyn Fn(usize) + Sync)) {
    std::thread::scope(|scope| {
        for ci in 1..n_chunks {
            scope.spawn(move || job(ci));
        }
        job(0);
    });
}

/// Persistent kernel worker pool: parked threads claim row-chunk
/// indices of the current job over a shared counter, so small matmuls
/// (M=1 serving decode, sub-adapter search eval) stop paying per-call
/// `thread::scope` spawn cost.
///
/// Invariants:
/// * one job in flight at a time (`DISPATCH`); a dispatch that finds
///   the pool busy — kernels racing from another thread, or a nested
///   dispatch — falls back to [`scope_run`] rather than blocking, so
///   the pool can never deadlock against itself;
/// * [`run`] does not return (not even by unwind) until every claimed
///   chunk finished and all unclaimed chunks are retracted, so the
///   type-erased borrow of the job closure never outlives the call;
/// * [`set_num_threads`] never touches the pool. Sizing is read per
///   dispatch and workers are only ever *added*, under the state lock;
///   excess workers simply find no chunk to claim. Resizing between
///   dispatches therefore cannot race a live job (pinned by
///   `tests/pool_threads.rs`).
mod pool {
    use std::sync::{Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

    /// Borrow of the dispatcher's job closure with the lifetime erased;
    /// dereferenced only between job publication and the completion
    /// wait in [`DispatchGuard::drop`], while the closure is alive.
    #[derive(Clone, Copy)]
    struct JobRef(*const (dyn Fn(usize) + Sync + 'static));
    // SAFETY: the pointee is `Sync` (shared calls are fine from any
    // thread) and outlives every dereference — `DispatchGuard` blocks
    // the dispatching call until `pending == 0`, i.e. until no worker
    // can still reach the pointer.
    unsafe impl Send for JobRef {}

    struct State {
        job: Option<JobRef>,
        n_chunks: usize,
        /// next chunk index to claim (work is claimed, not assigned, so
        /// a slow worker never stalls the others)
        next: usize,
        /// chunks not yet completed (claimed or unclaimed)
        pending: usize,
        /// worker threads spawned so far (grow-only, ≤ 63)
        workers: usize,
        worker_panicked: bool,
    }

    struct Shared {
        state: Mutex<State>,
        /// workers park here between jobs
        work_cv: Condvar,
        /// the dispatcher parks here until `pending == 0`
        done_cv: Condvar,
    }

    static POOL: OnceLock<Shared> = OnceLock::new();
    /// Serializes dispatches; `try_lock` keeps concurrent callers on
    /// the scoped fallback instead of queueing them.
    static DISPATCH: Mutex<()> = Mutex::new(());

    fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Run `job(0..n_chunks)` across the pool plus the calling thread;
    /// returns once every chunk completed.
    pub(super) fn run(n_chunks: usize, job: &(dyn Fn(usize) + Sync)) {
        let _dispatch = match DISPATCH.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                // pool busy (concurrent or nested kernels): scoped
                // dispatch produces bit-identical results
                super::scope_run(n_chunks, job);
                return;
            }
        };
        let shared = POOL.get_or_init(|| Shared {
            state: Mutex::new(State {
                job: None,
                n_chunks: 0,
                next: 0,
                pending: 0,
                workers: 0,
                worker_panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        // SAFETY: lifetime erasure only — `DispatchGuard` below keeps
        // this dispatch alive until no worker can still reach the
        // pointer, and `DISPATCH` guarantees no other job replaces it.
        let erased: *const (dyn Fn(usize) + Sync + 'static) = unsafe { std::mem::transmute(job) };
        let job_ref = JobRef(erased);
        {
            let mut st = lock(&shared.state);
            while st.workers + 1 < n_chunks {
                // the calling thread works too, hence `+ 1`
                match std::thread::Builder::new()
                    .name("shears-kernel".into())
                    .spawn(worker_loop)
                {
                    Ok(_) => st.workers += 1,
                    // degraded environment: the caller just runs more
                    // chunks itself — results are unaffected
                    Err(_) => break,
                }
            }
            st.job = Some(job_ref);
            st.n_chunks = n_chunks;
            st.next = 0;
            st.pending = n_chunks;
        }
        shared.work_cv.notify_all();
        let guard = DispatchGuard { shared };
        // the dispatching thread claims chunks alongside the workers
        loop {
            let mut st = lock(&shared.state);
            if st.next >= st.n_chunks {
                break;
            }
            let ci = st.next;
            st.next += 1;
            drop(st);
            // a claimed chunk must decrement `pending` even if it
            // panics, or the guard's completion wait would deadlock
            // during the unwind
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(ci)));
            let mut st = lock(&shared.state);
            st.pending -= 1;
            if st.pending == 0 {
                shared.done_cv.notify_all();
            }
            drop(st);
            if let Err(payload) = result {
                // the guard retracts unclaimed chunks and waits out
                // in-flight workers before the unwind continues
                std::panic::resume_unwind(payload);
            }
        }
        // waits for in-flight worker chunks, then clears the job
        drop(guard);
    }

    /// Retracts unclaimed chunks, waits out in-flight ones, and clears
    /// the job — also on unwind, so a panicking chunk on the calling
    /// thread cannot leave a worker holding the erased closure pointer.
    struct DispatchGuard<'a> {
        shared: &'a Shared,
    }

    impl Drop for DispatchGuard<'_> {
        fn drop(&mut self) {
            let mut st = lock(&self.shared.state);
            st.pending -= st.n_chunks - st.next;
            st.next = st.n_chunks;
            while st.pending > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
            let panicked = std::mem::replace(&mut st.worker_panicked, false);
            drop(st);
            if panicked && !std::thread::panicking() {
                panic!("a kernel pool worker panicked (worker backtrace on stderr)");
            }
        }
    }

    fn worker_loop() {
        let shared = POOL.get().expect("pool published before workers spawn");
        let mut st = lock(&shared.state);
        loop {
            if let Some(job) = st.job {
                if st.next < st.n_chunks {
                    let ci = st.next;
                    st.next += 1;
                    drop(st);
                    // SAFETY: the dispatcher cannot return before this
                    // chunk decrements `pending`, so the closure behind
                    // `job` is still alive here.
                    let job_fn = unsafe { &*job.0 };
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        job_fn(ci)
                    }))
                    .is_ok();
                    st = lock(&shared.state);
                    if !ok {
                        st.worker_panicked = true;
                    }
                    st.pending -= 1;
                    if st.pending == 0 {
                        shared.done_cv.notify_all();
                    }
                    continue;
                }
            }
            st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// --------------------------------------------------- prepared weights

/// Physical representation chosen for a prepared weight.
pub enum WeightRepr {
    /// Mostly nonzero: the dense register-blocked kernel wins.
    Dense,
    /// Past [`SPARSE_THRESHOLD`] zeros: per-output-row compressed
    /// (index, value) pairs — the Wanda/magnitude-pruned base weights.
    Csr {
        /// `n + 1` offsets into `idx`/`val`.
        row_start: Vec<u32>,
        /// column (input-feature) index of each nonzero
        idx: Vec<u32>,
        /// nonzero values, aligned with `idx`
        val: Vec<f32>,
    },
}

/// Column-major companion of a CSR weight: per input feature (weight
/// column) the output features holding a nonzero there, rows ascending.
/// Drives the sparsity-aware backward `dx[·,k] = Σ_n dy[·,n]·W[n,k]`
/// as a gather over column `k` ([`matmul_nn_prepared_into`]).
pub struct CscView {
    /// `k + 1` offsets into `rows`/`val`.
    pub col_start: Vec<u32>,
    /// weight-row (output-feature) index of each nonzero
    pub rows: Vec<u32>,
    /// nonzero values, aligned with `rows`
    pub val: Vec<f32>,
}

/// A weight scanned **once** into the representation its sparsity
/// merits. Built lazily per resident buffer (see
/// `runtime::DeviceBuffer`) and reused by every subsequent matmul;
/// rebuilt only when the owning buffer is re-uploaded (prune step,
/// optimizer update — tracked by `ParamStore` generations). The CSC
/// companion for the backward pass rides the same lifecycle: built on
/// the first backward through the weight, dropped with the whole
/// `PreparedWeight` on invalidation.
pub struct PreparedWeight {
    /// output features (weight rows)
    pub n: usize,
    /// input features (weight cols)
    pub k: usize,
    /// nonzero count (sparsity accounting)
    pub nnz: usize,
    pub repr: WeightRepr,
    /// lazily-built column-major view (CSR weights only)
    csc: OnceCell<CscView>,
}

impl PreparedWeight {
    /// One O(n·k) scan deciding dense vs CSR and building the gather.
    pub fn build(w: &[f32], n: usize, k: usize) -> PreparedWeight {
        Self::build_with_threshold(w, n, k, SPARSE_THRESHOLD)
    }

    /// [`PreparedWeight::build`] with an explicit zero-fraction
    /// threshold — `0.0` forces the CSR/CSC path even for dense
    /// weights (kernel-parity tests); any threshold above `1.0`
    /// forces the dense path (at exactly `1.0` an all-zero weight
    /// still goes CSR, since the comparison is strict).
    pub fn build_with_threshold(w: &[f32], n: usize, k: usize, threshold: f64) -> PreparedWeight {
        debug_assert_eq!(w.len(), n * k);
        let zeros = w.iter().filter(|v| **v == 0.0).count();
        let nnz = w.len() - zeros;
        if (zeros as f64) < threshold * (w.len().max(1) as f64) {
            return PreparedWeight { n, k, nnz, repr: WeightRepr::Dense, csc: OnceCell::new() };
        }
        let mut row_start = Vec::with_capacity(n + 1);
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        row_start.push(0u32);
        for ni in 0..n {
            for (ki, wv) in w[ni * k..(ni + 1) * k].iter().enumerate() {
                if *wv != 0.0 {
                    idx.push(ki as u32);
                    val.push(*wv);
                }
            }
            row_start.push(idx.len() as u32);
        }
        PreparedWeight {
            n,
            k,
            nnz,
            repr: WeightRepr::Csr { row_start, idx, val },
            csc: OnceCell::new(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self.repr, WeightRepr::Csr { .. })
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.n * self.k).max(1) as f64
    }

    /// The cached column-major view (`None` for dense weights). Built
    /// by counting sort from the CSR arrays on first call — once per
    /// buffer upload, not once per backward matmul.
    pub fn csc(&self) -> Option<&CscView> {
        let WeightRepr::Csr { row_start, idx, val } = &self.repr else {
            return None;
        };
        Some(self.csc.get_or_init(|| {
            let mut col_start = vec![0u32; self.k + 1];
            for ki in idx {
                col_start[*ki as usize + 1] += 1;
            }
            for ki in 0..self.k {
                col_start[ki + 1] += col_start[ki];
            }
            let mut cursor = col_start.clone();
            let mut rows = vec![0u32; idx.len()];
            let mut cval = vec![0.0f32; idx.len()];
            // CSR rows visited in ascending `ni` ⇒ rows ascending per column
            for ni in 0..self.n {
                let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                for (ki, wv) in idx[s..e].iter().zip(&val[s..e]) {
                    let c = &mut cursor[*ki as usize];
                    rows[*c as usize] = ni as u32;
                    cval[*c as usize] = *wv;
                    *c += 1;
                }
            }
            CscView { col_start, rows, val: cval }
        }))
    }

    /// Whether the CSC companion has been materialized (tests/metrics).
    pub fn csc_built(&self) -> bool {
        self.csc.get().is_some()
    }
}

// ------------------------------------------------------------ kernels

/// Dense rows `[lo, hi)` of `y = x @ wᵀ`; `y` holds exactly those rows.
fn nt_rows(x: &[f32], w: &[f32], k: usize, n: usize, lo: usize, hi: usize, y: &mut [f32]) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * n;
        if mi + MR <= hi {
            let x0 = &x[mi * k..(mi + 1) * k];
            let x1 = &x[(mi + 1) * k..(mi + 2) * k];
            let x2 = &x[(mi + 2) * k..(mi + 3) * k];
            let x3 = &x[(mi + 3) * k..(mi + 4) * k];
            for ni in 0..n {
                let d = dot4(x0, x1, x2, x3, &w[ni * k..(ni + 1) * k]);
                y[ybase + ni] = d[0];
                y[ybase + n + ni] = d[1];
                y[ybase + 2 * n + ni] = d[2];
                y[ybase + 3 * n + ni] = d[3];
            }
            mi += MR;
        } else {
            let xr = &x[mi * k..(mi + 1) * k];
            for (ni, yv) in y[ybase..ybase + n].iter_mut().enumerate() {
                *yv = dot(xr, &w[ni * k..(ni + 1) * k]);
            }
            mi += 1;
        }
    }
}

/// CSR rows `[lo, hi)` of `y = x @ wᵀ`, streaming each compressed
/// weight row across a block of x-rows. Per element: one [`gather_dot`]
/// over the nonzeros in column order, whatever the block shape.
#[allow(clippy::too_many_arguments)]
fn csr_rows(
    x: &[f32],
    row_start: &[u32],
    idx: &[u32],
    val: &[f32],
    k: usize,
    n: usize,
    lo: usize,
    hi: usize,
    y: &mut [f32],
) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * n;
        if mi + MR <= hi {
            let x0 = &x[mi * k..(mi + 1) * k];
            let x1 = &x[(mi + 1) * k..(mi + 2) * k];
            let x2 = &x[(mi + 2) * k..(mi + 3) * k];
            let x3 = &x[(mi + 3) * k..(mi + 4) * k];
            for ni in 0..n {
                let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                let a = gather_dot4(x0, x1, x2, x3, &idx[s..e], &val[s..e]);
                y[ybase + ni] = a[0];
                y[ybase + n + ni] = a[1];
                y[ybase + 2 * n + ni] = a[2];
                y[ybase + 3 * n + ni] = a[3];
            }
            mi += MR;
        } else {
            let xr = &x[mi * k..(mi + 1) * k];
            for (ni, yv) in y[ybase..ybase + n].iter_mut().enumerate() {
                let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                *yv = gather_dot(xr, &idx[s..e], &val[s..e]);
            }
            mi += 1;
        }
    }
}

/// CSC rows `[lo, hi)` of `dx = dy @ w`: element `(mi, ki)` gathers
/// column `ki`'s nonzeros against the `dy` row — the same gather-dot
/// the CSR forward uses, so per-element accumulation order is
/// partition- and block-invariant.
#[allow(clippy::too_many_arguments)]
fn csc_rows(
    dy: &[f32],
    col_start: &[u32],
    rows: &[u32],
    val: &[f32],
    n: usize,
    k: usize,
    lo: usize,
    hi: usize,
    y: &mut [f32],
) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * k;
        if mi + MR <= hi {
            let d0 = &dy[mi * n..(mi + 1) * n];
            let d1 = &dy[(mi + 1) * n..(mi + 2) * n];
            let d2 = &dy[(mi + 2) * n..(mi + 3) * n];
            let d3 = &dy[(mi + 3) * n..(mi + 4) * n];
            for ki in 0..k {
                let (s, e) = (col_start[ki] as usize, col_start[ki + 1] as usize);
                let a = gather_dot4(d0, d1, d2, d3, &rows[s..e], &val[s..e]);
                y[ybase + ki] = a[0];
                y[ybase + k + ki] = a[1];
                y[ybase + 2 * k + ki] = a[2];
                y[ybase + 3 * k + ki] = a[3];
            }
            mi += MR;
        } else {
            let dr = &dy[mi * n..(mi + 1) * n];
            for (ki, yv) in y[ybase..ybase + k].iter_mut().enumerate() {
                let (s, e) = (col_start[ki] as usize, col_start[ki + 1] as usize);
                *yv = gather_dot(dr, &rows[s..e], &val[s..e]);
            }
            mi += 1;
        }
    }
}

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` (dense, threaded). `y` is overwritten.
pub fn matmul_nt_into(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(y.len(), m * n);
    if m == 1 {
        // serving shape: one activation row → partition output columns
        parallel_rows(y, n, 1, k, |lo, _hi, yc| {
            for (j, yv) in yc.iter_mut().enumerate() {
                let ni = lo + j;
                *yv = dot(x, &w[ni * k..(ni + 1) * k]);
            }
        });
    } else {
        parallel_rows(y, m, n, n * k, |lo, hi, yc| nt_rows(x, w, k, n, lo, hi, yc));
    }
}

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` (dense).
pub fn matmul_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nt_into(x, w, m, k, n, &mut y);
    y
}

/// Dense rows `[lo, hi)` of `y = x @ wᵀ` where weight row `ni` is the
/// `k`-prefix of the `ws`-long stored row at `w[ni * ws..]`. Each dot
/// reads one contiguous length-`k` slice, so per-element results are
/// bit-identical to [`nt_rows`] over a repacked `[n, k]` buffer.
#[allow(clippy::too_many_arguments)]
fn nt_rows_strided(
    x: &[f32],
    w: &[f32],
    k: usize,
    n: usize,
    ws: usize,
    lo: usize,
    hi: usize,
    y: &mut [f32],
) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * n;
        if mi + MR <= hi {
            let x0 = &x[mi * k..(mi + 1) * k];
            let x1 = &x[(mi + 1) * k..(mi + 2) * k];
            let x2 = &x[(mi + 2) * k..(mi + 3) * k];
            let x3 = &x[(mi + 3) * k..(mi + 4) * k];
            for ni in 0..n {
                let d = dot4(x0, x1, x2, x3, &w[ni * ws..ni * ws + k]);
                y[ybase + ni] = d[0];
                y[ybase + n + ni] = d[1];
                y[ybase + 2 * n + ni] = d[2];
                y[ybase + 3 * n + ni] = d[3];
            }
            mi += MR;
        } else {
            let xr = &x[mi * k..(mi + 1) * k];
            for (ni, yv) in y[ybase..ybase + n].iter_mut().enumerate() {
                *yv = dot(xr, &w[ni * ws..ni * ws + k]);
            }
            mi += 1;
        }
    }
}

/// `y[M,N] = x[M,K] @ wᵀ` where weight row `ni` is the `k`-prefix of
/// the `ws`-long stored row at `w[ni * ws..]` — a rank-truncated
/// prefix sub-adapter's B term reads its parent's `[N, ws]` buffer in
/// place, no repack. With `ws == k` this computes exactly
/// [`matmul_nt_into`] (callers on the hot path branch to that kernel
/// so the full-rank path stays byte-for-byte the same code).
pub fn matmul_nt_strided_into(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ws: usize,
    y: &mut [f32],
) {
    debug_assert!(k <= ws && k > 0);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * ws);
    debug_assert_eq!(y.len(), m * n);
    if m == 1 {
        // serving shape: one activation row → partition output columns
        parallel_rows(y, n, 1, k, |lo, _hi, yc| {
            for (j, yv) in yc.iter_mut().enumerate() {
                let ni = lo + j;
                *yv = dot(x, &w[ni * ws..ni * ws + k]);
            }
        });
    } else {
        parallel_rows(y, m, n, n * k, |lo, hi, yc| nt_rows_strided(x, w, k, n, ws, lo, hi, yc));
    }
}

/// `y = x @ wᵀ` through a prepared representation: the CSR gather for
/// sparse weights, the register-blocked dense kernel otherwise. `w`
/// must be the same buffer `pw` was built from (used on the dense path).
pub fn matmul_nt_prepared_into(
    x: &[f32],
    w: &[f32],
    pw: &PreparedWeight,
    m: usize,
    y: &mut [f32],
) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(y.len(), m * n);
    match &pw.repr {
        WeightRepr::Dense => matmul_nt_into(x, w, m, k, n, y),
        WeightRepr::Csr { row_start, idx, val } => {
            if m == 1 {
                parallel_rows(y, n, 1, pw.nnz / n.max(1) + 1, |lo, _hi, yc| {
                    for (j, yv) in yc.iter_mut().enumerate() {
                        let ni = lo + j;
                        let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                        *yv = gather_dot(x, &idx[s..e], &val[s..e]);
                    }
                });
            } else {
                let work = n * (pw.nnz / n.max(1) + 1);
                parallel_rows(y, m, n, work, |lo, hi, yc| {
                    csr_rows(x, row_start, idx, val, k, n, lo, hi, yc)
                });
            }
        }
    }
}

/// `y = x @ wᵀ`, skipping zero weight entries when the weight is sparse
/// enough (the {0,1}-masked, Wanda-pruned base weights). Scans and
/// gathers **per call** — callers on the hot path should hold a
/// [`PreparedWeight`] (resident-buffer cache) and use
/// [`matmul_nt_prepared_into`] instead.
pub fn matmul_nt_auto(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nt_auto_into(x, w, m, k, n, &mut y);
    y
}

/// Per-call-prepared variant of [`matmul_nt_prepared_into`].
pub fn matmul_nt_auto_into(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    let pw = PreparedWeight::build(w, n, k);
    matmul_nt_prepared_into(x, w, &pw, m, y);
}

/// `y[M,N] = a[M,K] @ b[K,N]` (row-major, axpy inner loop, threaded).
/// `y`'s prior contents are ignored.
pub fn matmul_nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    parallel_rows(y, m, n, n * k, |lo, hi, yc| {
        yc.fill(0.0);
        for mi in lo..hi {
            let ar = &a[mi * k..(mi + 1) * k];
            let yr = &mut yc[(mi - lo) * n..(mi - lo + 1) * n];
            for (ki, av) in ar.iter().enumerate() {
                if *av == 0.0 {
                    continue;
                }
                axpy(yr, *av, &b[ki * n..(ki + 1) * n]);
            }
        }
    });
}

/// `y[M,N] = a[M,K] @ b[K,N]` (row-major, axpy inner loop).
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nn_into(a, b, m, k, n, &mut y);
    y
}

/// `dx[M,K] = dy[M,N] @ w[N,K]` through a prepared representation — the
/// backward companion of [`matmul_nt_prepared_into`] (`w` row-major
/// `[n, k]`, the same buffer `pw` was built from). Sparse weights route
/// through the cached [`CscView`] and skip the pruned zeros; dense
/// weights take the threaded axpy kernel. `dx` is overwritten.
pub fn matmul_nn_prepared_into(
    dy: &[f32],
    w: &[f32],
    pw: &PreparedWeight,
    m: usize,
    dx: &mut [f32],
) {
    let (n, k) = (pw.n, pw.k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dx.len(), m * k);
    match pw.csc() {
        None => matmul_nn_into(dy, w, m, n, k, dx),
        Some(csc) => {
            let (cs, rs, vs) = (&csc.col_start, &csc.rows, &csc.val);
            parallel_rows(dx, m, k, pw.nnz.max(1), |lo, hi, yc| {
                csc_rows(dy, cs, rs, vs, n, k, lo, hi, yc)
            });
        }
    }
}

/// `dx[M,K] = dy[M,N] @ w[N,K]` through a prepared representation.
pub fn matmul_nn_prepared(dy: &[f32], w: &[f32], pw: &PreparedWeight, m: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * pw.k];
    matmul_nn_prepared_into(dy, w, pw, m, &mut dx);
    dx
}

/// `y[M,N] = a[K,M]ᵀ @ b[K,N]` (gradient shape: `dW = dyᵀ @ x`),
/// threaded over output rows. `y`'s prior contents are ignored.
pub fn matmul_tn_into(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    parallel_rows(y, m, n, n * k, |lo, hi, yc| {
        yc.fill(0.0);
        for ki in 0..k {
            let ar = &a[ki * m..(ki + 1) * m];
            let br = &b[ki * n..(ki + 1) * n];
            for mi in lo..hi {
                let av = ar[mi];
                if av == 0.0 {
                    continue;
                }
                let yr = &mut yc[(mi - lo) * n..(mi - lo + 1) * n];
                axpy(yr, av, br);
            }
        }
    });
}

/// `y[M,N] = a[K,M]ᵀ @ b[K,N]` (gradient shape: `dW = dyᵀ @ x`).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_tn_into(a, b, k, m, n, &mut y);
    y
}

/// `y += x`, elementwise. Lane-chunked when SIMD is on; elementwise
/// updates are order-independent per element, so both modes produce
/// bit-identical results (unlike the gated reductions).
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    if simd_enabled() {
        let mut yc = y.chunks_exact_mut(LANES);
        let mut xc = x.chunks_exact(LANES);
        for (yv, xv) in yc.by_ref().zip(xc.by_ref()) {
            for l in 0..LANES {
                yv[l] += xv[l];
            }
        }
        for (yv, xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *yv += xv;
        }
    } else {
        for (yv, xv) in y.iter_mut().zip(x) {
            *yv += xv;
        }
    }
}

/// `y += s * x`, elementwise. Like [`add_assign`], bit-identical across
/// SIMD modes.
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    if simd_enabled() {
        let mut yc = y.chunks_exact_mut(LANES);
        let mut xc = x.chunks_exact(LANES);
        for (yv, xv) in yc.by_ref().zip(xc.by_ref()) {
            for l in 0..LANES {
                yv[l] += s * xv[l];
            }
        }
        for (yv, xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
            *yv += s * xv;
        }
    } else {
        for (yv, xv) in y.iter_mut().zip(x) {
            *yv += s * xv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0; m * n];
        for mi in 0..m {
            for ni in 0..n {
                for ki in 0..k {
                    y[mi * n + ni] += x[mi * k + ki] * w[ni * k + ki];
                }
            }
        }
        y
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0; m * n];
        for mi in 0..m {
            for ki in 0..k {
                for ni in 0..n {
                    y[mi * n + ni] += a[mi * k + ki] * b[ki * n + ni];
                }
            }
        }
        y
    }

    #[test]
    fn nt_matches_naive_and_sparse_path() {
        let (m, k, n) = (3, 7, 5);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.61).cos()).collect();
        // sparsify half of w so the auto path takes the gather route
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *wv = 0.0;
            }
        }
        let reference = naive_nt(&x, &w, m, k, n);
        for y in [matmul_nt(&x, &w, m, k, n), matmul_nt_auto(&x, &w, m, k, n)] {
            for (a, b) in y.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn prepared_weight_picks_repr_and_matches_dense() {
        let (m, k, n) = (6, 9, 4);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.21).sin()).collect();
        let dense: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.13).cos()).collect();
        let pw = PreparedWeight::build(&dense, n, k);
        assert!(!pw.is_sparse());
        assert_eq!(pw.nnz, n * k);

        let mut sparse = dense.clone();
        for (i, wv) in sparse.iter_mut().enumerate() {
            if i % 3 != 0 {
                *wv = 0.0;
            }
        }
        let pw = PreparedWeight::build(&sparse, n, k);
        assert!(pw.is_sparse());
        assert!((pw.density() - pw.nnz as f64 / (n * k) as f64).abs() < 1e-12);
        let reference = naive_nt(&x, &sparse, m, k, n);
        let mut y = vec![0.0f32; m * n];
        matmul_nt_prepared_into(&x, &sparse, &pw, m, &mut y);
        for (a, b) in y.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn single_row_path_matches_multi_row_kernel() {
        // M=1 dispatches over output columns; must equal the row kernel
        let (k, n) = (13, 11);
        let x: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.3).cos()).collect();
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *wv = 0.0;
            }
        }
        let naive = naive_nt(&x, &w, 1, k, n);
        for y in [matmul_nt(&x, &w, 1, k, n), matmul_nt_auto(&x, &w, 1, k, n)] {
            for (a, b) in y.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn strided_nt_matches_repacked_prefix() {
        // reading the k-prefix of ws-long rows in place must be
        // bit-identical to repacking those prefixes into [n, k] —
        // both the m=1 column path and the blocked row kernel
        let (k, n, ws) = (3, 11, 8);
        let w: Vec<f32> = (0..n * ws).map(|i| (i as f32 * 0.19).cos()).collect();
        let mut packed = vec![0.0f32; n * k];
        for ni in 0..n {
            packed[ni * k..(ni + 1) * k].copy_from_slice(&w[ni * ws..ni * ws + k]);
        }
        for m in [1usize, 6] {
            let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.41).sin()).collect();
            let reference = matmul_nt(&x, &packed, m, k, n);
            let mut y = vec![0.0f32; m * n];
            matmul_nt_strided_into(&x, &w, m, k, n, ws, &mut y);
            assert_eq!(y, reference, "m={m}");
        }
        // full-width stride degenerates to the plain kernel
        let x: Vec<f32> = (0..2 * ws).map(|i| (i as f32 * 0.07).sin()).collect();
        let mut y = vec![0.0f32; 2 * n];
        matmul_nt_strided_into(&x, &w, 2, ws, n, ws, &mut y);
        assert_eq!(y, matmul_nt(&x, &w, 2, ws, n));
    }

    #[test]
    fn thread_count_never_changes_results() {
        // deterministic row partition: bit-identical across pool sizes
        let (m, k, n) = (9, 17, 7);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.11).sin()).collect();
        let w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.23).cos()).collect();
        let before = num_threads();
        set_par_min_work(1); // force the fork even at this tiny size
        set_num_threads(1);
        let y1 = matmul_nt(&x, &w, m, k, n);
        let nn1 = matmul_nn(&x, &w, m, k, n); // w reinterpreted as [k, n]
        set_num_threads(3);
        let y3 = matmul_nt(&x, &w, m, k, n);
        let nn3 = matmul_nn(&x, &w, m, k, n);
        set_num_threads(before);
        set_par_min_work(0);
        assert_eq!(y1, y3);
        assert_eq!(nn1, nn3);
    }

    #[test]
    fn nn_and_tn_agree_with_transposes() {
        let (m, k, n) = (4, 3, 6);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.1 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.2).sin()).collect();
        // a @ b == a @ (bᵀ)ᵀ: check nn against nt with explicitly transposed b
        let mut bt = vec![0.0f32; n * k];
        for ki in 0..k {
            for ni in 0..n {
                bt[ni * k + ki] = b[ki * n + ni];
            }
        }
        let y1 = matmul_nn(&a, &b, m, k, n);
        let y2 = matmul_nt(&a, &bt, m, k, n);
        for (p, q) in y1.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-5);
        }
        // aᵀᵀ @ b via tn on the transposed a
        let mut at = vec![0.0f32; k * m];
        for mi in 0..m {
            for ki in 0..k {
                at[ki * m + mi] = a[mi * k + ki];
            }
        }
        let y3 = matmul_tn(&at, &b, k, m, n);
        for (p, q) in y3.iter().zip(&y1) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_tails() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(dot(&a, &b), 30.0);
        assert_eq!(dot(&a[..1], &b[..1]), 2.0);
        assert_eq!(dot(&[], &[]), 0.0);
        // exercise the laned chunk + tail split explicitly
        let long: Vec<f32> = (0..19).map(|i| i as f32 * 0.5).collect();
        let ones = vec![1.0f32; 19];
        let want: f32 = long.iter().sum();
        assert!((dot_lanes(&long, &ones) - want).abs() < 1e-4);
        assert!((dot_scalar(&long, &ones) - want).abs() < 1e-4);
    }

    #[test]
    fn empty_and_all_zero_weights() {
        // all-zero weight: CSR with zero nonzeros, result all zeros
        let (m, k, n) = (3, 5, 4);
        let x: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let w = vec![0.0f32; n * k];
        let pw = PreparedWeight::build(&w, n, k);
        assert!(pw.is_sparse());
        assert_eq!(pw.nnz, 0);
        let y = matmul_nt_auto(&x, &w, m, k, n);
        assert!(y.iter().all(|v| *v == 0.0));
        // and the CSC backward of an all-zero weight is all zeros
        let dy: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let dx = matmul_nn_prepared(&dy, &w, &pw, m);
        assert!(dx.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn csc_view_is_a_faithful_transpose_index() {
        let (n, k) = (5, 9);
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.31).sin()).collect();
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 3 != 1 {
                *wv = 0.0;
            }
        }
        let pw = PreparedWeight::build(&w, n, k);
        assert!(pw.is_sparse());
        assert!(!pw.csc_built());
        let csc = pw.csc().expect("sparse weight has a csc view");
        assert!(pw.csc_built());
        assert_eq!(csc.col_start.len(), k + 1);
        assert_eq!(csc.rows.len(), pw.nnz);
        // every (row, col, val) triple of the original weight, exactly once
        let mut seen = 0usize;
        for ki in 0..k {
            let (s, e) = (csc.col_start[ki] as usize, csc.col_start[ki + 1] as usize);
            let mut prev = None;
            for (ni, wv) in csc.rows[s..e].iter().zip(&csc.val[s..e]) {
                assert_eq!(*wv, w[*ni as usize * k + ki], "value mismatch at ({ni}, {ki})");
                if let Some(p) = prev {
                    assert!(p < *ni, "rows not ascending in col {ki}");
                }
                prev = Some(*ni);
                seen += 1;
            }
        }
        assert_eq!(seen, pw.nnz);
        // repeated access hands back the same cached view
        assert!(std::ptr::eq(csc, pw.csc().unwrap()));
    }

    #[test]
    fn nn_prepared_matches_dense_backward_at_every_sparsity() {
        let (m, n, k) = (6, 8, 10); // dy [m, n] @ w [n, k]
        let dy: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.17).sin()).collect();
        for keep_mod in [1usize, 2, 5, 100] {
            let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.23).cos()).collect();
            for (i, wv) in w.iter_mut().enumerate() {
                if i % keep_mod != 0 {
                    *wv = 0.0;
                }
            }
            let pw = PreparedWeight::build(&w, n, k);
            let reference = naive_nn(&dy, &w, m, n, k);
            let dx = matmul_nn_prepared(&dy, &w, &pw, m);
            for (i, (a, b)) in dx.iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5,
                    "keep_mod {keep_mod} sparse={} dx[{i}]: {a} vs {b}",
                    pw.is_sparse()
                );
            }
        }
    }

    #[test]
    fn forced_threshold_routes_dense_weights_through_csr_and_csc() {
        let (m, n, k) = (4, 6, 7);
        let w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.13).cos()).collect();
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.29).sin()).collect();
        let dy: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.41).sin()).collect();
        let pw = PreparedWeight::build_with_threshold(&w, n, k, 0.0);
        assert!(pw.is_sparse(), "threshold 0 must force CSR");
        assert_eq!(pw.nnz, n * k);
        let mut y = vec![0.0f32; m * n];
        matmul_nt_prepared_into(&x, &w, &pw, m, &mut y);
        for (a, b) in y.iter().zip(&naive_nt(&x, &w, m, k, n)) {
            assert!((a - b).abs() < 1e-5);
        }
        let dx = matmul_nn_prepared(&dy, &w, &pw, m);
        for (a, b) in dx.iter().zip(&naive_nn(&dy, &w, m, n, k)) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn reduce_helpers_match_naive_sums() {
        let x: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).sin()).collect();
        let y: Vec<f32> = (0..37).map(|i| (i as f32 * 0.3).cos()).collect();
        let z: Vec<f32> = (0..37).map(|i| 1.0 + 0.01 * i as f32).collect();
        let naive_sum: f32 = x.iter().sum();
        let naive_sq: f32 = x.iter().map(|v| v * v).sum();
        let mu = naive_sum / 37.0;
        let naive_dev: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum();
        let naive_dot: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let naive_dot3: f32 = (0..37).map(|j| x[j] * y[j] * z[j]).sum();
        let naive_exp: f32 = x.iter().map(|v| (v - 0.5).exp()).sum();
        assert!((reduce_sum(&x) - naive_sum).abs() < 1e-4);
        assert!((reduce_sum_sq(&x) - naive_sq).abs() < 1e-4);
        assert!((reduce_sq_dev(&x, mu) - naive_dev).abs() < 1e-4);
        assert!((reduce_dot(&x, &y) - naive_dot).abs() < 1e-4);
        assert!((reduce_dot3(&x, &y, &z) - naive_dot3).abs() < 1e-4);
        assert!((reduce_sum_exp(&x, 0.5) - naive_exp).abs() < 1e-3);
        assert_eq!(reduce_sum(&[]), 0.0);
    }
}
