//! Dense + sparsity-aware matmul primitives for the native CPU backend.
//!
//! Weight convention matches the whole stack (`kernels/ref.py`): weights
//! are `[out, in]` row-major, activations `[M, K]` row-major, so the hot
//! product `Y = X @ Wᵀ` is a grid of contiguous-row dot products — the
//! cache-friendly layout that needs no transposition.
//!
//! Three levers make this the prepared-weight kernel engine (ISSUE 2):
//!
//! * **[`PreparedWeight`]** — the §3.1 sparsity lever. A frozen weight is
//!   scanned **once** into either a dense marker or a CSR gather
//!   (`row_start`/`idx`/`val`) when it is past [`SPARSE_THRESHOLD`]
//!   zeros; every subsequent matmul skips the zeros without re-deriving
//!   the structure. The per-call gather of the original implementation
//!   survives only as the fallback for unprepared host tensors
//!   ([`matmul_nt_auto`]).
//! * **Register-blocked tiles** — [`matmul_nt_into`] processes x-rows in
//!   blocks of [`MR`], streaming each weight row once per block instead
//!   of once per row (a 4× cut in weight traffic). Per output element
//!   the accumulation order is *identical* to the scalar [`dot`] (4-way
//!   partial sums + tail), so blocked and unblocked paths agree bitwise.
//! * **Scoped worker threads** — every kernel dispatches contiguous
//!   output-row ranges across a `std::thread::scope` pool sized by
//!   `SHEARS_NUM_THREADS` (default: available parallelism; see
//!   [`num_threads`]). Partitioning only splits *rows between* threads,
//!   never the reduction *within* an element, so results are
//!   bit-identical for every thread count and the golden parity
//!   fixtures are unaffected.
//!
//! The `_into` variants write into caller-provided buffers (the
//! [`crate::ops::scratch::Scratch`] arena in the model hot path) so
//! steady-state forward/train loops do not allocate per matmul.

/// Fraction of zeros in a weight above which the CSR gather path wins.
pub const SPARSE_THRESHOLD: f64 = 0.3;

/// x-row register block for the dense kernel.
const MR: usize = 4;

use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimum multiply-accumulate ops per worker before forking another
/// thread (amortizes `thread::scope` spawn cost).
const DEFAULT_PAR_MIN_WORK: usize = 1 << 17;

static PAR_MIN_WORK: AtomicUsize = AtomicUsize::new(DEFAULT_PAR_MIN_WORK);

/// Lower the fork threshold so tiny shapes still take the threaded
/// path — test/bench hook; production code leaves the default.
/// `0` restores the default threshold.
#[doc(hidden)]
pub fn set_par_min_work(w: usize) {
    let w = if w == 0 { DEFAULT_PAR_MIN_WORK } else { w };
    PAR_MIN_WORK.store(w, Ordering::Relaxed);
}

/// 0 = uninitialized; resolved lazily from `SHEARS_NUM_THREADS` or the
/// machine's available parallelism.
static NUM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Worker count for the kernel dispatchers. Resolution order:
/// [`set_num_threads`] override > `SHEARS_NUM_THREADS` env var >
/// `std::thread::available_parallelism()`.
pub fn num_threads() -> usize {
    let n = NUM_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::env::var("SHEARS_NUM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|n| *n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    let n = n.clamp(1, 64);
    NUM_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (tests, CLI `--threads`). Values are
/// clamped to `[1, 64]`; `0` falls back to env/auto resolution on the
/// next [`num_threads`] call. Thread count never changes results, only
/// speed.
pub fn set_num_threads(n: usize) {
    let n = if n == 0 { 0 } else { n.clamp(1, 64) };
    NUM_THREADS.store(n, Ordering::Relaxed);
}

/// Blocked dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..a.len() {
        tail += a[j] * b[j];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Four dot products sharing one streamed `w` row. Per row the partial
/// sums and combine order are exactly those of [`dot`], so a row
/// computed here is bit-identical to the scalar path.
#[inline]
fn dot4(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], w: &[f32]) -> [f32; 4] {
    let k = w.len();
    let chunks = k / 4;
    let mut s = [[0.0f32; 4]; 4];
    for i in 0..chunks {
        let j = i * 4;
        for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
            s[r][0] += xr[j] * w[j];
            s[r][1] += xr[j + 1] * w[j + 1];
            s[r][2] += xr[j + 2] * w[j + 2];
            s[r][3] += xr[j + 3] * w[j + 3];
        }
    }
    let mut out = [0.0f32; 4];
    for (r, xr) in [x0, x1, x2, x3].into_iter().enumerate() {
        let mut tail = 0.0f32;
        for j in chunks * 4..k {
            tail += xr[j] * w[j];
        }
        out[r] = (s[r][0] + s[r][1]) + (s[r][2] + s[r][3]) + tail;
    }
    out
}

// --------------------------------------------------------- threading

/// Split `y` into contiguous row ranges and run `f(row_lo, row_hi,
/// rows_slice)` on each, forking scoped workers when `rows *
/// work_per_row` is large enough to amortize the spawns. The first
/// chunk runs on the calling thread. Determinism: `f` computes each
/// output element identically whatever the partition, so the thread
/// count never changes results.
fn parallel_rows<F>(y: &mut [f32], rows: usize, row_len: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(y.len(), rows * row_len);
    let total = rows.saturating_mul(work_per_row);
    let min_work = PAR_MIN_WORK.load(Ordering::Relaxed);
    let threads = num_threads().min(rows).min((total / min_work).max(1));
    if threads <= 1 || row_len == 0 {
        f(0, rows, y);
        return;
    }
    let chunk = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut inline: Option<(usize, &mut [f32])> = None;
        for (ci, slice) in y.chunks_mut(chunk * row_len).enumerate() {
            let lo = ci * chunk;
            if ci == 0 {
                inline = Some((lo, slice));
                continue;
            }
            let hi = lo + slice.len() / row_len;
            let fr = &f;
            scope.spawn(move || fr(lo, hi, slice));
        }
        if let Some((lo, slice)) = inline {
            let hi = lo + slice.len() / row_len;
            f(lo, hi, slice);
        }
    });
}

// --------------------------------------------------- prepared weights

/// Physical representation chosen for a prepared weight.
pub enum WeightRepr {
    /// Mostly nonzero: the dense register-blocked kernel wins.
    Dense,
    /// Past [`SPARSE_THRESHOLD`] zeros: per-output-row compressed
    /// (index, value) pairs — the Wanda/magnitude-pruned base weights.
    Csr {
        /// `n + 1` offsets into `idx`/`val`.
        row_start: Vec<u32>,
        /// column (input-feature) index of each nonzero
        idx: Vec<u32>,
        /// nonzero values, aligned with `idx`
        val: Vec<f32>,
    },
}

/// A weight scanned **once** into the representation its sparsity
/// merits. Built lazily per resident buffer (see
/// `runtime::DeviceBuffer`) and reused by every subsequent matmul;
/// rebuilt only when the owning buffer is re-uploaded (prune step,
/// optimizer update — tracked by `ParamStore` generations).
pub struct PreparedWeight {
    /// output features (weight rows)
    pub n: usize,
    /// input features (weight cols)
    pub k: usize,
    /// nonzero count (sparsity accounting)
    pub nnz: usize,
    pub repr: WeightRepr,
}

impl PreparedWeight {
    /// One O(n·k) scan deciding dense vs CSR and building the gather.
    pub fn build(w: &[f32], n: usize, k: usize) -> PreparedWeight {
        debug_assert_eq!(w.len(), n * k);
        let zeros = w.iter().filter(|v| **v == 0.0).count();
        let nnz = w.len() - zeros;
        if (zeros as f64) < SPARSE_THRESHOLD * (w.len().max(1) as f64) {
            return PreparedWeight { n, k, nnz, repr: WeightRepr::Dense };
        }
        let mut row_start = Vec::with_capacity(n + 1);
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        row_start.push(0u32);
        for ni in 0..n {
            for (ki, wv) in w[ni * k..(ni + 1) * k].iter().enumerate() {
                if *wv != 0.0 {
                    idx.push(ki as u32);
                    val.push(*wv);
                }
            }
            row_start.push(idx.len() as u32);
        }
        PreparedWeight { n, k, nnz, repr: WeightRepr::Csr { row_start, idx, val } }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self.repr, WeightRepr::Csr { .. })
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.n * self.k).max(1) as f64
    }
}

// ------------------------------------------------------------ kernels

/// Dense rows `[lo, hi)` of `y = x @ wᵀ`; `y` holds exactly those rows.
fn nt_rows(x: &[f32], w: &[f32], k: usize, n: usize, lo: usize, hi: usize, y: &mut [f32]) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * n;
        if mi + MR <= hi {
            let x0 = &x[mi * k..(mi + 1) * k];
            let x1 = &x[(mi + 1) * k..(mi + 2) * k];
            let x2 = &x[(mi + 2) * k..(mi + 3) * k];
            let x3 = &x[(mi + 3) * k..(mi + 4) * k];
            for ni in 0..n {
                let d = dot4(x0, x1, x2, x3, &w[ni * k..(ni + 1) * k]);
                y[ybase + ni] = d[0];
                y[ybase + n + ni] = d[1];
                y[ybase + 2 * n + ni] = d[2];
                y[ybase + 3 * n + ni] = d[3];
            }
            mi += MR;
        } else {
            let xr = &x[mi * k..(mi + 1) * k];
            for (ni, yv) in y[ybase..ybase + n].iter_mut().enumerate() {
                *yv = dot(xr, &w[ni * k..(ni + 1) * k]);
            }
            mi += 1;
        }
    }
}

/// CSR rows `[lo, hi)` of `y = x @ wᵀ`, streaming each compressed
/// weight row across a block of x-rows. Per element: one sequential
/// accumulator over the nonzeros in column order (the exact order the
/// original per-call gather used).
#[allow(clippy::too_many_arguments)]
fn csr_rows(
    x: &[f32],
    row_start: &[u32],
    idx: &[u32],
    val: &[f32],
    k: usize,
    n: usize,
    lo: usize,
    hi: usize,
    y: &mut [f32],
) {
    let mut mi = lo;
    while mi < hi {
        let ybase = (mi - lo) * n;
        let rows = (hi - mi).min(MR);
        if rows == MR {
            let x0 = &x[mi * k..(mi + 1) * k];
            let x1 = &x[(mi + 1) * k..(mi + 2) * k];
            let x2 = &x[(mi + 2) * k..(mi + 3) * k];
            let x3 = &x[(mi + 3) * k..(mi + 4) * k];
            for ni in 0..n {
                let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                let mut acc = [0.0f32; 4];
                for (ki, wv) in idx[s..e].iter().zip(&val[s..e]) {
                    let ki = *ki as usize;
                    acc[0] += x0[ki] * wv;
                    acc[1] += x1[ki] * wv;
                    acc[2] += x2[ki] * wv;
                    acc[3] += x3[ki] * wv;
                }
                y[ybase + ni] = acc[0];
                y[ybase + n + ni] = acc[1];
                y[ybase + 2 * n + ni] = acc[2];
                y[ybase + 3 * n + ni] = acc[3];
            }
            mi += MR;
        } else {
            let xr = &x[mi * k..(mi + 1) * k];
            for (ni, yv) in y[ybase..ybase + n].iter_mut().enumerate() {
                let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                let mut acc = 0.0f32;
                for (ki, wv) in idx[s..e].iter().zip(&val[s..e]) {
                    acc += xr[*ki as usize] * wv;
                }
                *yv = acc;
            }
            mi += 1;
        }
    }
}

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` (dense, threaded). `y` is overwritten.
pub fn matmul_nt_into(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(y.len(), m * n);
    if m == 1 {
        // serving shape: one activation row → partition output columns
        parallel_rows(y, n, 1, k, |lo, _hi, yc| {
            for (j, yv) in yc.iter_mut().enumerate() {
                let ni = lo + j;
                *yv = dot(x, &w[ni * k..(ni + 1) * k]);
            }
        });
    } else {
        parallel_rows(y, m, n, n * k, |lo, hi, yc| nt_rows(x, w, k, n, lo, hi, yc));
    }
}

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` (dense).
pub fn matmul_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nt_into(x, w, m, k, n, &mut y);
    y
}

/// `y = x @ wᵀ` through a prepared representation: the CSR gather for
/// sparse weights, the register-blocked dense kernel otherwise. `w`
/// must be the same buffer `pw` was built from (used on the dense path).
pub fn matmul_nt_prepared_into(
    x: &[f32],
    w: &[f32],
    pw: &PreparedWeight,
    m: usize,
    y: &mut [f32],
) {
    let (k, n) = (pw.k, pw.n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(y.len(), m * n);
    match &pw.repr {
        WeightRepr::Dense => matmul_nt_into(x, w, m, k, n, y),
        WeightRepr::Csr { row_start, idx, val } => {
            if m == 1 {
                parallel_rows(y, n, 1, pw.nnz / n.max(1) + 1, |lo, _hi, yc| {
                    for (j, yv) in yc.iter_mut().enumerate() {
                        let ni = lo + j;
                        let (s, e) = (row_start[ni] as usize, row_start[ni + 1] as usize);
                        let mut acc = 0.0f32;
                        for (ki, wv) in idx[s..e].iter().zip(&val[s..e]) {
                            acc += x[*ki as usize] * wv;
                        }
                        *yv = acc;
                    }
                });
            } else {
                let work = n * (pw.nnz / n.max(1) + 1);
                parallel_rows(y, m, n, work, |lo, hi, yc| {
                    csr_rows(x, row_start, idx, val, k, n, lo, hi, yc)
                });
            }
        }
    }
}

/// `y = x @ wᵀ`, skipping zero weight entries when the weight is sparse
/// enough (the {0,1}-masked, Wanda-pruned base weights). Scans and
/// gathers **per call** — callers on the hot path should hold a
/// [`PreparedWeight`] (resident-buffer cache) and use
/// [`matmul_nt_prepared_into`] instead.
pub fn matmul_nt_auto(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nt_auto_into(x, w, m, k, n, &mut y);
    y
}

/// Per-call-prepared variant of [`matmul_nt_prepared_into`].
pub fn matmul_nt_auto_into(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    let pw = PreparedWeight::build(w, n, k);
    matmul_nt_prepared_into(x, w, &pw, m, y);
}

/// `y[M,N] = a[M,K] @ b[K,N]` (row-major, axpy inner loop, threaded).
/// `y`'s prior contents are ignored.
pub fn matmul_nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    parallel_rows(y, m, n, n * k, |lo, hi, yc| {
        yc.fill(0.0);
        for mi in lo..hi {
            let ar = &a[mi * k..(mi + 1) * k];
            let yr = &mut yc[(mi - lo) * n..(mi - lo + 1) * n];
            for (ki, av) in ar.iter().enumerate() {
                if *av == 0.0 {
                    continue;
                }
                let br = &b[ki * n..(ki + 1) * n];
                for (yv, bv) in yr.iter_mut().zip(br) {
                    *yv += av * bv;
                }
            }
        }
    });
}

/// `y[M,N] = a[M,K] @ b[K,N]` (row-major, axpy inner loop).
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_nn_into(a, b, m, k, n, &mut y);
    y
}

/// `y[M,N] = a[K,M]ᵀ @ b[K,N]` (gradient shape: `dW = dyᵀ @ x`),
/// threaded over output rows. `y`'s prior contents are ignored.
pub fn matmul_tn_into(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    parallel_rows(y, m, n, n * k, |lo, hi, yc| {
        yc.fill(0.0);
        for ki in 0..k {
            let ar = &a[ki * m..(ki + 1) * m];
            let br = &b[ki * n..(ki + 1) * n];
            for mi in lo..hi {
                let av = ar[mi];
                if av == 0.0 {
                    continue;
                }
                let yr = &mut yc[(mi - lo) * n..(mi - lo + 1) * n];
                for (yv, bv) in yr.iter_mut().zip(br) {
                    *yv += av * bv;
                }
            }
        }
    });
}

/// `y[M,N] = a[K,M]ᵀ @ b[K,N]` (gradient shape: `dW = dyᵀ @ x`).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    matmul_tn_into(a, b, k, m, n, &mut y);
    y
}

/// `y += x`, elementwise.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += xv;
    }
}

/// `y += s * x`, elementwise.
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += s * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0; m * n];
        for mi in 0..m {
            for ni in 0..n {
                for ki in 0..k {
                    y[mi * n + ni] += x[mi * k + ki] * w[ni * k + ki];
                }
            }
        }
        y
    }

    #[test]
    fn nt_matches_naive_and_sparse_path() {
        let (m, k, n) = (3, 7, 5);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.61).cos()).collect();
        // sparsify half of w so the auto path takes the gather route
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *wv = 0.0;
            }
        }
        let reference = naive_nt(&x, &w, m, k, n);
        for y in [matmul_nt(&x, &w, m, k, n), matmul_nt_auto(&x, &w, m, k, n)] {
            for (a, b) in y.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn prepared_weight_picks_repr_and_matches_dense() {
        let (m, k, n) = (6, 9, 4);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.21).sin()).collect();
        let dense: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.13).cos()).collect();
        let pw = PreparedWeight::build(&dense, n, k);
        assert!(!pw.is_sparse());
        assert_eq!(pw.nnz, n * k);

        let mut sparse = dense.clone();
        for (i, wv) in sparse.iter_mut().enumerate() {
            if i % 3 != 0 {
                *wv = 0.0;
            }
        }
        let pw = PreparedWeight::build(&sparse, n, k);
        assert!(pw.is_sparse());
        assert!((pw.density() - pw.nnz as f64 / (n * k) as f64).abs() < 1e-12);
        let reference = naive_nt(&x, &sparse, m, k, n);
        let mut y = vec![0.0f32; m * n];
        matmul_nt_prepared_into(&x, &sparse, &pw, m, &mut y);
        for (a, b) in y.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn single_row_path_matches_multi_row_kernel() {
        // M=1 dispatches over output columns; must equal the row kernel
        let (k, n) = (13, 11);
        let x: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.3).cos()).collect();
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *wv = 0.0;
            }
        }
        let naive = naive_nt(&x, &w, 1, k, n);
        for y in [matmul_nt(&x, &w, 1, k, n), matmul_nt_auto(&x, &w, 1, k, n)] {
            for (a, b) in y.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn thread_count_never_changes_results() {
        // deterministic row partition: bit-identical across pool sizes
        let (m, k, n) = (9, 17, 7);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.11).sin()).collect();
        let w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.23).cos()).collect();
        let before = num_threads();
        set_par_min_work(1); // force the fork even at this tiny size
        set_num_threads(1);
        let y1 = matmul_nt(&x, &w, m, k, n);
        let nn1 = matmul_nn(&x, &w, m, k, n); // w reinterpreted as [k, n]
        set_num_threads(3);
        let y3 = matmul_nt(&x, &w, m, k, n);
        let nn3 = matmul_nn(&x, &w, m, k, n);
        set_num_threads(before);
        set_par_min_work(0);
        assert_eq!(y1, y3);
        assert_eq!(nn1, nn3);
    }

    #[test]
    fn nn_and_tn_agree_with_transposes() {
        let (m, k, n) = (4, 3, 6);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.1 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.2).sin()).collect();
        // a @ b == a @ (bᵀ)ᵀ: check nn against nt with explicitly transposed b
        let mut bt = vec![0.0f32; n * k];
        for ki in 0..k {
            for ni in 0..n {
                bt[ni * k + ki] = b[ki * n + ni];
            }
        }
        let y1 = matmul_nn(&a, &b, m, k, n);
        let y2 = matmul_nt(&a, &bt, m, k, n);
        for (p, q) in y1.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-5);
        }
        // aᵀᵀ @ b via tn on the transposed a
        let mut at = vec![0.0f32; k * m];
        for mi in 0..m {
            for ki in 0..k {
                at[ki * m + mi] = a[mi * k + ki];
            }
        }
        let y3 = matmul_tn(&at, &b, k, m, n);
        for (p, q) in y3.iter().zip(&y1) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_tails() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(dot(&a, &b), 30.0);
        assert_eq!(dot(&a[..1], &b[..1]), 2.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn empty_and_all_zero_weights() {
        // all-zero weight: CSR with zero nonzeros, result all zeros
        let (m, k, n) = (3, 5, 4);
        let x: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let w = vec![0.0f32; n * k];
        let pw = PreparedWeight::build(&w, n, k);
        assert!(pw.is_sparse());
        assert_eq!(pw.nnz, 0);
        let y = matmul_nt_auto(&x, &w, m, k, n);
        assert!(y.iter().all(|v| *v == 0.0));
    }
}
