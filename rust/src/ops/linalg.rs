//! Dense + sparsity-aware matmul primitives for the native CPU backend.
//!
//! Weight convention matches the whole stack (`kernels/ref.py`): weights
//! are `[out, in]` row-major, activations `[M, K]` row-major, so the hot
//! product `Y = X @ Wᵀ` is a grid of contiguous-row dot products — the
//! cache-friendly layout that needs no transposition. The dot kernel is
//! 4-way blocked (independent partial sums) so LLVM can vectorize the
//! f32 reduction.
//!
//! [`matmul_nt_auto`] is the §3.1 sparsity lever: for a pruned weight it
//! gathers each row's nonzero (index, value) pairs and skips the zeros —
//! ~2× fewer multiplies at the paper's 50% sparsity for an O(N·K) scan
//! per call (amortized against the O(M·N·K) product; caching the gather
//! per frozen weight is a planned follow-up, see ROADMAP).

/// Fraction of zeros in a weight above which the gather-and-skip path wins.
const SPARSE_THRESHOLD: f64 = 0.3;

/// Blocked dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut tail = 0.0f32;
    for j in chunks * 4..a.len() {
        tail += a[j] * b[j];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` (dense).
pub fn matmul_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0.0f32; m * n];
    for mi in 0..m {
        let xr = &x[mi * k..(mi + 1) * k];
        let yr = &mut y[mi * n..(mi + 1) * n];
        for (ni, yv) in yr.iter_mut().enumerate() {
            *yv = dot(xr, &w[ni * k..(ni + 1) * k]);
        }
    }
    y
}

/// `y = x @ wᵀ`, skipping zero weight entries when the weight is sparse
/// enough (the {0,1}-masked, Wanda-pruned base weights).
pub fn matmul_nt_auto(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let zeros = w.iter().filter(|v| **v == 0.0).count();
    if (zeros as f64) < SPARSE_THRESHOLD * (w.len().max(1) as f64) {
        return matmul_nt(x, w, m, k, n);
    }
    // gather per-row nonzeros once, then stream activations over them
    let mut idx: Vec<u32> = Vec::with_capacity(w.len() - zeros);
    let mut val: Vec<f32> = Vec::with_capacity(w.len() - zeros);
    let mut row_start: Vec<usize> = Vec::with_capacity(n + 1);
    row_start.push(0);
    for ni in 0..n {
        for (ki, wv) in w[ni * k..(ni + 1) * k].iter().enumerate() {
            if *wv != 0.0 {
                idx.push(ki as u32);
                val.push(*wv);
            }
        }
        row_start.push(idx.len());
    }
    let mut y = vec![0.0f32; m * n];
    for mi in 0..m {
        let xr = &x[mi * k..(mi + 1) * k];
        let yr = &mut y[mi * n..(mi + 1) * n];
        for (ni, yv) in yr.iter_mut().enumerate() {
            let (lo, hi) = (row_start[ni], row_start[ni + 1]);
            let mut acc = 0.0f32;
            for (ki, wv) in idx[lo..hi].iter().zip(&val[lo..hi]) {
                acc += xr[*ki as usize] * wv;
            }
            *yv = acc;
        }
    }
    y
}

/// `y[M,N] = a[M,K] @ b[K,N]` (row-major, axpy inner loop).
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    for mi in 0..m {
        let ar = &a[mi * k..(mi + 1) * k];
        let yr = &mut y[mi * n..(mi + 1) * n];
        for (ki, av) in ar.iter().enumerate() {
            if *av == 0.0 {
                continue;
            }
            let br = &b[ki * n..(ki + 1) * n];
            for (yv, bv) in yr.iter_mut().zip(br) {
                *yv += av * bv;
            }
        }
    }
    y
}

/// `y[M,N] = a[K,M]ᵀ @ b[K,N]` (gradient shape: `dW = dyᵀ @ x`).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    for ki in 0..k {
        let ar = &a[ki * m..(ki + 1) * m];
        let br = &b[ki * n..(ki + 1) * n];
        for (mi, av) in ar.iter().enumerate() {
            if *av == 0.0 {
                continue;
            }
            let yr = &mut y[mi * n..(mi + 1) * n];
            for (yv, bv) in yr.iter_mut().zip(br) {
                *yv += av * bv;
            }
        }
    }
    y
}

/// `y += x`, elementwise.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += xv;
    }
}

/// `y += s * x`, elementwise.
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += s * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0; m * n];
        for mi in 0..m {
            for ni in 0..n {
                for ki in 0..k {
                    y[mi * n + ni] += x[mi * k + ki] * w[ni * k + ki];
                }
            }
        }
        y
    }

    #[test]
    fn nt_matches_naive_and_sparse_path() {
        let (m, k, n) = (3, 7, 5);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut w: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.61).cos()).collect();
        // sparsify half of w so the auto path takes the gather route
        for (i, wv) in w.iter_mut().enumerate() {
            if i % 2 == 0 {
                *wv = 0.0;
            }
        }
        let reference = naive_nt(&x, &w, m, k, n);
        for y in [matmul_nt(&x, &w, m, k, n), matmul_nt_auto(&x, &w, m, k, n)] {
            for (a, b) in y.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn nn_and_tn_agree_with_transposes() {
        let (m, k, n) = (4, 3, 6);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.1 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.2).sin()).collect();
        // a @ b == a @ (bᵀ)ᵀ: check nn against nt with explicitly transposed b
        let mut bt = vec![0.0f32; n * k];
        for ki in 0..k {
            for ni in 0..n {
                bt[ni * k + ki] = b[ki * n + ni];
            }
        }
        let y1 = matmul_nn(&a, &b, m, k, n);
        let y2 = matmul_nt(&a, &bt, m, k, n);
        for (p, q) in y1.iter().zip(&y2) {
            assert!((p - q).abs() < 1e-5);
        }
        // aᵀᵀ @ b via tn on the transposed a
        let mut at = vec![0.0f32; k * m];
        for mi in 0..m {
            for ki in 0..k {
                at[ki * m + mi] = a[mi * k + ki];
            }
        }
        let y3 = matmul_tn(&at, &b, k, m, n);
        for (p, q) in y3.iter().zip(&y1) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_tails() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(dot(&a, &b), 30.0);
        assert_eq!(dot(&a[..1], &b[..1]), 2.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }
}
