//! Reusable scratch-buffer arena for the native hot path.
//!
//! Every matmul in the decoder forward/backward used to allocate (and
//! zero) a fresh `Vec<f32>`; at steady state the shapes repeat exactly,
//! so [`Scratch`] keeps returned buffers in capacity-keyed buckets and
//! hands them back on the next [`Scratch::take`]. After one warm-up
//! pass a train/eval loop performs **no per-matmul heap allocation** —
//! only the entry-point boundary (batch in, logits / updated params
//! out) still allocates, because those tensors escape to the caller.
//!
//! Interior mutability keeps the borrow story simple: the model layer
//! passes `&Scratch` everywhere and the pool lives in a `RefCell`. The
//! native backend is single-threaded at this level; the persistent
//! kernel worker pool (`linalg`) only ever writes into row chunks of
//! buffers the model layer already took — workers never touch the
//! arena itself, so it needs no synchronization.

use std::cell::RefCell;
use std::collections::BTreeMap;

/// Capacity-bucketed pool of `f32` buffers.
#[derive(Default)]
pub struct Scratch {
    /// capacity → stack of idle buffers with exactly that capacity
    pool: RefCell<BTreeMap<usize, Vec<Vec<f32>>>>,
    /// takes that found no pooled buffer and had to allocate
    misses: std::cell::Cell<u64>,
    /// total takes (misses / takes = steady-state health)
    takes: std::cell::Cell<u64>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A zero-filled buffer of exactly `len` elements: pooled when a
    /// buffer with sufficient capacity is idle, freshly allocated
    /// otherwise (a "miss" — steady-state loops should stop missing
    /// after their first iteration).
    ///
    /// Emptied buckets stay in the map (their key set stabilizes after
    /// warm-up): a steady-state take/give cycle then never inserts or
    /// removes tree nodes, so warm loops — the serving decode step in
    /// particular — perform literally zero heap operations here
    /// (`rust/tests/alloc_count.rs`).
    pub fn take(&self, len: usize) -> Vec<f32> {
        self.takes.set(self.takes.get() + 1);
        let mut pool = self.pool.borrow_mut();
        // smallest idle buffer that fits
        let popped = pool
            .range_mut(len..)
            .find_map(|(_, stack)| stack.pop());
        drop(pool);
        match popped {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.misses.set(self.misses.get() + 1);
                vec![0.0f32; len]
            }
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let cap = v.capacity();
        self.pool.borrow_mut().entry(cap).or_default().push(v);
    }

    /// Allocating takes so far (grows only while the pool is cold).
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Total takes served.
    pub fn takes(&self) -> u64 {
        self.takes.get()
    }

    /// Idle buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.borrow().values().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_returned_buffers() {
        let s = Scratch::new();
        let a = s.take(16);
        assert_eq!(a.len(), 16);
        assert_eq!(s.misses(), 1);
        s.give(a);
        assert_eq!(s.pooled(), 1);
        let b = s.take(16);
        assert_eq!(b.len(), 16);
        assert_eq!(s.misses(), 1, "second take must hit the pool");
        assert!(b.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn smaller_requests_reuse_larger_buffers() {
        let s = Scratch::new();
        s.give(Vec::with_capacity(64));
        let v = s.take(10);
        assert_eq!(v.len(), 10);
        assert_eq!(s.misses(), 0);
        s.give(v);
        // buffer went back under its (>= 64) capacity bucket
        assert_eq!(s.pooled(), 1);
        assert!(s.take(64).capacity() >= 64);
        assert_eq!(s.misses(), 0);
    }

    #[test]
    fn zeroing_erases_previous_contents() {
        let s = Scratch::new();
        let mut v = s.take(4);
        v.iter_mut().for_each(|x| *x = 7.0);
        s.give(v);
        assert!(s.take(4).iter().all(|x| *x == 0.0));
    }

    #[test]
    fn steady_state_stops_missing() {
        let s = Scratch::new();
        for _ in 0..3 {
            let a = s.take(8);
            let b = s.take(32);
            s.give(a);
            s.give(b);
        }
        assert_eq!(s.misses(), 2, "only the cold pass may allocate");
        assert_eq!(s.takes(), 6);
    }
}
