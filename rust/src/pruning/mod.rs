//! Unstructured sparsification drivers (paper §3.1, step 1 of Figure 1).
//!
//! Calibration: [`collect_stats`] streams a handful of batches through the
//! `calib_stats` entry point and accumulates per-site activation Σx² (for
//! Wanda's ‖X‖₂) and Gram matrices H = XᵀX (for SparseGPT) — the exact
//! "tiny subset of inputs, forward pass only" cost profile the paper
//! emphasizes (<5 min for 7B on one GPU; seconds here).
//!
//! Pruning: [`prune`] streams every prunable weight through the AOT'd
//! per-shape prune op (Wanda runs the L1 Pallas kernel), replaces the
//! weight in the store, and returns the {0,1} masks. Masks feed the
//! SparseFT baseline (`train_step_full` re-applies them each step) and the
//! sparsity accounting of Table 3.

use crate::data::batch::Batch;
use crate::model::{Manifest, ModelConfig, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Accumulated calibration statistics keyed by site name.
#[derive(Debug, Default)]
pub struct CalibStats {
    pub sumsq: HashMap<String, HostTensor>,
    pub gram: HashMap<String, HostTensor>,
    pub batches: usize,
}

/// Pruning method (the paper's main metric + the two alternatives it cites).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Wanda,
    Magnitude,
    SparseGpt,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Wanda => "wanda",
            Method::Magnitude => "magnitude",
            Method::SparseGpt => "sparsegpt",
        }
    }

    pub fn needs_stats(&self) -> bool {
        !matches!(self, Method::Magnitude)
    }
}

/// Run `calib_stats` over calibration batches, accumulating per-site stats.
pub fn collect_stats(
    rt: &Runtime,
    cfg: &ModelConfig,
    base: &ParamStore,
    batches: &[Batch],
) -> Result<CalibStats> {
    let entry = cfg.entry("calib_stats")?;
    let exe = rt.load(&entry.file)?;
    let mut stats = CalibStats::default();
    for batch in batches {
        let mut args: Vec<&HostTensor> = Vec::with_capacity(entry.inputs.len());
        for i in &entry.inputs {
            args.push(match i.name.as_str() {
                "x" => &batch.x,
                name => base.get(name)?,
            });
        }
        let outs = rt.run(&exe, &args)?;
        for (spec, t) in entry.outputs.iter().zip(outs) {
            if let Some(site) = spec.name.strip_prefix("sumsq.") {
                accumulate(stats.sumsq.entry(site.to_string()).or_insert_with(|| {
                    HostTensor::zeros(&t.shape)
                }), &t);
            } else if let Some(site) = spec.name.strip_prefix("gram.") {
                accumulate(stats.gram.entry(site.to_string()).or_insert_with(|| {
                    HostTensor::zeros(&t.shape)
                }), &t);
            } else {
                bail!("unexpected calib output {}", spec.name);
            }
        }
        stats.batches += 1;
    }
    Ok(stats)
}

fn accumulate(acc: &mut HostTensor, t: &HostTensor) {
    let dst = acc.f32s_mut();
    for (d, s) in dst.iter_mut().zip(t.f32s()) {
        *d += *s;
    }
}

/// Sparsify every prunable weight of `base` in place to `sparsity`
/// (fraction of zeros). Returns the per-weight {0,1} masks (keyed by the
/// weight name, as `train_step_full` expects).
///
/// Replacing each weight bumps its `ParamStore` generation, so any
/// resident copy (`runtime::ResidentParams`, `train::ForwardSession`)
/// re-uploads it on the next sync and the native backend rebuilds its
/// prepared CSR structure from the *pruned* values — the post-prune
/// forwards are where the cached sparse gather pays off.
pub fn prune(
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &ModelConfig,
    base: &mut ParamStore,
    method: Method,
    sparsity: f64,
    stats: Option<&CalibStats>,
) -> Result<ParamStore> {
    if !(0.0..1.0).contains(&sparsity) {
        bail!("sparsity must be in [0, 1): {sparsity}");
    }
    let keep = HostTensor::scalar_f32((1.0 - sparsity) as f32);
    let mut masks = ParamStore::new();
    if sparsity == 0.0 {
        // no-op prune: all-ones masks (lets every pipeline stage stay uniform)
        for p in &cfg.prunable {
            masks.insert(&p.name, HostTensor::ones(&p.shape));
        }
        return Ok(masks);
    }
    if method.needs_stats() && stats.is_none() {
        bail!("{} needs calibration stats", method.name());
    }
    let timer = crate::util::log::Timer::new(&format!("prune {}", method.name()));
    for p in &cfg.prunable {
        let (n, k) = (p.shape[0], p.shape[1]);
        let op = manifest.prune_op(method.name(), n, k)?;
        let exe = rt.load(&op.file)?;
        let w = base.get(&p.name)?;
        let outs = match method {
            Method::Wanda => {
                let s = stats.unwrap();
                let sumsq = s
                    .sumsq
                    .get(&p.site)
                    .with_context(|| format!("no sumsq stats for site {}", p.site))?;
                rt.run(&exe, &[w, sumsq, &keep])?
            }
            Method::Magnitude => rt.run(&exe, &[w, &keep])?,
            Method::SparseGpt => {
                let s = stats.unwrap();
                let gram = s
                    .gram
                    .get(&p.site)
                    .with_context(|| format!("no gram stats for site {}", p.site))?;
                rt.run(&exe, &[w, gram, &keep])?
            }
        };
        if outs.len() != 2 {
            bail!("prune op returned {} outputs", outs.len());
        }
        let mut it = outs.into_iter();
        base.insert(&p.name, it.next().unwrap());
        masks.insert(&p.name, it.next().unwrap());
    }
    timer.stop();
    Ok(masks)
}

/// Per-weight and overall sparsity over the prunable set (Table 3 metric).
pub fn sparsity_report(base: &ParamStore, cfg: &ModelConfig) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let (mut zeros, mut total) = (0usize, 0usize);
    for p in &cfg.prunable {
        if let Ok(t) = base.get(&p.name) {
            out.push((p.name.clone(), t.sparsity()));
            zeros += t.zeros_count();
            total += t.numel();
        }
    }
    out.push(("OVERALL".to_string(), zeros as f64 / total.max(1) as f64));
    out
}

/// Non-zero parameter count across base + active adapter params
/// (paper Table 3: Shears keeps adapters unmerged, so both count).
pub fn nonzero_params(base: &ParamStore, adapters: Option<&ParamStore>) -> usize {
    base.nonzero() + adapters.map(|a| a.nonzero()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_properties() {
        assert!(Method::Wanda.needs_stats());
        assert!(Method::SparseGpt.needs_stats());
        assert!(!Method::Magnitude.needs_stats());
        assert_eq!(Method::Wanda.name(), "wanda");
    }

    #[test]
    fn accumulate_adds_elementwise() {
        let mut acc = HostTensor::zeros(&[3]);
        accumulate(&mut acc, &HostTensor::from_f32(&[3], vec![1., 2., 3.]));
        accumulate(&mut acc, &HostTensor::from_f32(&[3], vec![0.5, 0.5, 0.5]));
        assert_eq!(acc.f32s(), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn nonzero_counts_both_stores() {
        let mut base = ParamStore::new();
        base.insert("w", HostTensor::from_f32(&[4], vec![1., 0., 2., 0.]));
        let mut ad = ParamStore::new();
        ad.insert("a", HostTensor::from_f32(&[2], vec![0., 3.]));
        assert_eq!(nonzero_params(&base, None), 2);
        assert_eq!(nonzero_params(&base, Some(&ad)), 3);
    }
}
