//! Model metadata and parameter state.
//!
//! [`manifest`] parses `artifacts/manifest.json` — the L2↔L3 ABI emitted
//! by `python/compile/aot.py` (parameter orderings, entry-point
//! signatures, prune-op shapes). [`params`] owns the host-side parameter
//! state (`ParamStore`): init, checkpointing, counting.

pub mod builtin;
pub mod manifest;
pub mod params;

pub use builtin::{builtin_manifest, make_config, standard_configs, ConfigSpec};
pub use manifest::{EntryPoint, IoSpec, Manifest, ModelConfig, ParamSpec, PruneOpSpec, Prunable};
pub use params::ParamStore;
