//! `artifacts/manifest.json` schema + loader.
//!
//! The manifest is the single source of truth for tensor shapes and the
//! canonical input/output ordering of every AOT'd entry point. Nothing in
//! rust hard-codes a parameter list; if the python side changes, only the
//! manifest (and the artifacts) change.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32"
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct EntryPoint {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Prunable {
    pub name: String,
    pub shape: Vec<usize>,
    /// calibration-statistics site feeding this weight's score
    pub site: String,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub arch: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub max_rank: usize,
    pub rank_choices: Vec<usize>,
    pub lora_alpha: f64,
    pub targets: Vec<String>,
    pub batch_train: usize,
    pub batch_eval: usize,
    /// prefix-tuning baseline KV length (lenient default for old manifests)
    pub prefix_len: usize,
    /// series/parallel adapter bottleneck dim (lenient default)
    pub bottleneck: usize,
    pub base_params: Vec<ParamSpec>,
    pub adapter_params: Vec<ParamSpec>,
    pub prefix_params: Vec<ParamSpec>,
    pub series_params: Vec<ParamSpec>,
    pub parallel_params: Vec<ParamSpec>,
    pub adapter_modules: Vec<String>,
    pub prunable: Vec<Prunable>,
    /// (site name, feature dim)
    pub sites: Vec<(String, usize)>,
    pub entrypoints: BTreeMap<String, EntryPoint>,
}

#[derive(Clone, Debug)]
pub struct PruneOpSpec {
    pub file: String,
    pub kind: String,
    pub shape: (usize, usize),
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ModelConfig>,
    pub prune_ops: BTreeMap<String, PruneOpSpec>,
}

fn parse_params(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()
        .context("param list")?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.at("name").as_str().context("param name")?.to_string(),
                shape: p.at("shape").as_shape().context("param shape")?,
            })
        })
        .collect()
}

fn parse_io(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()
        .context("io list")?
        .iter()
        .map(|p| {
            Ok(IoSpec {
                name: p.at("name").as_str().context("io name")?.to_string(),
                shape: p.at("shape").as_shape().context("io shape")?,
                dtype: p.at("dtype").as_str().context("io dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// The built-in manifest (native backend ABI, no artifacts needed).
    pub fn builtin() -> Manifest {
        crate::model::builtin::builtin_manifest()
    }

    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = artifacts_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        if j.at("version").as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut configs = BTreeMap::new();
        for (name, cj) in j.at("configs").as_obj().context("configs")? {
            configs.insert(name.clone(), Self::parse_config(name, cj)?);
        }
        let mut prune_ops = BTreeMap::new();
        for (name, pj) in j.at("prune_ops").as_obj().context("prune_ops")? {
            let shape = pj.at("shape").as_shape().context("prune shape")?;
            prune_ops.insert(
                name.clone(),
                PruneOpSpec {
                    file: pj.at("file").as_str().context("prune file")?.to_string(),
                    kind: pj.at("kind").as_str().context("prune kind")?.to_string(),
                    shape: (shape[0], shape[1]),
                    inputs: parse_io(pj.at("inputs"))?,
                    outputs: parse_io(pj.at("outputs"))?,
                },
            );
        }
        Ok(Manifest { configs, prune_ops })
    }

    fn parse_config(name: &str, cj: &Json) -> Result<ModelConfig> {
        let us = |k: &str| -> Result<usize> {
            cj.at(k).as_usize().with_context(|| format!("config field {k}"))
        };
        let mut entrypoints = BTreeMap::new();
        for (en, ej) in cj.at("entrypoints").as_obj().context("entrypoints")? {
            entrypoints.insert(
                en.clone(),
                EntryPoint {
                    file: ej.at("file").as_str().context("entry file")?.to_string(),
                    inputs: parse_io(ej.at("inputs"))?,
                    outputs: parse_io(ej.at("outputs"))?,
                },
            );
        }
        Ok(ModelConfig {
            name: name.to_string(),
            arch: cj.at("arch").as_str().context("arch")?.to_string(),
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            d_ff: us("d_ff")?,
            vocab: us("vocab")?,
            seq_len: us("seq_len")?,
            max_rank: us("max_rank")?,
            rank_choices: cj
                .at("rank_choices")
                .as_shape()
                .context("rank_choices")?,
            lora_alpha: cj.at("lora_alpha").as_f64().context("lora_alpha")?,
            targets: cj
                .at("targets")
                .as_arr()
                .context("targets")?
                .iter()
                .map(|t| t.as_str().unwrap_or_default().to_string())
                .collect(),
            batch_train: us("batch_train")?,
            batch_eval: us("batch_eval")?,
            prefix_len: cj.at("prefix_len").as_usize().unwrap_or(4),
            bottleneck: cj.at("bottleneck").as_usize().unwrap_or(8),
            base_params: parse_params(cj.at("base_params"))?,
            adapter_params: parse_params(cj.at("adapter_params"))?,
            prefix_params: parse_params(cj.at("prefix_params"))?,
            series_params: parse_params(cj.at("series_params"))?,
            parallel_params: parse_params(cj.at("parallel_params"))?,
            adapter_modules: cj
                .at("adapter_modules")
                .as_arr()
                .context("adapter_modules")?
                .iter()
                .map(|m| m.as_str().unwrap_or_default().to_string())
                .collect(),
            prunable: cj
                .at("prunable")
                .as_arr()
                .context("prunable")?
                .iter()
                .map(|p| {
                    Ok(Prunable {
                        name: p.at("name").as_str().context("prunable name")?.to_string(),
                        shape: p.at("shape").as_shape().context("prunable shape")?,
                        site: p.at("site").as_str().context("prunable site")?.to_string(),
                    })
                })
                .collect::<Result<_>>()?,
            sites: cj
                .at("sites")
                .as_arr()
                .context("sites")?
                .iter()
                .map(|s| {
                    Ok((
                        s.at("site").as_str().context("site name")?.to_string(),
                        s.at("dim").as_usize().context("site dim")?,
                    ))
                })
                .collect::<Result<_>>()?,
            entrypoints,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest"))
    }

    /// Prune-op lookup by kind + weight shape.
    pub fn prune_op(&self, kind: &str, n: usize, k: usize) -> Result<&PruneOpSpec> {
        self.prune_ops
            .get(&format!("{kind}_{n}x{k}"))
            .with_context(|| format!("prune op {kind}_{n}x{k} not in manifest"))
    }
}

impl ModelConfig {
    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.entrypoints
            .get(name)
            .with_context(|| format!("entry point '{name}' not in config {}", self.name))
    }

    /// Total scalar count of a param group.
    pub fn numel(specs: &[ParamSpec]) -> usize {
        specs.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    /// LoRA scale = alpha / max_rank (matches L2).
    pub fn lora_scale(&self) -> f32 {
        (self.lora_alpha / self.max_rank as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1,
      "configs": {
        "t": {
          "arch": "llama", "d_model": 8, "n_layers": 1, "n_heads": 2,
          "d_ff": 16, "vocab": 32, "seq_len": 4, "max_rank": 4,
          "rank_choices": [4, 2], "lora_alpha": 8.0,
          "targets": ["q"], "batch_train": 2, "batch_eval": 2,
          "base_params": [{"name": "embed", "shape": [32, 8]}],
          "adapter_params": [{"name": "lora_a.layers.0.attn.q", "shape": [4, 8]}],
          "prefix_params": [], "series_params": [], "parallel_params": [],
          "adapter_modules": ["layers.0.attn.q"],
          "prunable": [{"name": "layers.0.attn.q", "shape": [8, 8], "site": "0.attn_in"}],
          "sites": [{"site": "0.attn_in", "dim": 8}],
          "entrypoints": {
            "forward_eval": {
              "file": "t__forward_eval.hlo.txt",
              "inputs": [{"name": "x", "shape": [2, 4], "dtype": "i32"}],
              "outputs": [{"name": "logits", "shape": [2, 4, 32], "dtype": "f32"}]
            }
          }
        }
      },
      "prune_ops": {
        "wanda_8x8": {
          "file": "prune__wanda_8x8.hlo.txt", "kind": "wanda", "shape": [8, 8],
          "inputs": [{"name": "w", "shape": [8, 8], "dtype": "f32"}],
          "outputs": [{"name": "w_pruned", "shape": [8, 8], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        let c = m.config("t").unwrap();
        assert_eq!(c.d_model, 8);
        assert_eq!(c.rank_choices, vec![4, 2]);
        assert_eq!(c.adapter_modules, vec!["layers.0.attn.q"]);
        assert_eq!(c.prunable[0].site, "0.attn_in");
        let e = c.entry("forward_eval").unwrap();
        assert_eq!(e.inputs[0].dtype, "i32");
        assert_eq!(e.outputs[0].shape, vec![2, 4, 32]);
        let p = m.prune_op("wanda", 8, 8).unwrap();
        assert_eq!(p.shape, (8, 8));
        assert!((c.lora_scale() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn missing_lookups_error() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.config("nope").is_err());
        assert!(m.prune_op("wanda", 9, 9).is_err());
        assert!(m.config("t").unwrap().entry("nope").is_err());
    }

    #[test]
    fn bad_version_rejected() {
        assert!(Manifest::parse(r#"{"version": 2, "configs": {}, "prune_ops": {}}"#).is_err());
    }
}
