//! Built-in manifest: the L2↔L3 ABI constructed in pure Rust.
//!
//! Mirrors `python/compile/model.py` (CONFIGS + the `*_param_specs`
//! functions), `train.py` (entry-point signatures) and `aot.py` (file
//! naming, ENTRY_SETS) exactly, so the native CPU backend can serve the
//! same entry points as the AOT'd artifacts without `make artifacts`
//! ever having run. Entry `file` names follow the artifact convention
//! (`{config}__{entry}.hlo.txt`, `prune__{kind}_{n}x{k}.hlo.txt`), which
//! keeps [`crate::runtime::Runtime::load`] backend-agnostic: the same
//! file name resolves to a compiled executable on PJRT and to a native
//! op here.
//!
//! If `python/compile/model.py` changes, this module must change with it
//! — the parity suite (`rust/tests/parity.rs`) pins the numerics and the
//! golden fixtures record the Python side's shapes.

use crate::model::manifest::{
    EntryPoint, IoSpec, Manifest, ModelConfig, ParamSpec, PruneOpSpec, Prunable,
};
use std::collections::BTreeMap;

/// Scalar knobs of one model configuration (mirrors a CONFIGS entry).
#[derive(Clone, Debug)]
pub struct ConfigSpec {
    pub name: String,
    pub arch: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub max_rank: usize,
    pub rank_choices: Vec<usize>,
    pub lora_alpha: f64,
    pub targets: Vec<String>,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub prefix_len: usize,
    pub bottleneck: usize,
}

fn strs(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

/// The four standard configurations (model.py CONFIGS, verbatim).
pub fn standard_configs() -> Vec<ConfigSpec> {
    vec![
        ConfigSpec {
            name: "tiny-llama".into(),
            arch: "llama".into(),
            d_model: 48,
            n_layers: 2,
            n_heads: 4,
            d_ff: 128,
            vocab: 256,
            seq_len: 48,
            max_rank: 8,
            rank_choices: vec![8, 6, 4],
            lora_alpha: 16.0,
            targets: strs(&["q", "k", "v", "up", "down"]),
            batch_train: 8,
            batch_eval: 16,
            prefix_len: 4,
            bottleneck: 8,
        },
        ConfigSpec {
            name: "llama-sim-s".into(),
            arch: "llama".into(),
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ff: 344,
            vocab: 512,
            seq_len: 64,
            max_rank: 8,
            rank_choices: vec![8, 6, 4],
            lora_alpha: 16.0,
            targets: strs(&["q", "k", "v", "up", "gate", "down"]),
            batch_train: 16,
            batch_eval: 32,
            prefix_len: 8,
            bottleneck: 16,
        },
        ConfigSpec {
            name: "llama-sim-m".into(),
            arch: "llama".into(),
            d_model: 192,
            n_layers: 6,
            n_heads: 8,
            d_ff: 512,
            vocab: 512,
            seq_len: 64,
            max_rank: 8,
            rank_choices: vec![8, 6, 4],
            lora_alpha: 16.0,
            targets: strs(&["q", "k", "v", "up", "down"]),
            batch_train: 16,
            batch_eval: 32,
            prefix_len: 8,
            bottleneck: 16,
        },
        ConfigSpec {
            name: "mpt-sim".into(),
            arch: "mpt".into(),
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            d_ff: 512,
            vocab: 512,
            seq_len: 64,
            max_rank: 8,
            rank_choices: vec![8, 6, 4],
            lora_alpha: 16.0,
            targets: strs(&["q", "k", "v", "o", "up", "down"]),
            batch_train: 16,
            batch_eval: 32,
            prefix_len: 8,
            bottleneck: 16,
        },
    ]
}

/// (out, in) dims of a target's weight (model.py `_target_shape`).
fn target_shape(d: usize, f: usize, t: &str) -> (usize, usize) {
    match t {
        "q" | "k" | "v" | "o" => (d, d),
        "gate" | "up" => (f, d),
        "down" => (d, f),
        other => panic!("unknown adapter target '{other}'"),
    }
}

fn p(name: String, shape: Vec<usize>) -> ParamSpec {
    ParamSpec { name, shape }
}

fn base_param_specs(c: &ConfigSpec) -> Vec<ParamSpec> {
    let (d, f, v) = (c.d_model, c.d_ff, c.vocab);
    let llama = c.arch == "llama";
    let mut s = vec![p("embed".into(), vec![v, d])];
    for i in 0..c.n_layers {
        let pre = format!("layers.{i}.");
        s.push(p(format!("{pre}attn_norm.g"), vec![d]));
        if !llama {
            s.push(p(format!("{pre}attn_norm.b"), vec![d]));
        }
        for t in ["q", "k", "v", "o"] {
            s.push(p(format!("{pre}attn.{t}"), vec![d, d]));
        }
        s.push(p(format!("{pre}mlp_norm.g"), vec![d]));
        if !llama {
            s.push(p(format!("{pre}mlp_norm.b"), vec![d]));
        }
        if llama {
            s.push(p(format!("{pre}mlp.gate"), vec![f, d]));
        }
        s.push(p(format!("{pre}mlp.up"), vec![f, d]));
        s.push(p(format!("{pre}mlp.down"), vec![d, f]));
    }
    s.push(p("final_norm.g".into(), vec![d]));
    if !llama {
        s.push(p("final_norm.b".into(), vec![d]));
    }
    s.push(p("lm_head".into(), vec![v, d]));
    s
}

fn adapter_modules(c: &ConfigSpec) -> Vec<String> {
    let mut mods = Vec::new();
    for i in 0..c.n_layers {
        for t in &c.targets {
            let sect = if matches!(t.as_str(), "q" | "k" | "v" | "o") { "attn" } else { "mlp" };
            mods.push(format!("layers.{i}.{sect}.{t}"));
        }
    }
    mods
}

fn adapter_param_specs(c: &ConfigSpec) -> Vec<ParamSpec> {
    let r = c.max_rank;
    let mut s = Vec::new();
    for m in adapter_modules(c) {
        let t = m.rsplit('.').next().unwrap();
        let (out, inp) = target_shape(c.d_model, c.d_ff, t);
        s.push(p(format!("lora_a.{m}"), vec![r, inp]));
        s.push(p(format!("lora_b.{m}"), vec![out, r]));
    }
    s
}

fn prefix_param_specs(c: &ConfigSpec) -> Vec<ParamSpec> {
    let dh = c.d_model / c.n_heads;
    let mut s = Vec::new();
    for i in 0..c.n_layers {
        s.push(p(format!("prefix_k.{i}"), vec![c.n_heads, c.prefix_len, dh]));
        s.push(p(format!("prefix_v.{i}"), vec![c.n_heads, c.prefix_len, dh]));
    }
    s
}

fn series_param_specs(c: &ConfigSpec) -> Vec<ParamSpec> {
    let (d, bn) = (c.d_model, c.bottleneck);
    let mut s = Vec::new();
    for i in 0..c.n_layers {
        s.push(p(format!("series_down.{i}"), vec![bn, d]));
        s.push(p(format!("series_up.{i}"), vec![d, bn]));
    }
    s
}

fn parallel_param_specs(c: &ConfigSpec) -> Vec<ParamSpec> {
    let (d, bn) = (c.d_model, c.bottleneck);
    let mut s = Vec::new();
    for i in 0..c.n_layers {
        s.push(p(format!("parallel_down.{i}"), vec![bn, d]));
        s.push(p(format!("parallel_up.{i}"), vec![d, bn]));
    }
    s
}

fn prunable_specs(c: &ConfigSpec) -> Vec<Prunable> {
    let (d, f) = (c.d_model, c.d_ff);
    let llama = c.arch == "llama";
    let mut s = Vec::new();
    for i in 0..c.n_layers {
        let pre = format!("layers.{i}.");
        for t in ["q", "k", "v"] {
            s.push(Prunable {
                name: format!("{pre}attn.{t}"),
                shape: vec![d, d],
                site: format!("{i}.attn_in"),
            });
        }
        s.push(Prunable {
            name: format!("{pre}attn.o"),
            shape: vec![d, d],
            site: format!("{i}.o_in"),
        });
        if llama {
            s.push(Prunable {
                name: format!("{pre}mlp.gate"),
                shape: vec![f, d],
                site: format!("{i}.mlp_in"),
            });
        }
        s.push(Prunable {
            name: format!("{pre}mlp.up"),
            shape: vec![f, d],
            site: format!("{i}.mlp_in"),
        });
        s.push(Prunable {
            name: format!("{pre}mlp.down"),
            shape: vec![d, f],
            site: format!("{i}.down_in"),
        });
    }
    s
}

fn calib_sites(c: &ConfigSpec) -> Vec<(String, usize)> {
    let (d, f) = (c.d_model, c.d_ff);
    let mut s = Vec::new();
    for i in 0..c.n_layers {
        s.push((format!("{i}.attn_in"), d));
        s.push((format!("{i}.o_in"), d));
        s.push((format!("{i}.mlp_in"), d));
        s.push((format!("{i}.down_in"), f));
    }
    s
}

// ------------------------------------------------------- entry signatures

fn io_f32(name: impl Into<String>, shape: Vec<usize>) -> IoSpec {
    IoSpec { name: name.into(), shape, dtype: "f32".into() }
}

fn io_i32(name: impl Into<String>, shape: Vec<usize>) -> IoSpec {
    IoSpec { name: name.into(), shape, dtype: "i32".into() }
}

fn params_io(specs: &[ParamSpec], prefix: &str) -> Vec<IoSpec> {
    specs
        .iter()
        .map(|s| io_f32(format!("{prefix}{}", s.name), s.shape.clone()))
        .collect()
}

/// step, lr, x, y, loss_mask — the train-batch tail shared by every step.
fn train_tail(c: &ConfigSpec) -> Vec<IoSpec> {
    vec![
        io_f32("step", vec![]),
        io_f32("lr", vec![]),
        io_i32("x", vec![c.batch_train, c.seq_len]),
        io_i32("y", vec![c.batch_train, c.seq_len]),
        io_f32("loss_mask", vec![c.batch_train, c.seq_len]),
    ]
}

fn entry(c: &ConfigSpec, entry_name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>) -> EntryPoint {
    EntryPoint {
        file: format!("{}__{}.hlo.txt", c.name, entry_name),
        inputs,
        outputs,
    }
}

fn build_entrypoints(c: &ConfigSpec) -> BTreeMap<String, EntryPoint> {
    let base = base_param_specs(c);
    let adpt = adapter_param_specs(c);
    let prun = prunable_specs(c);
    let n_mods = adapter_modules(c).len();
    let r = c.max_rank;
    let (be, s, v) = (c.batch_eval, c.seq_len, c.vocab);
    let logits = vec![io_f32("logits", vec![be, s, v])];
    let mut map = BTreeMap::new();

    // train_step_nls: super-adapter NLS step (train.py build_train_step_nls)
    {
        let mut inputs = params_io(&base, "");
        inputs.extend(params_io(&adpt, ""));
        inputs.extend(params_io(&adpt, "m."));
        inputs.extend(params_io(&adpt, "v."));
        inputs.extend(train_tail(c));
        inputs.push(io_f32("rank_mask", vec![n_mods, r]));
        let mut outputs = params_io(&adpt, "");
        outputs.extend(params_io(&adpt, "m."));
        outputs.extend(params_io(&adpt, "v."));
        outputs.push(io_f32("loss", vec![]));
        map.insert("train_step_nls".to_string(), entry(c, "train_step_nls", inputs, outputs));
    }

    // train_step_full: full FT with mask re-application (SparseFT / pretrain)
    {
        let mut inputs = params_io(&base, "");
        inputs.extend(params_io(&base, "m."));
        inputs.extend(params_io(&base, "v."));
        for pr in &prun {
            inputs.push(io_f32(format!("mask.{}", pr.name), pr.shape.clone()));
        }
        inputs.extend(train_tail(c));
        let mut outputs = params_io(&base, "");
        outputs.extend(params_io(&base, "m."));
        outputs.extend(params_io(&base, "v."));
        outputs.push(io_f32("loss", vec![]));
        map.insert("train_step_full".to_string(), entry(c, "train_step_full", inputs, outputs));
    }

    // PEFT-baseline train steps (shared shape)
    for (name, extra) in [
        ("train_step_prefix", prefix_param_specs(c)),
        ("train_step_series", series_param_specs(c)),
        ("train_step_parallel", parallel_param_specs(c)),
    ] {
        let mut inputs = params_io(&base, "");
        inputs.extend(params_io(&extra, ""));
        inputs.extend(params_io(&extra, "m."));
        inputs.extend(params_io(&extra, "v."));
        inputs.extend(train_tail(c));
        let mut outputs = params_io(&extra, "");
        outputs.extend(params_io(&extra, "m."));
        outputs.extend(params_io(&extra, "v."));
        outputs.push(io_f32("loss", vec![]));
        map.insert(name.to_string(), entry(c, name, inputs, outputs));
    }

    // forward_eval (+ the pallas-lowered alias; native executes one impl)
    let fwd_names: &[&str] = if matches!(c.name.as_str(), "tiny-llama" | "llama-sim-s") {
        &["forward_eval", "forward_eval_pallas"]
    } else {
        &["forward_eval"]
    };
    for name in fwd_names {
        let mut inputs = params_io(&base, "");
        inputs.extend(params_io(&adpt, ""));
        inputs.push(io_i32("x", vec![be, s]));
        inputs.push(io_f32("rank_mask", vec![n_mods, r]));
        map.insert(name.to_string(), entry(c, name, inputs, logits.clone()));
    }

    // forward_eval_base
    {
        let mut inputs = params_io(&base, "");
        inputs.push(io_i32("x", vec![be, s]));
        map.insert(
            "forward_eval_base".to_string(),
            entry(c, "forward_eval_base", inputs, logits.clone()),
        );
    }

    // PEFT-baseline forwards
    for (name, extra) in [
        ("forward_eval_prefix", prefix_param_specs(c)),
        ("forward_eval_series", series_param_specs(c)),
        ("forward_eval_parallel", parallel_param_specs(c)),
    ] {
        let mut inputs = params_io(&base, "");
        inputs.extend(params_io(&extra, ""));
        inputs.push(io_i32("x", vec![be, s]));
        map.insert(name.to_string(), entry(c, name, inputs, logits.clone()));
    }

    // calib_stats: per-site (Σx², Gram) for Wanda/SparseGPT
    {
        let mut inputs = params_io(&base, "");
        inputs.push(io_i32("x", vec![be, s]));
        let mut outputs = Vec::new();
        for (site, dim) in calib_sites(c) {
            outputs.push(io_f32(format!("sumsq.{site}"), vec![dim]));
            outputs.push(io_f32(format!("gram.{site}"), vec![dim, dim]));
        }
        map.insert("calib_stats".to_string(), entry(c, "calib_stats", inputs, outputs));
    }

    map
}

/// Build a full [`ModelConfig`] (specs + entry points) from scalar knobs.
pub fn make_config(spec: &ConfigSpec) -> ModelConfig {
    ModelConfig {
        name: spec.name.clone(),
        arch: spec.arch.clone(),
        d_model: spec.d_model,
        n_layers: spec.n_layers,
        n_heads: spec.n_heads,
        d_ff: spec.d_ff,
        vocab: spec.vocab,
        seq_len: spec.seq_len,
        max_rank: spec.max_rank,
        rank_choices: spec.rank_choices.clone(),
        lora_alpha: spec.lora_alpha,
        targets: spec.targets.clone(),
        batch_train: spec.batch_train,
        batch_eval: spec.batch_eval,
        prefix_len: spec.prefix_len,
        bottleneck: spec.bottleneck,
        base_params: base_param_specs(spec),
        adapter_params: adapter_param_specs(spec),
        prefix_params: prefix_param_specs(spec),
        series_params: series_param_specs(spec),
        parallel_params: parallel_param_specs(spec),
        adapter_modules: adapter_modules(spec),
        prunable: prunable_specs(spec),
        sites: calib_sites(spec),
        entrypoints: build_entrypoints(spec),
    }
}

/// The built-in manifest: all standard configs + every prune op shape.
pub fn builtin_manifest() -> Manifest {
    let specs = standard_configs();
    let mut configs = BTreeMap::new();
    let mut shapes = std::collections::BTreeSet::new();
    for spec in &specs {
        let cfg = make_config(spec);
        for pr in &cfg.prunable {
            shapes.insert((pr.shape[0], pr.shape[1]));
        }
        configs.insert(spec.name.clone(), cfg);
    }
    let mut prune_ops = BTreeMap::new();
    for (n, k) in shapes {
        for kind in ["wanda", "magnitude", "sparsegpt"] {
            let mut inputs = vec![io_f32("w", vec![n, k])];
            match kind {
                "wanda" => inputs.push(io_f32("xnorm_sq", vec![k])),
                "sparsegpt" => inputs.push(io_f32("gram", vec![k, k])),
                _ => {}
            }
            inputs.push(io_f32("keep_frac", vec![]));
            prune_ops.insert(
                format!("{kind}_{n}x{k}"),
                PruneOpSpec {
                    file: format!("prune__{kind}_{n}x{k}.hlo.txt"),
                    kind: kind.to_string(),
                    shape: (n, k),
                    inputs,
                    outputs: vec![io_f32("w_pruned", vec![n, k]), io_f32("mask", vec![n, k])],
                },
            );
        }
    }
    Manifest { configs, prune_ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_mirrors_python_abi() {
        let m = builtin_manifest();
        assert_eq!(m.configs.len(), 4);
        let c = m.config("tiny-llama").unwrap();
        assert_eq!(c.d_model, 48);
        assert_eq!(c.adapter_modules.len(), 2 * 5);
        // llama base params: embed + L*(2 norms + 4 attn + 3 mlp) + final + head
        assert_eq!(c.base_params.len(), 1 + 2 * 9 + 2);
        assert_eq!(c.entrypoints.len(), 12);
        // NLS signature: base + 3*adapters + 6 tail inputs
        let e = c.entry("train_step_nls").unwrap();
        assert_eq!(e.inputs.len(), c.base_params.len() + 3 * c.adapter_params.len() + 6);
        assert_eq!(
            e.outputs.last().map(|o| o.name.as_str()),
            Some("loss")
        );
        // the rank-mask input is declared (train/mod.rs keys off it)
        assert!(e.inputs.iter().any(|i| i.name == "rank_mask"));
        // prune ops cover every prunable shape in all three kinds
        for cfg in m.configs.values() {
            for pr in &cfg.prunable {
                for kind in ["wanda", "magnitude", "sparsegpt"] {
                    assert!(m.prune_op(kind, pr.shape[0], pr.shape[1]).is_ok());
                }
            }
        }
    }

    #[test]
    fn mpt_has_layernorm_biases_and_no_gate() {
        let m = builtin_manifest();
        let c = m.config("mpt-sim").unwrap();
        assert!(c.base_params.iter().any(|p| p.name == "layers.0.attn_norm.b"));
        assert!(!c.base_params.iter().any(|p| p.name.contains("mlp.gate")));
        assert!(c.entry("forward_eval_pallas").is_err());
        assert!(c.entry("forward_eval").is_ok());
    }

    #[test]
    fn calib_outputs_follow_site_order() {
        let m = builtin_manifest();
        let c = m.config("tiny-llama").unwrap();
        let e = c.entry("calib_stats").unwrap();
        assert_eq!(e.outputs.len(), 2 * c.sites.len());
        assert_eq!(e.outputs[0].name, "sumsq.0.attn_in");
        assert_eq!(e.outputs[1].name, "gram.0.attn_in");
        assert_eq!(e.outputs[1].shape, vec![48, 48]);
    }
}
