//! `ParamStore`: host-side parameter state for one model config.
//!
//! Holds named `HostTensor`s and serves them *in manifest order* to the
//! runtime. Initialization mirrors the L2 conventions: norm gains 1,
//! biases 0, weights N(0, 0.05); LoRA A N(0, 0.02), LoRA B zeros (the
//! paper's §2.2 init — adapters start transparent).
//!
//! Every entry carries a **generation** counter, bumped on `insert` and
//! `get_mut`: `runtime::ResidentParams` keys its uploaded buffers (and
//! their cached prepared structure — the CSR forward gather *and* the
//! CSC backward view, which live inside one `PreparedWeight`) on it, so
//! a prune step or optimizer update invalidates exactly the weights it
//! touched, across both the forward and backward kernel paths.

use crate::model::manifest::{ModelConfig, ParamSpec};
use crate::tensor::HostTensor;
use crate::util::durable;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

#[derive(Clone, Debug)]
struct Entry {
    t: HostTensor,
    generation: u64,
}

#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    map: BTreeMap<String, Entry>,
    next_gen: u64,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: HostTensor) {
        self.next_gen += 1;
        self.map.insert(name.to_string(), Entry { t, generation: self.next_gen });
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.map
            .get(name)
            .map(|e| &e.t)
            .with_context(|| format!("param '{name}' missing"))
    }

    /// Mutable access bumps the generation: any resident copy of this
    /// tensor (and its cached prepared structure) becomes stale.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut HostTensor> {
        self.next_gen += 1;
        let gen = self.next_gen;
        self.map
            .get_mut(name)
            .map(|e| {
                e.generation = gen;
                &mut e.t
            })
            .with_context(|| format!("param '{name}' missing"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// `(name, tensor, generation)` over every entry.
    pub fn entries(&self) -> impl Iterator<Item = (&String, &HostTensor, u64)> {
        self.map.iter().map(|(n, e)| (n, &e.t, e.generation))
    }

    /// Current generation of `name` (changes whenever the tensor may
    /// have changed).
    pub fn generation(&self, name: &str) -> Option<u64> {
        self.map.get(name).map(|e| e.generation)
    }

    /// Tensors in the order of `specs` (the manifest ABI order).
    pub fn ordered<'a>(&'a self, specs: &[ParamSpec]) -> Result<Vec<&'a HostTensor>> {
        specs.iter().map(|s| self.get(&s.name)).collect()
    }

    /// Replace tensors following `specs` order from an output slice.
    pub fn update_from(&mut self, specs: &[ParamSpec], outs: &[HostTensor]) -> Result<()> {
        if outs.len() < specs.len() {
            bail!("update_from: {} outputs < {} specs", outs.len(), specs.len());
        }
        for (s, t) in specs.iter().zip(outs) {
            if t.shape != s.shape {
                bail!("shape mismatch for {}: {:?} vs {:?}", s.name, t.shape, s.shape);
            }
            self.insert(&s.name, t.clone());
        }
        Ok(())
    }

    // ------------------------------------------------------------- init

    /// Base-model init (pre-pretraining): norm gains 1, biases 0,
    /// everything else N(0, std).
    pub fn init_base(cfg: &ModelConfig, rng: &mut Rng, std: f32) -> Self {
        let mut s = Self::new();
        for p in &cfg.base_params {
            let t = if p.name.ends_with(".g") {
                HostTensor::ones(&p.shape)
            } else if p.name.ends_with(".b") {
                HostTensor::zeros(&p.shape)
            } else {
                let mut t = HostTensor::zeros(&p.shape);
                rng.fill_normal(t.f32s_mut(), 0.0, std);
                t
            };
            s.insert(&p.name, t);
        }
        s
    }

    /// Elastic LoRA super-adapter init (paper §2.2): A gaussian, B zero.
    pub fn init_adapters(cfg: &ModelConfig, rng: &mut Rng) -> Self {
        let mut s = Self::new();
        for p in &cfg.adapter_params {
            let t = if p.name.starts_with("lora_a.") {
                let mut t = HostTensor::zeros(&p.shape);
                rng.fill_normal(t.f32s_mut(), 0.0, 0.02);
                t
            } else {
                HostTensor::zeros(&p.shape)
            };
            s.insert(&p.name, t);
        }
        s
    }

    /// Baseline-adapter init (prefix / series / parallel param groups).
    pub fn init_extra(specs: &[ParamSpec], rng: &mut Rng) -> Self {
        let mut s = Self::new();
        for p in specs {
            // "up" projections start at zero so baselines also begin
            // transparent (matches LoRA's B=0 convention).
            let t = if p.name.contains("up") {
                HostTensor::zeros(&p.shape)
            } else {
                let mut t = HostTensor::zeros(&p.shape);
                rng.fill_normal(t.f32s_mut(), 0.0, 0.02);
                t
            };
            s.insert(&p.name, t);
        }
        s
    }

    /// Zeroed optimizer state aligned with `specs`.
    pub fn zeros_like(specs: &[ParamSpec]) -> Self {
        let mut s = Self::new();
        for p in specs {
            s.insert(&p.name, HostTensor::zeros(&p.shape));
        }
        s
    }

    // --------------------------------------------------------- counting

    /// Total parameters in the store.
    pub fn numel(&self) -> usize {
        self.map.values().map(|e| e.t.numel()).sum()
    }

    /// Non-zero parameters (paper Table 3's headline metric).
    pub fn nonzero(&self) -> usize {
        self.map.values().map(|e| e.t.numel() - e.t.zeros_count()).sum()
    }

    /// Overall sparsity across a named subset (e.g. the prunable weights).
    pub fn sparsity_of(&self, names: &[String]) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for n in names {
            if let Some(e) = self.map.get(n) {
                zeros += e.t.zeros_count();
                total += e.t.numel();
            }
        }
        zeros as f64 / total.max(1) as f64
    }

    // ------------------------------------------------------- checkpoints

    /// Serialize to the checkpoint payload: `"SHRS"`, `[count u64]`,
    /// then (name, tensor) records. No footer — this is the embeddable
    /// form (training checkpoints nest several stores in one file);
    /// [`ParamStore::save`] adds the integrity footer via
    /// [`durable::write_atomic`].
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut payload = Vec::new();
        payload.extend_from_slice(b"SHRS");
        payload.extend_from_slice(&(self.map.len() as u64).to_le_bytes());
        for (name, e) in &self.map {
            let nb = name.as_bytes();
            payload.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            payload.extend_from_slice(nb);
            e.t.write_to(&mut payload)?;
        }
        Ok(payload)
    }

    /// Parse a payload produced by [`ParamStore::to_bytes`].
    /// Corruption is a clean `corrupt checkpoint` error — never a
    /// panic, never a partially-filled store.
    pub fn from_bytes(payload: &[u8]) -> Result<Self> {
        Self::parse(payload)
    }

    /// Binary checkpoint: the [`ParamStore::to_bytes`] payload closed
    /// by an integrity footer ([`durable::FOOTER_MAGIC`]).
    ///
    /// The write is **atomic** (same-directory temp file + fsync +
    /// rename — [`durable::write_atomic`]). A crash (or a supervisor
    /// kill) mid-save leaves the previous checkpoint intact — readers
    /// never observe a half-written file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        durable::write_atomic(path, &self.to_bytes()?)
    }

    /// Load a checkpoint, validating the integrity footer when present.
    /// Corruption (bad checksum, truncation, trailing bytes, impossible
    /// record claims) is a clean `corrupt checkpoint` error — never a
    /// panic, never a partially-filled store. Footer-less files written
    /// by older versions still load.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::parse(&durable::read_verified(path, "checkpoint")?)
    }

    fn parse(payload: &[u8]) -> Result<Self> {
        let mut r = std::io::Cursor::new(payload);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("corrupt checkpoint: truncated header")?;
        if &magic != b"SHRS" {
            bail!("not a shears checkpoint");
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8).context("corrupt checkpoint: truncated header")?;
        let count = u64::from_le_bytes(b8) as usize;
        let mut s = Self::new();
        for i in 0..count {
            let mut b4 = [0u8; 4];
            r.read_exact(&mut b4)
                .with_context(|| format!("corrupt checkpoint: truncated at record {i} of {count}"))?;
            let nlen = u32::from_le_bytes(b4) as usize;
            if nlen > 4096 {
                bail!("corrupt checkpoint: name length {nlen}");
            }
            let mut nb = vec![0u8; nlen];
            r.read_exact(&mut nb)
                .with_context(|| format!("corrupt checkpoint: truncated at record {i} of {count}"))?;
            let name = String::from_utf8(nb).context("param name utf8")?;
            let t = HostTensor::read_from(&mut r)
                .with_context(|| format!("corrupt checkpoint: record {i} ('{name}')"))?;
            s.insert(&name, t);
        }
        let pos = r.position() as usize;
        if pos != payload.len() {
            bail!(
                "corrupt checkpoint: {} trailing bytes after {count} records",
                payload.len() - pos
            );
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;

    fn mini_config() -> ModelConfig {
        // reuse the manifest-test fixture through the public parser
        let m = Manifest::parse(
            r#"{
          "version": 1,
          "configs": {
            "t": {
              "arch": "llama", "d_model": 8, "n_layers": 1, "n_heads": 2,
              "d_ff": 16, "vocab": 32, "seq_len": 4, "max_rank": 4,
              "rank_choices": [4, 2], "lora_alpha": 8.0,
              "targets": ["q"], "batch_train": 2, "batch_eval": 2,
              "base_params": [
                 {"name": "embed", "shape": [32, 8]},
                 {"name": "layers.0.attn_norm.g", "shape": [8]},
                 {"name": "layers.0.attn.q", "shape": [8, 8]}
              ],
              "adapter_params": [
                 {"name": "lora_a.layers.0.attn.q", "shape": [4, 8]},
                 {"name": "lora_b.layers.0.attn.q", "shape": [8, 4]}
              ],
              "prefix_params": [], "series_params": [], "parallel_params": [],
              "adapter_modules": ["layers.0.attn.q"],
              "prunable": [{"name": "layers.0.attn.q", "shape": [8, 8], "site": "0.attn_in"}],
              "sites": [{"site": "0.attn_in", "dim": 8}],
              "entrypoints": {}
            }
          },
          "prune_ops": {}
        }"#,
        )
        .unwrap();
        m.config("t").unwrap().clone()
    }

    #[test]
    fn init_conventions() {
        let cfg = mini_config();
        let mut rng = Rng::new(0);
        let base = ParamStore::init_base(&cfg, &mut rng, 0.05);
        assert!(base.get("layers.0.attn_norm.g").unwrap().f32s().iter().all(|x| *x == 1.0));
        assert!(base.get("embed").unwrap().f32s().iter().any(|x| *x != 0.0));

        let ad = ParamStore::init_adapters(&cfg, &mut rng);
        assert!(ad.get("lora_b.layers.0.attn.q").unwrap().f32s().iter().all(|x| *x == 0.0));
        assert!(ad.get("lora_a.layers.0.attn.q").unwrap().f32s().iter().any(|x| *x != 0.0));
    }

    #[test]
    fn deterministic_init() {
        let cfg = mini_config();
        let a = ParamStore::init_base(&cfg, &mut Rng::new(7), 0.05);
        let b = ParamStore::init_base(&cfg, &mut Rng::new(7), 0.05);
        assert_eq!(a.get("embed").unwrap(), b.get("embed").unwrap());
    }

    #[test]
    fn ordered_respects_specs() {
        let cfg = mini_config();
        let base = ParamStore::init_base(&cfg, &mut Rng::new(0), 0.05);
        let v = base.ordered(&cfg.base_params).unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].shape, vec![32, 8]); // embed first per manifest
    }

    #[test]
    fn update_from_checks_shapes() {
        let cfg = mini_config();
        let mut base = ParamStore::init_base(&cfg, &mut Rng::new(0), 0.05);
        let bad = vec![HostTensor::zeros(&[1, 1])];
        assert!(base.update_from(&cfg.base_params[..1], &bad).is_err());
        let good = vec![HostTensor::ones(&[32, 8])];
        base.update_from(&cfg.base_params[..1], &good).unwrap();
        assert_eq!(base.get("embed").unwrap().f32s()[0], 1.0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = mini_config();
        let base = ParamStore::init_base(&cfg, &mut Rng::new(3), 0.05);
        let dir = std::env::temp_dir().join("shears_test_ckpt");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("params.bin");
        base.save(&path).unwrap();
        let re = ParamStore::load(&path).unwrap();
        assert_eq!(re.len(), base.len());
        assert_eq!(re.get("embed").unwrap(), base.get("embed").unwrap());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_leaves_footer_and_no_temp_file() {
        let cfg = mini_config();
        let base = ParamStore::init_base(&cfg, &mut Rng::new(5), 0.05);
        let dir = std::env::temp_dir().join("shears_test_ckpt_footer");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("params.bin");
        base.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[bytes.len() - 4..], durable::FOOTER_MAGIC, "footer trailer magic");
        assert!(
            !dir.join("params.bin.tmp").exists(),
            "temp file is renamed away, not left behind"
        );
        // overwrite-in-place (the common checkpoint cadence) keeps working
        base.save(&path).unwrap();
        let re = ParamStore::load(&path).unwrap();
        assert_eq!(re.len(), base.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn generations_bump_on_insert_and_get_mut() {
        let mut s = ParamStore::new();
        s.insert("w", HostTensor::zeros(&[2]));
        let g0 = s.generation("w").unwrap();
        // read access leaves the generation alone
        let _ = s.get("w").unwrap();
        assert_eq!(s.generation("w"), Some(g0));
        // mutable access marks the tensor changed
        let _ = s.get_mut("w").unwrap();
        let g1 = s.generation("w").unwrap();
        assert!(g1 > g0);
        // replacing bumps again
        s.insert("w", HostTensor::ones(&[2]));
        assert!(s.generation("w").unwrap() > g1);
        assert_eq!(s.entries().count(), 1);
    }

    #[test]
    fn nonzero_counting() {
        let mut s = ParamStore::new();
        s.insert("w", HostTensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 2.0]));
        assert_eq!(s.numel(), 4);
        assert_eq!(s.nonzero(), 2);
        assert_eq!(s.sparsity_of(&["w".to_string()]), 0.5);
    }
}
