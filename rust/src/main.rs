//! `shears` — the Layer-3 leader binary.
//!
//! ```text
//! shears info      [--backend native|pjrt|auto --artifacts DIR]
//! shears pipeline  [--config NAME --method M --sparsity S --steps N ...]
//! shears eval      [--config NAME --tasks t1,t2 ...]   (base model, w/o tune)
//! shears serve     [--config NAME --requests N ...]
//! ```
//!
//! `--backend native` (or any build without artifacts) runs the whole
//! workflow on the pure-Rust CPU executor — no Python or XLA required.
//!
//! Every subcommand is a thin shell over the library (`shears::*`); the
//! real functionality lives there and in examples/ + rust/benches/.

use anyhow::{bail, Result};
use shears::cli::{usage, Args, FlagSpec};
use shears::coordinator::{PipelineOpts, ShearsPipeline};
use shears::data::{self, Task, Vocab};
use shears::pruning::Method;
use shears::runtime::Runtime;
use shears::serve::{Decoder, GenRequest, ServeServer, ServerOpts, Submit};
use shears::train::evaluate;
use shears::util::rng::Rng;

fn flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "artifacts", default: Some("artifacts"), help: "artifacts directory" },
        FlagSpec {
            name: "backend",
            default: Some("auto"),
            help: "native|pjrt|auto (auto = pjrt when built with `xla` and artifacts exist)",
        },
        FlagSpec { name: "config", default: Some("tiny-llama"), help: "model config name" },
        FlagSpec { name: "method", default: Some("wanda"), help: "wanda|magnitude|sparsegpt" },
        FlagSpec { name: "sparsity", default: Some("0.5"), help: "target sparsity" },
        FlagSpec { name: "pretrain-steps", default: Some("200"), help: "pretraining steps" },
        FlagSpec { name: "steps", default: Some("150"), help: "super-adapter train steps" },
        FlagSpec { name: "lr", default: Some("3e-3"), help: "peak learning rate" },
        FlagSpec { name: "seed", default: Some("42"), help: "random seed" },
        FlagSpec { name: "tasks", default: Some("gsm8k-sim"), help: "comma-separated task names" },
        FlagSpec { name: "train-examples", default: Some("256"), help: "fine-tune set size" },
        FlagSpec { name: "eval-examples", default: Some("64"), help: "test set size" },
        FlagSpec { name: "hill-climb", default: Some("0"), help: "hill-climb eval budget (0 = heuristic only)" },
        FlagSpec { name: "workdir", default: Some("runs"), help: "checkpoint cache directory" },
        FlagSpec {
            name: "checkpoint-every",
            default: Some("0"),
            help: "pipeline: snapshot train/search state to workdir every N \
                   steps, with divergence rollback (0 = guards off)",
        },
        FlagSpec {
            name: "rollback-budget",
            default: Some("3"),
            help: "pipeline: training divergence rollbacks tolerated before a \
                   clean abort (needs --checkpoint-every)",
        },
        FlagSpec {
            name: "eval-timeout-ms",
            default: Some("0"),
            help: "pipeline: run search evals in a supervised worker with this \
                   per-call timeout; wedged workers are respawned and the eval \
                   retried (0 = in-process evals)",
        },
        FlagSpec { name: "requests", default: Some("32"), help: "serve: request count" },
        FlagSpec { name: "max-new", default: Some("8"), help: "serve: max new tokens" },
        FlagSpec {
            name: "submitters",
            default: Some("0"),
            help: "serve: submitter threads driving the async queue (0 = batch API)",
        },
        FlagSpec {
            name: "queue-cap",
            default: Some("64"),
            help: "serve: async pending-queue bound (submissions past it are rejected)",
        },
        FlagSpec {
            name: "deadline-ms",
            default: Some("0"),
            help: "serve: per-request deadline for EDF admission (0 = best effort)",
        },
        FlagSpec {
            name: "max-wall-ms",
            default: Some("0"),
            help: "serve: hard per-request wall-clock budget — requests past it \
                   are cancelled mid-decode, freeing their KV slot (0 = unbounded)",
        },
        FlagSpec {
            name: "restart-budget",
            default: Some("3"),
            help: "serve: supervised engine rebuilds tolerated after panics before \
                   the async server shuts down cleanly (SHEARS_FAULT arms drills)",
        },
        FlagSpec {
            name: "tenants",
            default: Some("0"),
            help: "serve: register N tenant sub-adapters and tag requests \
                   round-robin (0 = single-tenant base entry)",
        },
        FlagSpec {
            name: "adapter-budget",
            default: Some("0"),
            help: "serve: resident adapter byte budget, k/m/g suffixes ok \
                   (0 = unlimited; LRU-evicts idle adapters past it)",
        },
        FlagSpec {
            name: "threads",
            default: Some("0"),
            help: "native kernel worker threads (0 = SHEARS_NUM_THREADS or all cores)",
        },
        FlagSpec {
            name: "brownout-fraction",
            default: Some("0.5"),
            help: "serve: LoRA rank fraction for degraded admissions \
                   (prefix sub-adapter; needs --brownout)",
        },
        FlagSpec {
            name: "brownout-step-hi-ms",
            default: Some("0"),
            help: "serve: EWMA step latency that trips Degraded; lo = hi/2, \
                   Shedding at 4x (0 = latency signal unused)",
        },
        FlagSpec {
            name: "brownout-queue-hi",
            default: Some("0"),
            help: "serve: queue depth that trips Degraded; lo = hi/2, \
                   Shedding near queue-cap (0 = 3/4 of --queue-cap)",
        },
        FlagSpec {
            name: "brownout-miss-hi",
            default: Some("0"),
            help: "serve: deadline-miss rate (0..1) over recent completions \
                   that trips Degraded; lo = hi/2 (0 = miss signal unused)",
        },
        FlagSpec {
            name: "shed-horizon-ms",
            default: Some("1000"),
            help: "serve: while Shedding, admit only what fits this latency \
                   horizon; excess submissions are rejected as Overloaded",
        },
    ]
}

/// Switches (value-less flags) shared by all subcommands.
const SWITCHES: &[&str] = &["brownout", "resume"];

fn parse_tasks(spec: &str) -> Result<Vec<Task>> {
    let all: Vec<Task> = Task::MATH.iter().chain(Task::COMMONSENSE.iter()).copied().collect();
    spec.split(',')
        .filter(|t| !t.is_empty())
        .map(|name| {
            all.iter()
                .find(|t| t.name() == name)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("unknown task '{name}'"))
        })
        .collect()
}

fn parse_method(m: &str) -> Result<Method> {
    Ok(match m {
        "wanda" => Method::Wanda,
        "magnitude" => Method::Magnitude,
        "sparsegpt" => Method::SparseGpt,
        other => bail!("unknown method '{other}'"),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" {
        eprintln!("usage: shears <info|pipeline|eval|serve|check|lint> [flags]\n");
        eprintln!("{}", usage(&flags(), SWITCHES));
        return Ok(());
    }
    let args = Args::parse(&argv, &flags(), SWITCHES)?;
    // thread-count override for the native kernel engine; never changes
    // results (deterministic row partitioning), only wall time
    let threads = args.get_usize("threads")?;
    if threads > 0 {
        shears::ops::linalg::set_num_threads(threads);
    }
    match args.subcommand.as_str() {
        "info" => cmd_info(&args),
        "pipeline" => cmd_pipeline(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "check" => cmd_check(&args),
        "lint" => cmd_lint(),
        other => bail!("unknown subcommand '{other}' (try: shears help)"),
    }
}

/// Run the crate-native static-analysis pass (same engine as the
/// `shears-lint` binary and `tests/lints.rs`) over this crate's own
/// sources; fails on any diagnostic or stale allowlist entry.
fn cmd_lint() -> Result<()> {
    let report = shears::analysis::lint_self()?;
    for d in &report.diags {
        println!("{d}");
    }
    println!(
        "shears-lint: {} file(s), {} diagnostic(s), allowlist {}/{} entries used",
        report.files,
        report.diags.len(),
        report.allow_used,
        report.allow_total
    );
    if report.diags.is_empty() {
        Ok(())
    } else {
        bail!("{} lint diagnostic(s)", report.diags.len())
    }
}

/// Load-check every manifest entry point one by one (debug aid: XLA
/// aborts the process on some unsupported ops, so each file gets its own
/// verdict line first; on the native backend this verifies entry-point
/// coverage instead).
fn cmd_check(args: &Args) -> Result<()> {
    let rt = Runtime::from_flag(args.get("backend"), args.get("artifacts"))?;
    let manifest = rt.manifest()?;
    let only = args.get("config"); // reuse flag: substring filter
    let mut files: Vec<String> = manifest
        .configs
        .values()
        .flat_map(|c| c.entrypoints.values().map(|e| e.file.clone()))
        .chain(manifest.prune_ops.values().map(|p| p.file.clone()))
        .filter(|f| f.contains(only))
        .collect();
    files.sort();
    files.dedup();
    println!("backend: {}", rt.backend_name());
    for f in files {
        println!("checking {f} ...");
        match rt.load(&f) {
            Ok(e) => println!("  OK ({} params)", e.param_count),
            Err(e) => println!("  FAIL: {e:#}"),
        }
    }
    // optional execute smoke: --method exec-b runs forward_eval_base via the
    // buffer path, --method exec via the literal path
    let mode = args.get("method");
    if let Some(rest) = mode.strip_prefix("exec") {
        let buffers = rest.starts_with("-b");
        let entry_name = rest
            .split(':')
            .nth(1)
            .unwrap_or("forward_eval_base")
            .to_string();
        let cfg = manifest.config("tiny-llama")?;
        let mut rng = Rng::new(0);
        let base = shears::model::ParamStore::init_base(cfg, &mut rng, 0.05);
        let entry = cfg.entry(&entry_name)?;
        let exe = rt.load(&entry.file)?;
        // generic zero-filled inputs of the declared shapes/dtypes
        let owned: Vec<shears::tensor::HostTensor> = entry
            .inputs
            .iter()
            .map(|i| {
                if i.dtype == "i32" {
                    shears::tensor::HostTensor::from_i32(
                        &i.shape,
                        vec![1; i.shape.iter().product()],
                    )
                } else if base.contains(&i.name) {
                    base.get(&i.name).unwrap().clone()
                } else if i.name == "step" {
                    shears::tensor::HostTensor::scalar_f32(1.0)
                } else {
                    let mut t = shears::tensor::HostTensor::zeros(&i.shape);
                    if i.name.starts_with("lora_a") || i.name == "loss_mask" || i.name == "rank_mask" || i.name.starts_with("mask.") {
                        t.f32s_mut().iter_mut().for_each(|x| *x = 1.0);
                    }
                    t
                }
            })
            .collect();
        let tensors: Vec<&shears::tensor::HostTensor> = owned.iter().collect();
        let outs = if buffers {
            let margs: Vec<shears::runtime::Arg> =
                tensors.iter().map(|t| shears::runtime::Arg::Host(t)).collect();
            rt.run_args(&exe, &margs)?
        } else {
            rt.run(&exe, &tensors)?
        };
        println!(
            "exec smoke OK [{}]: {} outputs, first shape {:?}",
            entry_name,
            outs.len(),
            outs[0].shape
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::from_flag(args.get("backend"), args.get("artifacts"))?;
    let manifest = rt.manifest()?;
    println!(
        "shears backend={} manifest={}",
        rt.backend_name(),
        rt.artifacts_dir()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "builtin".into())
    );
    for (name, cfg) in &manifest.configs {
        let base: usize = shears::model::ModelConfig::numel(&cfg.base_params);
        let adpt: usize = shears::model::ModelConfig::numel(&cfg.adapter_params);
        println!(
            "  {name:<14} arch={:<6} d={} L={} params={:.2}M adapters={:.1}K ranks={:?} entries={}",
            cfg.arch,
            cfg.d_model,
            cfg.n_layers,
            base as f64 / 1e6,
            adpt as f64 / 1e3,
            cfg.rank_choices,
            cfg.entrypoints.len()
        );
    }
    println!("  prune ops: {}", manifest.prune_ops.len());
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let rt = Runtime::from_flag(args.get("backend"), args.get("artifacts"))?;
    let manifest = rt.manifest()?;
    let opts = PipelineOpts {
        config: args.get("config").to_string(),
        method: parse_method(args.get("method"))?,
        sparsity: args.get_f64("sparsity")?,
        pretrain_steps: args.get_usize("pretrain-steps")?,
        train_steps: args.get_usize("steps")?,
        lr: args.get_f64("lr")?,
        seed: args.get_usize("seed")? as u64,
        tasks: parse_tasks(args.get("tasks"))?,
        train_examples: args.get_usize("train-examples")?,
        eval_examples: args.get_usize("eval-examples")?,
        calib_batches: 4,
        hill_climb_budget: args.get_usize("hill-climb")?,
        search_eval_examples: 32,
        workdir: Some(args.get("workdir").into()),
        checkpoint_every: args.get_usize("checkpoint-every")?,
        resume: args.has("resume"),
        rollback_budget: args.get_usize("rollback-budget")?,
        eval_timeout_ms: args.get_u64("eval-timeout-ms")?,
    };
    let pipeline = ShearsPipeline::new(&rt, &manifest, opts)?;
    let report = pipeline.run()?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    // zero-shot / w-o-tune evaluation of the (pretrained) base model
    let rt = Runtime::from_flag(args.get("backend"), args.get("artifacts"))?;
    let manifest = rt.manifest()?;
    let cfg = manifest.config(args.get("config"))?;
    let vocab = Vocab::new(cfg.vocab);
    let opts = PipelineOpts {
        config: args.get("config").to_string(),
        pretrain_steps: args.get_usize("pretrain-steps")?,
        seed: args.get_usize("seed")? as u64,
        workdir: Some(args.get("workdir").into()),
        ..Default::default()
    };
    let pipeline = ShearsPipeline::new(&rt, &manifest, opts)?;
    let (base, _) = pipeline.pretrained_base()?;
    for task in parse_tasks(args.get("tasks"))? {
        let test = data::dataset(
            task,
            &vocab,
            args.get_usize("seed")? as u64 ^ 0x7E57,
            args.get_usize("eval-examples")?,
            cfg.seq_len,
        );
        let acc = evaluate(&rt, cfg, "forward_eval_base", &[&base], None, &test, &vocab)?;
        println!("{:<16} acc={:.3} (chance {:.3})", task.name(), acc, task.chance());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let rt = Runtime::from_flag(args.get("backend"), args.get("artifacts"))?;
    let manifest = rt.manifest()?;
    let cfg = manifest.config(args.get("config"))?;
    let opts = PipelineOpts {
        config: args.get("config").to_string(),
        pretrain_steps: args.get_usize("pretrain-steps")?,
        seed: args.get_usize("seed")? as u64,
        workdir: Some(args.get("workdir").into()),
        ..Default::default()
    };
    let pipeline = ShearsPipeline::new(&rt, &manifest, opts)?;
    let (base, _) = pipeline.pretrained_base()?;
    let vocab = Vocab::new(cfg.vocab);
    let mut rng = Rng::new(7);
    let deadline_ms = args.get_usize("deadline-ms")?;
    let max_wall_ms = args.get_usize("max-wall-ms")?;

    // multi-tenant mode: N tenants share the sparse base, each serving
    // its own NLS sub-adapter (a rank-mask over one shared LoRA store);
    // requests are tagged round-robin, with every (N+1)-th left on the
    // bare-base default
    let tenants = args.get_usize("tenants")?;
    let budget = args.get_bytes("adapter-budget")?;
    let space = shears::nls::SearchSpace::from_config(cfg);
    let tenant_masks: Vec<(String, shears::tensor::HostTensor)> = {
        let mut trng = Rng::new(args.get_usize("seed")? as u64 ^ 0x7E4A);
        (0..tenants)
            .map(|t| (format!("tenant-{t}"), space.rank_mask(&space.sample(&mut trng))))
            .collect()
    };
    let entry = if tenants > 0 { "forward_eval" } else { "forward_eval_base" };

    let requests: Vec<GenRequest> = (0..args.get_usize("requests")?)
        .map(|i| {
            let ex = Task::Gsm8kSim.sample(&vocab, &mut rng, cfg.seq_len);
            let mut r = GenRequest::new(
                ex.tokens[..ex.answer_start].to_vec(),
                args.get_usize("max-new").unwrap_or(8),
            );
            if deadline_ms > 0 {
                r = r.with_deadline(std::time::Duration::from_millis(deadline_ms as u64));
            }
            if max_wall_ms > 0 {
                r = r.with_max_wall_ms(max_wall_ms as u64);
            }
            if tenants > 0 && i % (tenants + 1) != tenants {
                r = r.with_adapter(tenant_masks[i % (tenants + 1)].0.clone());
            }
            r
        })
        .collect();

    let adapters = (tenants > 0)
        .then(|| shears::model::ParamStore::init_adapters(cfg, &mut Rng::new(0xADA9)));
    let submitters = args.get_usize("submitters")?;

    // overload-adaptive serving: --brownout arms the controller with
    // operator-friendly derived thresholds (lo = hi/2 hysteresis bands,
    // Shedding one tier above Degraded) — see serve::BrownoutOpts for
    // the raw knobs
    let brownout = {
        let mut b = shears::serve::BrownoutOpts::default();
        if args.has("brownout") {
            b.enabled = true;
            // CLI traffic opts in: the point of the flag is to degrade
            // rank rather than miss deadlines
            b.default_allow_degraded = true;
            b.fraction = args.get_f64("brownout-fraction")? as f32;
            b.shed_horizon_ms = args.get_f64("shed-horizon-ms")?;
            let step_hi = args.get_f64("brownout-step-hi-ms")?;
            if step_hi > 0.0 {
                b.degrade.step_ms_hi = step_hi;
                b.degrade.step_ms_lo = step_hi * 0.5;
                b.shed.step_ms_hi = step_hi * 4.0;
                b.shed.step_ms_lo = step_hi;
            }
            let queue_cap = args.get_usize("queue-cap")?;
            let queue_hi = match args.get_usize("brownout-queue-hi")? {
                0 => (queue_cap * 3 / 4).max(1),
                n => n,
            };
            b.degrade.queue_hi = queue_hi;
            b.degrade.queue_lo = queue_hi / 2;
            b.shed.queue_hi = queue_cap.saturating_sub(1).max(queue_hi);
            b.shed.queue_lo = queue_hi;
            let miss_hi = args.get_f64("brownout-miss-hi")?;
            if miss_hi > 0.0 {
                b.degrade.miss_hi = miss_hi;
                b.degrade.miss_lo = miss_hi * 0.5;
            }
        }
        b
    };
    if args.has("brownout") && submitters == 0 {
        eprintln!("--brownout needs the async frontend; add --submitters >= 1");
    }
    let metrics = if submitters == 0 {
        // synchronous batch API: fixed slice, FIFO admission, blocks
        let mut stores = vec![&base];
        stores.extend(adapters.as_ref());
        let decoder = Decoder::new(&rt, cfg, entry, stores, None)?;
        decoder.set_adapter_budget(budget)?;
        for (id, mask) in &tenant_masks {
            decoder.register_adapter(id, mask)?;
        }
        let (_responses, metrics) = decoder.serve(&requests)?;
        if tenants > 0 {
            println!(
                "tenants: {} resident adapters, {} bytes",
                decoder.adapter_ids().len(),
                decoder.adapter_bytes()
            );
        }
        metrics
    } else {
        // async frontend: the server thread owns its own backend + the
        // stores; N submitter threads drive the deadline-ordered queue
        let mut stores = vec![base];
        stores.extend(adapters);
        let server = ServeServer::spawn(
            ServerOpts {
                backend: args.get("backend").to_string(),
                artifacts_dir: args.get("artifacts").to_string(),
                config: args.get("config").to_string(),
                entry: entry.into(),
                slots: 0,
                queue_cap: args.get_usize("queue-cap")?,
                adapter_budget_bytes: budget,
                restart_budget: args.get_usize("restart-budget")? as u32,
                brownout: brownout.clone(),
                // deadlines stay advisory on the CLI; max_wall (above)
                // is the enforced budget. An empty fault plan means
                // SHEARS_FAULT drills arm automatically at spawn.
                ..Default::default()
            },
            stores,
            None,
        )?;
        for (id, mask) in &tenant_masks {
            server.register_adapter(id, mask)?;
        }
        let per = requests.len().div_ceil(submitters.max(1));
        std::thread::scope(|scope| {
            for (t, chunk) in requests.chunks(per.max(1)).enumerate() {
                let h = server.handle();
                scope.spawn(move || {
                    let streams: Vec<_> = chunk
                        .iter()
                        .filter_map(|r| match h.submit(r.clone()) {
                            Submit::Accepted(s) => Some(s),
                            Submit::Rejected(why) => {
                                eprintln!("submitter {t}: request rejected ({why:?})");
                                None
                            }
                        })
                        .collect();
                    for s in streams {
                        if let Err(e) = s.wait() {
                            eprintln!("submitter {t}: {e:#}");
                        }
                    }
                });
            }
        });
        let metrics = server.shutdown()?;
        println!(
            "async queue [{submitters} submitters]: ttft p50 {:.1} ms / p99 {:.1} ms, \
             {} deadline misses, {} rejected, max queue depth {}",
            metrics.p50_ttft_ms,
            metrics.p99_ttft_ms,
            metrics.deadline_misses,
            metrics.rejected,
            metrics.max_queue_depth
        );
        metrics
    };
    println!(
        "served {} requests: {:.1} tok/s, occupancy {:.1}/{}, p50 {:.1} ms, p99 {:.1} ms",
        metrics.requests,
        metrics.tokens_per_sec,
        metrics.mean_batch_occupancy,
        cfg.batch_eval,
        metrics.p50_latency_ms,
        metrics.p99_latency_ms
    );
    if metrics.decode_steps > 0 {
        println!(
            "decode path: {} prefills + {} KV-cached steps ({} truncated prompts)",
            metrics.prefills, metrics.decode_steps, metrics.truncated_prompts
        );
    }
    if metrics.faults + metrics.cancelled + metrics.quarantined + metrics.restarts > 0 {
        println!(
            "fault tolerance: {} faults, {} cancelled, {} quarantine recoveries, {} restarts",
            metrics.faults, metrics.cancelled, metrics.quarantined, metrics.restarts
        );
    }
    if brownout.enabled {
        println!(
            "brownout: {} degraded admissions (rank x{:.2}), {} shed, \
             {} transitions, {:.1}s degraded / {:.1}s shedding",
            metrics.degraded,
            brownout.fraction,
            metrics.shed,
            metrics.brownout_transitions,
            metrics.brownout_degraded_secs,
            metrics.brownout_shedding_secs
        );
    }
    Ok(())
}
