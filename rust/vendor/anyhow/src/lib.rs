//! Offline stand-in for the `anyhow` crate (the build environment has no
//! crates.io access — DESIGN.md §3 offline-registry constraint).
//!
//! Implements exactly the surface this repo uses:
//! * [`Error`] — a context-chain error. `{}` prints the outermost message,
//!   `{:#}` prints the whole chain joined with `": "` (matching anyhow's
//!   alternate formatting, which the CLI and tests rely on).
//! * [`Result`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] macros.
//!
//! Unlike the real crate there is no backtrace capture and no downcasting;
//! source errors are flattened into the message chain at conversion time.

use std::fmt;

/// Context-chain error. `chain[0]` is the outermost (most recently added)
/// message; the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the `Context` trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion does not collide with `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing");

        fn inner(x: u32) -> Result<u32> {
            if x > 3 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert_eq!(format!("{:#}", inner(9).unwrap_err()), "too big: 9");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn ensure_bails_with_message() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{:#}", f(12).unwrap_err()), "x too big: 12");
        assert!(format!("{:#}", f(5).unwrap_err()).contains("x != 5"));
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            let _n: usize = "x".parse()?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
